#!/usr/bin/env bash
# Test tiers for the LPF reproduction.
#
#   scripts/test.sh fast      pure planner/unit tests, seconds, no XLA compile
#   scripts/test.sh slow      XLA-compiling SPMD tests only
#   scripts/test.sh sanitize  full suite under LPF_SANITIZE=1 (repro.analysis)
#   scripts/test.sh tier1     the canonical verification command (full suite)
#   scripts/test.sh           == tier1
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-tier1}" in
  fast)  exec python -m pytest -q -m fast ;;
  slow)  exec python -m pytest -q -m slow ;;
  sanitize) LPF_SANITIZE=1 exec python -m pytest -q ;;
  tier1) exec python -m pytest -x -q ;;
  *)     echo "usage: scripts/test.sh [fast|slow|sanitize|tier1]" >&2; exit 2 ;;
esac
