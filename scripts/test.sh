#!/usr/bin/env bash
# Test tiers for the LPF reproduction.
#
#   scripts/test.sh fast      pure planner/unit tests, seconds, no XLA compile
#   scripts/test.sh slow      XLA-compiling SPMD tests only
#   scripts/test.sh sanitize  full suite under LPF_SANITIZE=1 (repro.analysis)
#   scripts/test.sh smoke     fault-injection smoke: one fixed plan per seam
#   scripts/test.sh chaos     seeded chaos soak (CHAOS_SEEDS plans, default 100)
#   scripts/test.sh tier1     the canonical verification command (full suite)
#   scripts/test.sh           == tier1
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-tier1}" in
  fast)  exec python -m pytest -q -m fast ;;
  slow)  exec python -m pytest -q -m slow ;;
  sanitize) LPF_SANITIZE=1 exec python -m pytest -q ;;
  # the chaos workloads run the real mesh path on 8 host devices; the
  # flag must be set before the interpreter starts (jax reads it at
  # first import)
  smoke)
    XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}" \
      exec python -m repro.runtime.faults --smoke ;;
  chaos)
    XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}" \
      exec python -m repro.runtime.faults --chaos \
        --seeds "${CHAOS_SEEDS:-100}" --seed0 "${CHAOS_SEED0:-0}" ;;
  tier1) exec python -m pytest -x -q ;;
  *)     echo "usage: scripts/test.sh [fast|slow|sanitize|smoke|chaos|tier1]" >&2
         exit 2 ;;
esac
