"""Adafactor (factored second moments) — the memory-lean optimizer option
for the 671B config: v is stored as row/col statistics for matrices,
cutting optimizer memory from 2x to ~1x+eps of the parameter count."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdafactorConfig", "adafactor_init", "adafactor_update"]


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: Any = 1e-3
    decay: float = 0.8           # t^-decay second-moment schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    min_dim_factored: int = 128


def _factored(p, cfg) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= cfg.min_dim_factored \
        and p.shape[-2] >= cfg.min_dim_factored


def adafactor_init(params, cfg: AdafactorConfig = AdafactorConfig()):
    def one(p):
        if _factored(p, cfg):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"acc": jax.tree.map(one, params,
                                is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params,
                     cfg: AdafactorConfig = AdafactorConfig()):
    step = state["step"] + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    def upd(p, g, acc):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + cfg.eps
        if "vr" in acc:
            vr = beta2 * acc["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * acc["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                              cfg.eps))
            upd_v = gf / jnp.maximum(denom, cfg.eps)
            new_acc = {"vr": vr, "vc": vc}
        else:
            v = beta2 * acc["v"] + (1 - beta2) * g2
            upd_v = gf / (jnp.sqrt(v) + cfg.eps)
            new_acc = {"v": v}
        rms = jnp.sqrt(jnp.mean(jnp.square(upd_v)) + 1e-30)
        upd_v = upd_v / jnp.maximum(1.0, rms / cfg.clip_threshold)
        return (p.astype(jnp.float32) - lr * upd_v).astype(p.dtype), new_acc

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    accs = treedef.flatten_up_to(state["acc"])
    out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, accs)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            {"acc": jax.tree.unflatten(treedef, [o[1] for o in out]),
             "step": step},
            {"lr": jnp.asarray(lr, jnp.float32)})
