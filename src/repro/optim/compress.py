"""Gradient compression with error feedback (the COMPRESSED sync
attribute's convergence-safe companion).

``ef_compress``: quantise (grad + residual) to int8 per-leaf, return the
quantised update and the *new* residual (what quantisation lost).  The
residual rides in the optimizer state, so information is delayed, never
destroyed — stale-synchronous in spirit, per the paper's future-work
refs [1, 16].
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "ef_compress", "ef_decompress"]


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q(leaf):
    scale = jnp.max(jnp.abs(leaf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(leaf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, residual) -> Tuple[dict, dict, dict]:
    """Returns (q_grads int8, scales, new_residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _q(x)
        deq = q.astype(jnp.float32) * s
        return q, s, x - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]),
            jax.tree.unflatten(treedef, [o[2] for o in out]))


def ef_decompress(q_grads, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_grads, scales)
