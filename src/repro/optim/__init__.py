"""Optimizers + gradient compression."""

from .adafactor import AdafactorConfig, adafactor_init, adafactor_update
from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .compress import ef_compress, ef_decompress, ef_init


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    """Standard warmup + cosine decay schedule."""
    import jax.numpy as jnp

    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup, warm, cos)
    return lr


__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "AdafactorConfig", "adafactor_init", "adafactor_update",
           "ef_compress", "ef_decompress", "ef_init", "warmup_cosine"]
