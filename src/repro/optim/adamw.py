"""AdamW with decoupled weight decay + global-norm clipping.

Pure-functional: state is a pytree congruent with params, shardable by
the same rules (ZeRO — optimizer state lives wherever the param shard
lives).  Moments are f32 regardless of param dtype unless
``factored_dtype`` overrides (memory policy for the 671B config).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Any = 3e-4                       # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(cfg.moment_dtype)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim > 1 else 0.0   # no decay on norms/biases
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.asarray(lr, jnp.float32)}
