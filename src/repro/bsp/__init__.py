"""BSP layer: immortal collectives and framework-facing sync programs,
all built on the LPF core primitives."""

from .collectives import (CollectiveHandle, allgather, allreduce,
                          allreduce_done, allreduce_start, alltoall,
                          broadcast, exscan, pad_to, reduce)
from .grad_sync import build_cross_pod_sync, lpf_allreduce
from .pod_sync import lpf_bucketed_allreduce

__all__ = [
    "allgather", "allreduce", "alltoall", "broadcast", "exscan", "reduce",
    "pad_to", "build_cross_pod_sync", "lpf_allreduce",
    "CollectiveHandle", "allreduce_start", "allreduce_done",
    "lpf_bucketed_allreduce",
]
