"""Cross-pod pytree all-reduce as LPF supersteps — the slow-link (DCN)
gradient hop, hand-lowered for arbitrary pytree payloads.

Why not the slot machinery: gradients are large sharded pytrees; the
1-D slot engine would force reshapes across sharded dims.  This module
lowers the same superstep schedule onto per-leaf collectives over the
pod axis, with the paper's sync attributes honoured:

* ``compress``   — quantised payloads on the wire: a shared (pmax'd)
                   scale + int16 summands halve DCN bytes; pair with
                   error feedback (``optim.compress``) in the caller's
                   optimizer state.
* ``no_conflict``— trivially true (reductions commute).

Lowering note: the q-1-round ring of ``ppermute`` over the pod axis of
auto-sharded leaves trips an XLA SPMD partitioner CHECK
(spmd_partitioner_util.cc partition-group mismatch) in partial-manual
regions, so the exchange lowers through ``lax.psum`` instead — identical
wire volume for q = 2 (the production pod count) and still a single
superstep.  Costs are recorded in a :class:`CostLedger` exactly like a
core sync, so the compliance checker can audit the compiled collectives.
Must run inside a shard_map region that is *manual over the pod axis*
(see ``runtime/train_step.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import CostLedger, LPF_SYNC_DEFAULT, SuperstepCost, SyncAttributes

__all__ = ["pod_allreduce"]


def _leaf_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def pod_allreduce(tree, q: int, axis: str = "pod", *,
                  attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                  mean: bool = True,
                  ledger: Optional[CostLedger] = None):
    """All-reduce a pytree over the ``axis`` of size ``q`` in one
    superstep; payloads optionally int16-quantised with a shared scale."""
    if q <= 1:
        return tree
    compress = attrs.compress is not None

    if compress:
        def one(l):
            lf = l.astype(jnp.float32)
            # shared scale across pods -> summands commute exactly
            scale = lax.pmax(jnp.max(jnp.abs(lf)), axis) / 127.0 + 1e-30
            qv = jnp.clip(jnp.round(lf / scale), -127, 127).astype(jnp.int16)
            s = lax.psum(qv, axis)
            return (s.astype(jnp.float32) * scale).astype(jnp.float32)
        acc = jax.tree.map(one, tree)
    else:
        acc = jax.tree.map(
            lambda l: lax.psum(l.astype(jnp.float32), axis), tree)

    if ledger is not None:
        n = _leaf_bytes(tree)
        per_round = (n // 2 if compress else n)
        wire = per_round * 2 * (q - 1) // q     # all-reduce: 2n(q-1)/q
        ledger.add(SuperstepCost(
            label=f"pod_allreduce[x{q}]", h_bytes=n * (q - 1) // q * 2,
            wire_bytes=wire, total_wire_bytes=wire * q, rounds=1,
            n_msgs=2 * (q - 1) * q,
            method="ring" + ("+int16" if compress else "")))
    if mean:
        acc = jax.tree.map(lambda a: a / q, acc)
    return jax.tree.map(lambda a, l: a.astype(l.dtype), acc, tree)
