"""Cross-pod pytree all-reduce as LPF supersteps — the slow-link (DCN)
gradient hop, hand-lowered for arbitrary pytree payloads.

Why not the slot machinery: gradients are large sharded pytrees; the
1-D slot engine would force reshapes across sharded dims.  This module
lowers the same superstep schedule onto per-leaf collectives over the
pod axis, with the paper's sync attributes honoured:

* ``compress``   — quantised payloads on the wire: a shared (pmax'd)
                   scale + int16 summands halve DCN bytes; pair with
                   error feedback (``optim.compress``) in the caller's
                   optimizer state.
* ``no_conflict``— trivially true (reductions commute).

Lowering note: the q-1-round ring of ``ppermute`` over the pod axis of
auto-sharded leaves trips an XLA SPMD partitioner CHECK
(spmd_partitioner_util.cc partition-group mismatch) in partial-manual
regions, so the exchange lowers through native reduction collectives
instead.  Two methods:

* ``rs+ag`` (default when uncompressed) — the gradients are flattened
  into one vector and synced as an explicit reduce-scatter + all-gather
  pair (``lax.psum_scatter`` + ``lax.all_gather``): the same fused
  transports the core planner picks for reduction supersteps, with the
  2n(q-1)/q wire split across two audited rounds.
* ``bucketed`` — per-layer gradients are packed greedily into
  ``bucket_bytes``-sized buckets, each synced as its own rs+ag pair:
  L per-layer supersteps become ceil(sum(B)/bucket) fat ones — the BSP
  model's "fewer, fatter h-relations" applied to the DCN hop (each
  extra superstep pays another ``l``, and DCN ``l`` is the largest in
  the machine table).  Buckets are issued in order with no explicit
  fence (XLA schedules freely, as it always has).
  ``bucket_bytes=None`` degenerates to one bucket (== ``rs+ag``).
* ``bucketed_fenced`` — the same buckets with the BSP superstep fence
  made explicit (an optimization barrier ties bucket k+1's input to
  bucket k's output, so it cannot launch early): the faithful
  *sequential* BSP schedule, and the baseline the overlap benchmark
  measures against.
* ``bucketed_overlap`` — the same buckets issued *split-phase* in
  REVERSE layer order (last layer's bucket first — the order the
  backward pass materialises gradients, so the first reduce-scatter
  can launch before earlier layers' gradients exist): each bucket's
  reduce-scatter launches before the previously issued bucket's
  all-gather, so the two independent collectives overlap on the wire —
  the classic DDP gradient-bucket pipeline.  The ledger records the
  overlapped schedule itself ([rs_B-1][ag_k||rs_k-1]...[ag_0], each
  group priced ``max(h_i)g + max(rounds_i)l + l_overlap`` via
  ``overlap_cost``).  ``auto`` with ``bucket_bytes`` picks this.
* ``ring``  — one ``lax.psum`` per leaf (XLA's own ring all-reduce);
  the compressed path always uses this, as int16 summands must be
  combined before dequantisation.

Costs are recorded in a :class:`CostLedger` exactly like a core sync,
so the compliance checker can audit the compiled collectives.  Must run
inside a shard_map region that is *manual over the pod axis* (see
``runtime/train_step.py``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import (CostLedger, LPF_SYNC_DEFAULT, SuperstepCost,
                        SyncAttributes, overlap_cost)

__all__ = ["pod_allreduce", "bucketize", "lpf_bucketed_allreduce"]


def _leaf_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def bucketize(sizes_bytes, bucket_bytes: Optional[int]):
    """Greedy contiguous packing of per-leaf byte sizes into buckets of
    at most ``bucket_bytes`` (a leaf larger than the bucket gets its
    own).  Returns a list of index lists.  ``bucket_bytes=None`` packs
    everything into one bucket.  Zero-byte leaves are skipped — they
    appear in no bucket (nothing to put on the wire) — so callers must
    pass such leaves through unchanged.  ``bucket_bytes <= 0`` is
    rejected: it used to silently mean per-leaf, which callers hit by
    accident when a byte-size computation underflowed."""
    if bucket_bytes is not None and bucket_bytes <= 0:
        raise ValueError(
            f"bucket_bytes must be a positive byte count or None (one "
            f"bucket), got {bucket_bytes!r}; pass e.g. 1 for per-leaf "
            f"buckets")
    if any(b < 0 for b in sizes_bytes):
        raise ValueError(f"negative leaf size in {sizes_bytes!r}")
    nonzero = [i for i, b in enumerate(sizes_bytes) if b > 0]
    if not nonzero:
        return []
    if bucket_bytes is None:
        return [nonzero]
    buckets, cur, cur_b = [], [], 0
    for i in nonzero:
        b = sizes_bytes[i]
        if cur and cur_b + b > bucket_bytes:
            buckets.append(cur)
            cur, cur_b = [], 0
        cur.append(i)
        cur_b += b
    buckets.append(cur)
    return buckets


def _rs_start(leaves, q: int, axis: str, fence=None):
    """The split-phase *start* half of one bucket's allreduce: flatten,
    pad, and issue the reduce-scatter.  ``fence`` (a prior bucket's
    completed output) is tied in through an optimization barrier when
    the caller wants the BSP superstep order enforced — the synchronous
    bucketed schedule; the overlapped schedule passes ``None`` so XLA
    may run this reduce-scatter while the previous bucket's all-gather
    is still on the wire."""
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    m = -(-n // q)
    if q * m > n:
        flat = jnp.concatenate([flat, jnp.zeros(q * m - n, jnp.float32)])
    if fence is not None:
        flat, _ = lax.optimization_barrier((flat, fence))
    red = lax.psum_scatter(flat.reshape(q, m), axis,
                           scatter_dimension=0, tiled=False)
    return red, shapes, n, m


def _ag_finish(red, shapes, n: int, q: int, axis: str):
    """The *done* half: all-gather the reduced chunks and unflatten."""
    full = lax.all_gather(red, axis, tiled=True)[:n]
    outs = []
    off = 0
    for shp in shapes:
        k = int(np.prod(shp)) if shp else 1
        outs.append(full[off:off + k].reshape(shp))
        off += k
    return outs, full


def pod_allreduce(tree, q: int, axis: str = "pod", *,
                  attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                  mean: bool = True,
                  ledger: Optional[CostLedger] = None,
                  method: str = "auto",
                  bucket_bytes: Optional[int] = None):
    """All-reduce a pytree over the ``axis`` of size ``q``; payloads
    optionally int16-quantised with a shared scale.

    ``method``: ``auto`` (bucketed_overlap when ``bucket_bytes`` is set,
    rs+ag when uncompressed, ring otherwise), ``rs+ag`` (explicit
    reduce-scatter + all-gather of the whole flattened tree),
    ``bucketed`` (one rs+ag pair per ~``bucket_bytes`` of gradients),
    ``bucketed_fenced`` (the same with an explicit BSP fence between
    buckets — the faithful sequential schedule), ``bucketed_overlap``
    (the buckets issued split-phase: bucket k+1's reduce-scatter
    launches before bucket k's all-gather — the classic DDP overlap),
    or ``ring`` (one ``lax.psum`` per leaf)."""
    if q <= 1:
        return tree
    compress = attrs.compress is not None
    bucket_methods = ("bucketed", "bucketed_fenced", "bucketed_overlap")
    if method not in ("auto", "rs+ag", "ring") + bucket_methods:
        raise ValueError(f"unknown pod_allreduce method {method!r}")
    if method == "auto":
        method = "ring" if compress else \
            ("bucketed_overlap" if bucket_bytes is not None else "rs+ag")
    if method in ("rs+ag",) + bucket_methods and compress:
        raise ValueError(f"{method} cannot combine quantised payloads; "
                         "use method='ring' with compression")

    if method in ("rs+ag",) + bucket_methods:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if not leaves:
            return tree
        # wire payloads are f32 regardless of the stored dtype
        sizes = [int(np.prod(l.shape)) * 4 if l.shape else 4
                 for l in leaves]
        buckets = bucketize(
            sizes, bucket_bytes if method != "rs+ag" else None)
        # zero-byte leaves ride no bucket: pass them through unchanged
        acc_leaves = [l.astype(jnp.float32) if sizes[i] == 0 else None
                      for i, l in enumerate(leaves)]

        def half_cost(bi, m, tag):
            """One superstep (the rs or the ag half) of bucket bi."""
            wire = (q - 1) * m * 4              # f32 on the wire, per pod
            return SuperstepCost(
                label=f"pod_allreduce.b{bi}.{tag}[x{q}]", h_bytes=wire,
                wire_bytes=wire, total_wire_bytes=wire * q, rounds=1,
                n_msgs=q * q, method=method)

        def account_pair(bi, m):
            if ledger is None:
                return
            wire = 2 * (q - 1) * m * 4          # f32 on the wire, per pod
            suffix = f".b{bi}" if method != "rs+ag" else ""
            ledger.add(SuperstepCost(
                label=f"pod_allreduce{suffix}[x{q}]", h_bytes=wire,
                wire_bytes=wire, total_wire_bytes=wire * q, rounds=2,
                n_msgs=2 * q * q, method=method))

        def finish(state, account=True):
            bi, idxs, red, shapes, n, m = state
            outs, full = _ag_finish(red, shapes, n, q, axis)
            for i, a in zip(idxs, outs):
                acc_leaves[i] = a
            if account:
                account_pair(bi, m)
            return full

        if method == "bucketed_overlap":
            # DDP-style software pipeline: issue the next bucket's
            # reduce-scatter *before* the previous bucket's all-gather,
            # so the two independent collectives can overlap on the
            # wire.  Buckets are issued LAST-LAYER-FIRST: the backward
            # pass materialises the last layers' gradients first, so
            # reversing the issue order lets XLA start the first
            # reduce-scatter before earlier layers' gradients exist —
            # matching gradient availability instead of fighting it.
            # The ledger records the schedule as issued —
            # [rs_B-1][ag_k||rs_k-1]... [ag_0] — with every overlap
            # group priced by the overlap cost model, so
            # predicted_seconds over this ledger is the overlapped
            # schedule's time, not the sequential one's.
            pending = None
            for bi, idxs in reversed(list(enumerate(buckets))):
                red, shapes, n, m = _rs_start(
                    [leaves[i] for i in idxs], q, axis)
                if ledger is not None:
                    rs_half = half_cost(bi, m, "rs")
                    if pending is None:
                        ledger.add(rs_half)
                    else:
                        ag_half = half_cost(pending[0], pending[5], "ag")
                        ledger.add(overlap_cost(
                            [ag_half, rs_half],
                            label=f"{ag_half.label}||{rs_half.label}"))
                if pending is not None:
                    finish(pending, account=False)
                pending = (bi, idxs, red, shapes, n, m)
            if pending is not None:
                finish(pending, account=False)
                if ledger is not None:
                    ledger.add(half_cost(pending[0], pending[5], "ag"))
        else:
            # in-order schedule; ``bucketed_fenced`` additionally makes
            # the BSP fence between supersteps explicit (bucket k+1
            # cannot launch early) — the sequential baseline the
            # overlap benchmark measures against
            fence = None
            for bi, idxs in enumerate(buckets):
                red, shapes, n, m = _rs_start(
                    [leaves[i] for i in idxs], q, axis,
                    fence=fence if method == "bucketed_fenced" else None)
                fence = finish((bi, idxs, red, shapes, n, m))
        acc = jax.tree_util.tree_unflatten(treedef, acc_leaves)
        if mean:
            acc = jax.tree.map(lambda a: a / q, acc)
        return jax.tree.map(lambda a, l: a.astype(l.dtype), acc, tree)

    if compress:
        def one(l):
            lf = l.astype(jnp.float32)
            # shared scale across pods -> summands commute exactly
            scale = lax.pmax(jnp.max(jnp.abs(lf)), axis) / 127.0 + 1e-30
            qv = jnp.clip(jnp.round(lf / scale), -127, 127).astype(jnp.int16)
            s = lax.psum(qv, axis)
            return (s.astype(jnp.float32) * scale).astype(jnp.float32)
        acc = jax.tree.map(one, tree)
    else:
        acc = jax.tree.map(
            lambda l: lax.psum(l.astype(jnp.float32), axis), tree)

    if ledger is not None:
        n = _leaf_bytes(tree)
        per_round = (n // 2 if compress else n)
        wire = per_round * 2 * (q - 1) // q     # all-reduce: 2n(q-1)/q
        ledger.add(SuperstepCost(
            label=f"pod_allreduce[x{q}]", h_bytes=n * (q - 1) // q * 2,
            wire_bytes=wire, total_wire_bytes=wire * q, rounds=1,
            n_msgs=2 * (q - 1) * q,
            method="ring" + ("+int16" if compress else "")))
    if mean:
        acc = jax.tree.map(lambda a: a / q, acc)
    return jax.tree.map(lambda a, l: a.astype(l.dtype), acc, tree)


def lpf_bucketed_allreduce(ctx, x: jnp.ndarray, bucket_elems: int, *,
                           mean: bool = False,
                           attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                           label: str = "ddp") -> jnp.ndarray:
    """Slot-based bucketed allreduce of a flat [n] vector — the DDP
    bucket pipeline expressed through the core program layer instead of
    per-leaf pod collectives.

    The vector splits into ceil(n/bucket_elems) buckets; every bucket's
    reduce-scatter + allgather pair is *started* split-phase before any
    is finished, so the whole schedule records as ONE program whose
    schedule search overlaps independent bucket supersteps, and whose
    replay (for a fixed shape) is a single compiled XLA computation.
    Used by the compiled-replay benchmark as the representative small-h
    iterated program."""
    from repro.bsp.collectives import allreduce_done, allreduce_start

    n = int(x.shape[0])
    if bucket_elems <= 0:
        raise ValueError(f"bucket_elems must be positive, got {bucket_elems}")
    with ctx.program(label):
        handles = []
        for k, off in enumerate(range(0, n, bucket_elems)):
            part = x[off:min(off + bucket_elems, n)]
            handles.append(allreduce_start(
                ctx, part, attrs=attrs, label=f"{label}.b{k}"))
        parts = [allreduce_done(ctx, h, mean=mean) for h in handles]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]
