"""BSP collectives implemented ON the LPF primitives.

These are the textbook one/two-superstep BSP algorithms (Valiant/McColl,
Bisseling) — *immortal* in the paper's sense: their cost is provable from
(p, g, l) alone and holds on any compliant layer.

============  ========================================  ==================
collective    algorithm                                  BSP cost
============  ========================================  ==================
broadcast     two-phase: scatter + allgather             2(n/p)(p-1)g + 2l
allgather     one superstep (fused all-gather path)      (n/p)(p-1)g + l
alltoall      one superstep (fused total exchange)       (n/p)(p-1)g + l
reduce        scatter(+local sum) to root chunks         ~2(n/p)(p-1)g + 2l
allreduce     scatter-reduce + allgather                 2(n/p)(p-1)g + 2l
scan          local scan + allgather of partials + fix   (p-1)wg + l
============  ========================================  ==================

``allreduce`` with ``CompressSpec`` quantises the wire payload (the
paper's relaxed-guarantee sync attribute): effective g drops by ~4x for
int8 at a bounded precision cost; combine with error feedback in
``optim/compress.py`` for convergence-safe gradient sync.

All functions take and return plain arrays and run inside any SPMD region
via :func:`repro.core.hook` — this is the interoperability story: the same
collective code serves the FFT, PageRank, and the training framework.

Although every call registers fresh slots, the superstep planner's cache
keys on the *shape* of the h-relation (slot ids canonically renamed), so
a collective invoked repeatedly — per layer, per FFT stage, per training
step trace — plans its exchange pattern once and replays the cached
:class:`repro.core.SuperstepPlan` thereafter.  Each ``ctx.sync`` returns
the superstep's :class:`repro.core.SuperstepCost` for callers that want
to thread costs upward without reading the ledger back.
"""

from __future__ import annotations

import math
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import LPFContext, LPF_SYNC_DEFAULT, SyncAttributes
from repro.core.errors import LPFFatalError

__all__ = ["broadcast", "allgather", "alltoall", "allreduce", "reduce",
           "exscan", "pad_to"]


def pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    return jnp.concatenate([x, jnp.zeros(n - x.shape[0], x.dtype)])


def _chunk(n: int, p: int) -> int:
    return -(-n // p)  # ceil


def allgather(ctx: LPFContext, x: jnp.ndarray, *,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "allgather") -> jnp.ndarray:
    """Every process contributes ``x`` (uniform shape [w]); returns [p*w]."""
    p = ctx.p
    w = int(x.shape[0])
    if p == 1:
        return x
    ctx.resize_memory_register(ctx.registry.n_active + 2)
    ctx.resize_message_queue(p * p)
    src = ctx.register_global(f"{label}.src", x)
    dst = ctx.register_global(f"{label}.dst", jnp.zeros(p * w, x.dtype))
    ctx.put_msgs([(s, d, src, 0, dst, s * w, w)
                  for s in range(p) for d in range(p)])
    ctx.sync(attrs, label=label)
    out = ctx.tensor(dst)
    ctx.deregister(src)
    ctx.deregister(dst)
    return out


def alltoall(ctx: LPFContext, x: jnp.ndarray, *,
             attrs: SyncAttributes = LPF_SYNC_DEFAULT,
             label: str = "alltoall") -> jnp.ndarray:
    """Canonical total exchange: ``x`` is [p*w]; chunk d goes to process d;
    returns [p*w] with chunk s received from process s."""
    p = ctx.p
    if p == 1:
        return x
    if x.shape[0] % p:
        raise LPFFatalError(f"alltoall payload {x.shape[0]} not divisible by p={p}")
    w = x.shape[0] // p
    ctx.resize_memory_register(ctx.registry.n_active + 2)
    ctx.resize_message_queue(p * p)
    src = ctx.register_global(f"{label}.src", x)
    dst = ctx.register_global(f"{label}.dst", jnp.zeros_like(x))
    ctx.put_msgs([(s, d, src, d * w, dst, s * w, w)
                  for s in range(p) for d in range(p)])
    ctx.sync(attrs, label=label)
    out = ctx.tensor(dst)
    ctx.deregister(src)
    ctx.deregister(dst)
    return out


def broadcast(ctx: LPFContext, x: jnp.ndarray, root: int = 0, *,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "broadcast") -> jnp.ndarray:
    """Two-phase broadcast (scatter + allgather): 2(n/p)(p-1)g + 2l —
    the BSP-optimal algorithm for n >= p (vs n(p-1)g for the naive put)."""
    p = ctx.p
    if p == 1:
        return x
    n = int(x.shape[0])
    c = _chunk(n, p)
    xp = pad_to(x, c * p)
    ctx.resize_memory_register(ctx.registry.n_active + 2)
    ctx.resize_message_queue(p + p * p)
    src = ctx.register_global(f"{label}.src", xp)
    buf = ctx.register_global(f"{label}.buf", jnp.zeros(c * p, x.dtype))
    # phase 1: root scatters chunk d to process d (p-1 messages from root)
    ctx.put_msgs([(root, d, src, d * c, buf, d * c, c)
                  for d in range(p)])
    ctx.sync(attrs, label=f"{label}.scatter")
    # phase 2: each process owns chunk `s` at offset s*c; allgather them
    ctx.put_msgs([(s, d, buf, s * c, buf, s * c, c)
                  for s in range(p) for d in range(p) if s != d])
    ctx.sync(attrs, label=f"{label}.allgather")
    out = ctx.tensor(buf)[:n]
    ctx.deregister(src)
    ctx.deregister(buf)
    return out


def reduce(ctx: LPFContext, x: jnp.ndarray, root: int = 0, *,
           op: Callable = jnp.add,
           attrs: SyncAttributes = LPF_SYNC_DEFAULT,
           label: str = "reduce") -> jnp.ndarray:
    """Reduction to ``root``: scatter-reduce then gather chunks at root."""
    y = allreduce(ctx, x, op=op, attrs=attrs, label=label)
    return y  # replicated result contains the root value


def allreduce(ctx: LPFContext, x: jnp.ndarray, *,
              op: Callable = jnp.add,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "allreduce") -> jnp.ndarray:
    """Two-superstep scatter-reduce + allgather: 2(n/p)(p-1)g + 2l —
    bandwidth-optimal, matching a ring all-reduce's 2n(p-1)/p volume."""
    p = ctx.p
    if p == 1:
        return x
    n = int(x.shape[0])
    c = _chunk(n, p)
    xp = pad_to(x, c * p)
    ctx.resize_memory_register(ctx.registry.n_active + 3)
    ctx.resize_message_queue(2 * p * p)
    src = ctx.register_global(f"{label}.src", xp)
    buf = ctx.register_global(f"{label}.buf", jnp.zeros(c * p, x.dtype))
    out = ctx.register_global(f"{label}.out", jnp.zeros(c * p, x.dtype))
    # superstep 1: total exchange — chunk d of every process lands on d
    ctx.put_msgs([(s, d, src, d * c, buf, s * c, c)
                  for s in range(p) for d in range(p)])
    ctx.sync(attrs, label=f"{label}.scatter")
    # local reduction of my chunk across all p contributions
    contrib = ctx.tensor(buf).reshape(p, c)
    if op is jnp.add:
        red = jnp.sum(contrib, axis=0)
    else:
        red = contrib[0]
        for i in range(1, p):
            red = op(red, contrib[i])
    ctx.write(out, jnp.concatenate([red, jnp.zeros(c * (p - 1), x.dtype)]))
    # superstep 2: allgather reduced chunks (mine lives at offset 0)
    ctx.put_msgs([(s, d, out, 0, out, s * c, c)
                  for s in range(p) for d in range(p)])
    ctx.sync(attrs, label=f"{label}.allgather")
    result = ctx.tensor(out)[:n]
    ctx.deregister(src)
    ctx.deregister(buf)
    ctx.deregister(out)
    return result


def exscan(ctx: LPFContext, x: jnp.ndarray, *,
           attrs: SyncAttributes = LPF_SYNC_DEFAULT,
           label: str = "exscan") -> jnp.ndarray:
    """Exclusive prefix sum over processes of a [w]-vector: local partials
    are allgathered (w(p-1)g + l) and summed below the caller's pid."""
    p = ctx.p
    if p == 1:
        return jnp.zeros_like(x)
    parts = allgather(ctx, x, attrs=attrs, label=label).reshape(p, -1)
    mask = (jnp.arange(p) < ctx.pid)[:, None].astype(x.dtype)
    return jnp.sum(parts * mask, axis=0)
