"""BSP collectives implemented ON the LPF primitives.

These are the textbook one/two-superstep BSP algorithms (Valiant/McColl,
Bisseling) — *immortal* in the paper's sense: their cost is provable from
(p, g, l) alone and holds on any compliant layer.

============  ========================================  ==================
collective    algorithm (fused superstep methods)        BSP cost
============  ========================================  ==================
broadcast     fused_scatter + fused_ag                   2(n/p)(p-1)g + 2l
allgather     one superstep (fused_ag)                   (n/p)(p-1)g + l
alltoall      one superstep (fused total exchange)       (n/p)(p-1)g + l
reduce        fused_rs + fused_gather to root            2(n/p)(p-1)g + 2l
allreduce     fused_rs + fused_ag                        2(n/p)(p-1)g + 2l
exscan        allgather of partials + local sum          (p-1)wg + l
============  ========================================  ==================

``reduce`` and ``allreduce`` stage *accumulating-put* supersteps
(``attrs.reduce_op``): the reduce-scatter relation — every process puts
chunk d at the same destination offset on process d, conflicting writes
combining — lowers to a single ``lax.psum_scatter`` (or ``all_to_all``
+ local combine for max/min), so the ledger's promise and the compiled
HLO are both one collective per superstep.  Ops other than
``jnp.add``/``jnp.maximum``/``jnp.minimum`` (or any op under wire
compression) fall back to the total-exchange + local-reduce algorithm —
same BSP cost, more rounds on the wire.

``allreduce`` with ``CompressSpec`` quantises the wire payload (the
paper's relaxed-guarantee sync attribute): effective g drops by ~4x for
int8 at a bounded precision cost; combine with error feedback in
``optim/compress.py`` for convergence-safe gradient sync.

All functions take and return plain arrays and run inside any SPMD region
via :func:`repro.core.hook` — this is the interoperability story: the same
collective code serves the FFT, PageRank, and the training framework.

Although every call registers fresh slots, the superstep planner's cache
keys on the *shape* of the h-relation (slot ids canonically renamed), so
a collective invoked repeatedly — per layer, per FFT stage, per training
step trace — plans its exchange pattern once and replays the cached
:class:`repro.core.SuperstepPlan` thereafter.  Each ``ctx.sync`` returns
the superstep's :class:`repro.core.SuperstepCost` for callers that want
to thread costs upward without reading the ledger back.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import LPFContext, LPF_SYNC_DEFAULT, Slot, SyncAttributes
from repro.core.errors import LPFFatalError
from repro.core.sync import _REDUCE_FNS

__all__ = ["broadcast", "allgather", "alltoall", "allreduce", "reduce",
           "exscan", "pad_to", "CollectiveHandle", "allreduce_start",
           "allreduce_done"]


def pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """Zero-pad a 1-D array to length ``n``."""
    if x.ndim != 1:
        raise LPFFatalError(
            f"pad_to expects a 1-D array, got shape {tuple(x.shape)}; "
            f"flatten tensors before padding")
    if x.shape[0] > n:
        raise LPFFatalError(
            f"pad_to cannot shrink: input length {x.shape[0]} exceeds "
            f"target {n}")
    if x.shape[0] == n:
        return x
    return jnp.concatenate([x, jnp.zeros(n - x.shape[0], x.dtype)])


def _chunk(n: int, p: int) -> int:
    return -(-n // p)  # ceil


def _reduce_op_name(op: Callable) -> Optional[str]:
    """Name of ``op`` in the planner's accumulating-put vocabulary
    (single source of truth: ``repro.core.sync._REDUCE_FNS``)."""
    for name, fn in _REDUCE_FNS.items():
        if op is fn:
            return name
    return None


def _use_fused_reduction(op: Callable, attrs: SyncAttributes
                         ) -> Optional[str]:
    """The reduce_op to stage, or None when the generic exchange
    algorithm must run instead: exotic combine fn, compressed wire
    (quantised payloads cannot be combined before dequantisation), or
    an explicit bruck/valiant method request (those schedules cannot
    combine conflicting writes)."""
    red_op = _reduce_op_name(op)
    if red_op is None or attrs.compress is not None \
            or attrs.method in ("bruck", "valiant"):
        return None
    return red_op


def allgather(ctx: LPFContext, x: jnp.ndarray, *,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "allgather") -> jnp.ndarray:
    """Every process contributes ``x`` (uniform shape [w]); returns [p*w]."""
    p = ctx.p
    w = int(x.shape[0])
    if p == 1:
        return x
    # one-superstep program: repeated allgathers of the same shape replay
    # the cached (and compiled) trace instead of re-planning the h-relation
    with ctx.program(label):
        ctx.resize_memory_register(ctx.registry.n_active + 2)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global(f"{label}.src", x)
        dst = ctx.register_global(f"{label}.dst", jnp.zeros(p * w, x.dtype))
        ctx.put_msgs([(s, d, src, 0, dst, s * w, w)
                      for s in range(p) for d in range(p)])
        ctx.sync(attrs, label=label)
        out = ctx.tensor(dst)
        ctx.deregister(src)
        ctx.deregister(dst)
    return out


def alltoall(ctx: LPFContext, x: jnp.ndarray, *,
             attrs: SyncAttributes = LPF_SYNC_DEFAULT,
             label: str = "alltoall") -> jnp.ndarray:
    """Canonical total exchange: ``x`` is [p*w]; chunk d goes to process d;
    returns [p*w] with chunk s received from process s."""
    p = ctx.p
    if p == 1:
        return x
    if x.shape[0] % p:
        raise LPFFatalError(f"alltoall payload {x.shape[0]} not divisible by p={p}")
    w = x.shape[0] // p
    # one-superstep program — same caching rationale as allgather
    with ctx.program(label):
        ctx.resize_memory_register(ctx.registry.n_active + 2)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global(f"{label}.src", x)
        dst = ctx.register_global(f"{label}.dst", jnp.zeros_like(x))
        ctx.put_msgs([(s, d, src, d * w, dst, s * w, w)
                      for s in range(p) for d in range(p)])
        ctx.sync(attrs, label=label)
        out = ctx.tensor(dst)
        ctx.deregister(src)
        ctx.deregister(dst)
    return out


def broadcast(ctx: LPFContext, x: jnp.ndarray, root: int = 0, *,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "broadcast") -> jnp.ndarray:
    """Two-phase broadcast (scatter + allgather): 2(n/p)(p-1)g + 2l —
    the BSP-optimal algorithm for n >= p (vs n(p-1)g for the naive put).
    Both phases take fused one-collective supersteps (fused_scatter +
    fused_ag): 2 rounds total instead of the p+1 coloured rounds of the
    generic schedule."""
    p = ctx.p
    if p == 1:
        return x
    n = int(x.shape[0])
    c = _chunk(n, p)
    xp = pad_to(x, c * p)
    with ctx.program("broadcast"):
        ctx.resize_memory_register(ctx.registry.n_active + 2)
        ctx.resize_message_queue(p + p * p)
        src = ctx.register_global(f"{label}.src", xp)
        buf = ctx.register_global(f"{label}.buf", jnp.zeros(c * p, x.dtype))
        # phase 1: root scatters chunk d to process d (p-1 msgs from root)
        ctx.put_msgs([(root, d, src, d * c, buf, d * c, c)
                      for d in range(p)])
        ctx.sync(attrs, label=f"{label}.scatter")
        # phase 2: each process owns chunk `s` at offset s*c; allgather
        ctx.put_msgs([(s, d, buf, s * c, buf, s * c, c)
                      for s in range(p) for d in range(p) if s != d])
        ctx.sync(attrs, label=f"{label}.allgather")
        out = ctx.tensor(buf)[:n]
        ctx.deregister(src)
        ctx.deregister(buf)
    return out


def _reduce_scatter_chunk(ctx: LPFContext, xp: jnp.ndarray, c: int,
                          red_op: str, attrs: SyncAttributes,
                          label: str):
    """Stage + sync the fused reduce-scatter superstep: chunk d of every
    process combines (via ``red_op``) into a [c]-slot on process d.
    Returns the chunk slot (caller deregisters)."""
    p = ctx.p
    src = ctx.register_global(f"{label}.src", xp)
    buf = ctx.register_global(f"{label}.chunk", jnp.zeros(c, xp.dtype))
    ctx.put_msgs([(s, d, src, d * c, buf, 0, c)
                  for s in range(p) for d in range(p)])
    ctx.sync(attrs.replace(reduce_op=red_op), label=f"{label}.rs")
    ctx.deregister(src)
    return buf


@dataclasses.dataclass
class CollectiveHandle:
    """A split-phase collective in flight: its supersteps are staged
    (deferred into the recording trace, or already executed when the
    context is not recording) but the result read is postponed.  Reading
    through the matching ``*_done`` call is what flushes the handle's
    dependency cone — starting several collectives before finishing any
    keeps them in one trace, where the optimizer's schedule search
    batches, reorders, or overlaps them (the DDP bucket pipeline),
    non-adjacent supersteps included."""

    out_slot: Optional[Slot]
    n: int                       # valid payload length in the out slot
    p: int
    value: Optional[jnp.ndarray] = None   # eager fallback result


def _fused_reduction_start(ctx: LPFContext, x: jnp.ndarray, red_op: str,
                           attrs: SyncAttributes, label: str, suffix: str,
                           chunk_dsts: Callable) -> CollectiveHandle:
    """Stage the fused-reduction pair split-phase: reduce-scatter the
    chunks, then a second superstep distributing them per
    ``chunk_dsts(s, p)`` — every process s's reduced [c]-chunk lands at
    offset s*c on those pids.  The result read is deferred to
    :func:`_fused_reduction_done`."""
    p = ctx.p
    n = int(x.shape[0])
    c = _chunk(n, p)
    ctx.resize_memory_register(ctx.registry.n_active + 3)
    ctx.resize_message_queue(p * p)
    buf = _reduce_scatter_chunk(ctx, pad_to(x, c * p), c, red_op, attrs,
                                label)
    out = ctx.register_global(f"{label}.out", jnp.zeros(c * p, x.dtype))
    ctx.put_msgs([(s, d, buf, 0, out, s * c, c)
                  for s in range(p) for d in chunk_dsts(s, p)])
    ctx.sync(attrs, label=f"{label}.{suffix}")
    ctx.deregister(buf)      # deferred while the trace references it
    return CollectiveHandle(out_slot=out, n=n, p=p)


def _fused_reduction_done(ctx: LPFContext, handle: CollectiveHandle
                          ) -> jnp.ndarray:
    if handle.value is not None:
        return handle.value
    result = ctx.tensor(handle.out_slot)[:handle.n]
    ctx.deregister(handle.out_slot)
    return result


def _fused_reduction(ctx: LPFContext, x: jnp.ndarray, red_op: str,
                     attrs: SyncAttributes, label: str, suffix: str,
                     chunk_dsts: Callable) -> jnp.ndarray:
    with ctx.program("fused_reduction"):
        handle = _fused_reduction_start(ctx, x, red_op, attrs, label,
                                        suffix, chunk_dsts)
        result = _fused_reduction_done(ctx, handle)
    return result


def allreduce_start(ctx: LPFContext, x: jnp.ndarray, *,
                    op: Callable = jnp.add,
                    attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                    label: str = "allreduce") -> CollectiveHandle:
    """Split-phase allreduce, superstep 1 of the DDP overlap story:
    stage the reduce-scatter + allgather pair *without* reading the
    result.  Inside a recording, several started allreduces share one
    trace, where the optimizer's schedule search hoists the mutually
    independent supersteps together — all buckets' reduce-scatters
    issue as one overlap group, then all the allgathers (each depends
    only on its own bucket's reduce-scatter); :func:`allreduce_done`
    flushes exactly the handle's dependency cone.  Ops with no fused
    lowering (exotic combine fns, compressed wire) fall back to the
    eager exchange algorithm and return a pre-resolved handle."""
    if ctx.p == 1:
        return CollectiveHandle(None, int(x.shape[0]), 1, value=x)
    red_op = _use_fused_reduction(op, attrs)
    if red_op is None:
        return CollectiveHandle(
            None, int(x.shape[0]), ctx.p,
            value=_allreduce_exchange(ctx, x, op=op, attrs=attrs,
                                      label=label))
    return _fused_reduction_start(ctx, x, red_op, attrs, label, "ag",
                                  lambda s, p_: range(p_))


def allreduce_done(ctx: LPFContext, handle: CollectiveHandle, *,
                   mean: bool = False) -> jnp.ndarray:
    """Finish a :func:`allreduce_start`: read (cone-flushing) the result
    and release the slot; optionally average."""
    out = _fused_reduction_done(ctx, handle)
    return out / handle.p if mean else out


def reduce(ctx: LPFContext, x: jnp.ndarray, root: int = 0, *,
           op: Callable = jnp.add,
           attrs: SyncAttributes = LPF_SYNC_DEFAULT,
           label: str = "reduce") -> jnp.ndarray:
    """Genuine two-superstep reduction to ``root``: a fused
    reduce-scatter of chunks, then a fused gather of the reduced chunks
    to root — 2(n/p)(p-1)g + 2l, half the rounds and none of the
    replication of an allreduce.  Non-root processes return zeros (the
    result is defined at root only, as in the paper's BSP reduce)."""
    p = ctx.p
    if p == 1:
        return x
    red_op = _use_fused_reduction(op, attrs)
    if red_op is None:
        # no fused lowering: reduce via the allreduce algorithm
        y = _allreduce_exchange(ctx, x, op=op, attrs=attrs, label=label)
        return jnp.where(ctx.pid == root, y, jnp.zeros_like(y))
    # superstep 2 gathers the reduced chunks at root (fused_gather)
    return _fused_reduction(ctx, x, red_op, attrs, label, "gather",
                            lambda s, p_: (root,))


def allreduce(ctx: LPFContext, x: jnp.ndarray, *,
              op: Callable = jnp.add,
              attrs: SyncAttributes = LPF_SYNC_DEFAULT,
              label: str = "allreduce") -> jnp.ndarray:
    """Two-superstep reduce-scatter + allgather: 2(n/p)(p-1)g + 2l —
    bandwidth-optimal, matching a ring all-reduce's 2n(p-1)/p volume.

    For sum/max/min without wire compression both supersteps take the
    fused paths (``lax.psum_scatter`` + ``lax.all_gather``): the ledger
    records 1 round each, and the compiled HLO carries exactly one
    reduce-scatter and one all-gather."""
    p = ctx.p
    if p == 1:
        return x
    red_op = _use_fused_reduction(op, attrs)
    if red_op is None:
        return _allreduce_exchange(ctx, x, op=op, attrs=attrs, label=label)
    # superstep 2 allgathers the reduced chunks to everyone (fused_ag)
    return _fused_reduction(ctx, x, red_op, attrs, label, "ag",
                            lambda s, p_: range(p_))


def _allreduce_exchange(ctx: LPFContext, x: jnp.ndarray, *,
                        op: Callable = jnp.add,
                        attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                        label: str = "allreduce") -> jnp.ndarray:
    """The generic algorithm: total exchange + local reduce + allgather.
    Same 2(n/p)(p-1)g + 2l cost; used when the op has no accumulating-put
    lowering or the wire is compressed (quantised payloads must be
    decompressed before they can be combined)."""
    p = ctx.p
    n = int(x.shape[0])
    c = _chunk(n, p)
    xp = pad_to(x, c * p)
    with ctx.program("allreduce_exchange"):
        ctx.resize_memory_register(ctx.registry.n_active + 3)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global(f"{label}.src", xp)
        buf = ctx.register_global(f"{label}.buf", jnp.zeros(c * p, x.dtype))
        out = ctx.register_global(f"{label}.out", jnp.zeros(c * p, x.dtype))
        # superstep 1: total exchange — chunk d of every process lands on d
        ctx.put_msgs([(s, d, src, d * c, buf, s * c, c)
                      for s in range(p) for d in range(p)])
        ctx.sync(attrs, label=f"{label}.scatter")
        # local reduction of my chunk across all p contributions (the
        # tensor read flushes the exchange — a compute barrier)
        contrib = ctx.tensor(buf).reshape(p, c)
        if op is jnp.add:
            red = jnp.sum(contrib, axis=0)
        else:
            red = contrib[0]
            for i in range(1, p):
                red = op(red, contrib[i])
        ctx.write(out, jnp.concatenate([red,
                                        jnp.zeros(c * (p - 1), x.dtype)]))
        # superstep 2: allgather reduced chunks (mine lives at offset 0)
        ctx.put_msgs([(s, d, out, 0, out, s * c, c)
                      for s in range(p) for d in range(p)])
        ctx.sync(attrs, label=f"{label}.allgather")
        result = ctx.tensor(out)[:n]
        ctx.deregister(src)
        ctx.deregister(buf)
        ctx.deregister(out)
    return result


def exscan(ctx: LPFContext, x: jnp.ndarray, *,
           attrs: SyncAttributes = LPF_SYNC_DEFAULT,
           label: str = "exscan") -> jnp.ndarray:
    """Exclusive prefix sum over processes of a [w]-vector: local partials
    are allgathered through the fused_ag superstep (w(p-1)g + l, one
    ``lax.all_gather`` on the wire) and summed below the caller's pid."""
    p = ctx.p
    if p == 1:
        return jnp.zeros_like(x)
    parts = allgather(ctx, x, attrs=attrs, label=f"{label}.ag").reshape(p, -1)
    mask = (jnp.arange(p) < ctx.pid)[:, None].astype(x.dtype)
    return jnp.sum(parts * mask, axis=0)
