"""Cross-pod gradient synchronisation as an explicit LPF superstep program.

At 1000+ node scale the pod-to-pod (DCN) hop is the slow link; this module
owns that hop so the paper's sync attributes can be applied to it:

* default      — BSP reduce-scatter + allgather over the ``pod`` axis
                 (bandwidth-optimal 2n(q-1)/q wire for q pods), staged
                 as accumulating-put supersteps so the whole sync is one
                 ``reduce-scatter`` + one ``all-gather`` on the wire,
* COMPRESSED   — int8 payloads on the wire (effective g / 4); pair with
                 error feedback (``optim/compress.py``) for convergence,
* STALE(k)     — at *bucket* granularity when ``bucket_bytes`` is set:
                 the local-SGD outer loop used to skip whole syncs;
                 with ``attrs.stale = k`` the sync instead skips
                 individual stale buckets on off-steps
                 (:func:`bucket_staleness` — the last-layer bucket,
                 whose gradients carry the highest variance, stays
                 fresh every step; lower-variance buckets sync every
                 k-th step).  Without buckets the loop-level skip
                 (``runtime/train_loop.py sync_every``) still applies.

The sync runs fully *manual* (shard_map over all mesh axes) on per-device
gradient shards: devices with equal (data, model) coordinates across pods
exchange and average their shards — the intra-pod reduction has already
happened via GSPMD reduce-scatter during the backward pass, making the
whole gradient path a two-level hierarchical all-reduce.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import LPFContext, LPF_SYNC_DEFAULT, SyncAttributes, hook
from repro.core import compat
from . import collectives

__all__ = ["build_cross_pod_sync", "bucket_staleness", "lpf_allreduce"]


def bucket_staleness(n_buckets: int, stale: int) -> list:
    """Per-bucket staleness schedule for the bucketed-sync x local-SGD
    composition: bucket ``b`` syncs on (static) step ``s`` iff its entry
    here is 0 or ``s`` is a multiple of it.

    Bucket indices follow :func:`repro.bsp.pod_sync.bucketize` order
    (first bucket = first layers).  The LAST bucket — the layers
    closest to the loss, whose gradients carry the highest variance and
    tolerate staleness worst — is always fresh; every earlier
    (lower-variance) bucket inherits ``stale`` and is skipped on
    off-steps.  ``stale <= 0`` disables skipping entirely."""
    if stale <= 0 or n_buckets <= 0:
        return [0] * max(n_buckets, 0)
    return [stale] * (n_buckets - 1) + [0]


def lpf_allreduce(ctx: LPFContext, x: jnp.ndarray, *,
                  op=jnp.add,
                  attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                  mean: bool = False) -> jnp.ndarray:
    """Allreduce a flat vector over the context axes; optionally average.

    Rides the fused reduce-scatter + allgather supersteps for
    sum/max/min (uncompressed), the exchange algorithm otherwise."""
    out = collectives.allreduce(ctx, x, op=op, attrs=attrs)
    return out / ctx.p if mean else out


def build_cross_pod_sync(mesh: jax.sharding.Mesh, grad_specs: Any, *,
                         attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                         pod_axis: str = "pod", mean: bool = True,
                         bucket_bytes: Optional[int] = None):
    """Returns ``sync(grads) -> grads`` averaging across ``pod_axis``.

    ``grad_specs`` is a pytree of PartitionSpecs congruent with ``grads``
    (the parameter sharding rules).  If the mesh has no pod axis (or one
    pod) the function is the identity — single-pod programs pay nothing.

    With ``bucket_bytes`` the per-leaf gradients are packed into
    ~``bucket_bytes``-sized buckets and every bucket's reduce-scatter +
    all-gather pair is staged *split-phase* into one recorded LPF
    program before any result is read — in REVERSE layer order (the
    last layers' gradients materialise first in the backward pass, so
    issuing their bucket first lets the first reduce-scatter launch as
    soon as those gradients exist): the program optimizer's schedule
    search then overlaps the mutually independent cross-bucket
    supersteps (only same-bucket pairs are data-dependent), and the
    dataflow-precise flush lets each result read execute exactly its
    own bucket's cone.  Repeated training steps replay the whole
    cached multi-bucket trace.

    ``attrs.stale = k > 0`` composes bucketing with local SGD at bucket
    granularity: ``sync(grads, step=i)`` (``step`` is a *static* Python
    int — pass it at trace time, one jitted variant per phase) skips
    the stale buckets on off-steps per :func:`bucket_staleness`; their
    leaves pass through pod-local.  The last-layer bucket always
    syncs."""
    if pod_axis not in mesh.axis_names or mesh.shape[pod_axis] == 1:
        return lambda grads, step=0: grads

    def sync(grads, step: int = 0):
        leaves, treedef = compat.tree_flatten(grads)
        specs = compat.tree_flatten(grad_specs)[0]

        def body(*local_leaves):
            def spmd(ctx, s, p, leaves_in):
                from .pod_sync import bucketize
                shapes = [l.shape for l in leaves_in]
                dtypes = [l.dtype for l in leaves_in]
                flats = [l.reshape(-1).astype(jnp.float32)
                         for l in leaves_in]
                buckets = bucketize([f.nbytes for f in flats],
                                    bucket_bytes)
                stales = bucket_staleness(len(buckets), attrs.stale)
                # start every bucket's rs+ag pair inside ONE recording,
                # last-layer bucket first (backward-pass gradient
                # availability); exiting the program flushes the whole
                # multi-bucket trace as one optimized program whose
                # schedule search overlaps the independent cross-bucket
                # supersteps split-phase
                handles = []
                with ctx.program("bucket_sync"):
                    for bi, idxs in reversed(list(enumerate(buckets))):
                        if stales[bi] and step % stales[bi] != 0:
                            continue    # stale bucket: keep local grads
                        flat = jnp.concatenate([flats[i] for i in idxs]) \
                            if len(idxs) > 1 else flats[idxs[0]]
                        n = flat.shape[0]
                        pad = (-n) % max(p, 1)
                        flat = collectives.pad_to(flat, n + pad)
                        handles.append((idxs, n, collectives.allreduce_start(
                            ctx, flat, attrs=attrs, label=f"bucket{bi}")))
                red_parts = [None] * len(flats)
                for idxs, n, handle in handles:
                    red = collectives.allreduce_done(ctx, handle,
                                                     mean=mean)[:n]
                    off = 0
                    for i in idxs:
                        k = flats[i].shape[0]
                        red_parts[i] = red[off:off + k]
                        off += k
                outs = []
                for part, flat, shp, dt in zip(red_parts, flats, shapes,
                                               dtypes):
                    if part is None:
                        # zero-byte leaf, or a stale-skipped bucket:
                        # nothing on the wire, the pod-local value rides
                        part = flat
                    outs.append(part.reshape(shp).astype(dt))
                return tuple(outs)

            return hook((pod_axis,), spmd, tuple(local_leaves))

        out = compat.shard_map(body, mesh=mesh, in_specs=tuple(specs),
                               out_specs=tuple(specs),
                               check_vma=False)(*leaves)
        return compat.tree_unflatten(treedef, list(out))

    return sync
