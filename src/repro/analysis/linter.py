"""Static race/hazard linter over recorded LPF traces.

:func:`lint_trace` walks a list of :class:`repro.core.ProgramStep` and
reports stable-coded diagnostics without executing anything:

==========  ========  =================================================
code        severity  meaning
==========  ========  =================================================
``LPF001``  error     write-write race in a table the user asserted
                      ``no_conflict`` on — the result depends on CRCW
                      arbitration order, which ``no_conflict`` lowering
                      is licensed to ignore
``LPF002``  error     read of a slot region never written since the
                      slot was declared undefined (pass ``undefined=``)
``LPF003``  error     message references a slot deregistered earlier in
                      the recording (pass ``events=``); as a *warning*,
                      a slot registered during the recording that is
                      never deregistered (leak across the recording)
``LPF004``  error     malformed message: pid out of range, negative
                      size, source/destination extent out of bounds of
                      the registered slot, dtype mismatch, or a
                      remotely-referred ``register_local`` slot
``LPF005``  warning   self-message whose source and destination ranges
                      overlap but are shifted — the copy aliases itself
                      and the result depends on copy direction
``LPF006``  warning   dead transfer: the destination range is fully
                      overwritten by a later superstep before any read
                      (:func:`lint_program` reports the ones that
                      *survive* optimization)
==========  ========  =================================================

The interval/conflict logic here is deliberately self-contained (it
re-implements the three-line overlap predicates instead of importing
the optimizer's) so a bug in ``repro.core.program``'s hazard relations
cannot blind the linter to it.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

from ..core.attrs import SyncAttributes
from ..core.program import ProgramStep, SuperstepProgram, canonical_order
from ..core.sync import Msg, find_conflict

__all__ = ["Diagnostic", "ERROR", "WARNING", "lint_trace", "lint_program"]

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One linter/verifier finding, printable as
    ``CODE severity step[N]: message  <offending Msg>``."""

    code: str               # "LPF001".."LPF006" / "LPF1xx" (verifier)
    severity: str           # ERROR | WARNING
    step: int               # step rank it anchors to; -1 = whole trace
    message: str
    msg: Optional[Msg] = None

    def __str__(self) -> str:
        where = f"step[{self.step}]" if self.step >= 0 else "trace"
        tail = f"  {self.msg}" if self.msg is not None else ""
        return f"{self.code} {self.severity} {where}: {self.message}{tail}"


# --------------------------------------------------------------------------
# self-contained interval / hazard primitives
# --------------------------------------------------------------------------

def _overlaps(a_off: int, a_size: int, b_off: int, b_size: int) -> bool:
    return a_off < b_off + b_size and b_off < a_off + a_size


def _reads(reader: Msg, writer: Msg) -> bool:
    """Does ``reader``'s source range observe ``writer``'s destination?"""
    return (reader.src == writer.dst
            and reader.src_slot.sid == writer.dst_slot.sid
            and _overlaps(reader.src_off, reader.size,
                          writer.dst_off, writer.size))


def _waw(a: Msg, b: Msg) -> bool:
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and _overlaps(a.dst_off, a.size, b.dst_off, b.size))


def _merge_intervals(ivs: Iterable[Sequence[int]]) -> List[List[int]]:
    """Normalize half-open ``[lo, hi)`` intervals: sorted and disjoint
    (touching intervals merge)."""
    out: List[List[int]] = []
    for lo, hi in sorted(tuple(iv) for iv in ivs):
        if out and lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return out


def _covered(ivs: Sequence[Sequence[int]], lo: int, hi: int) -> bool:
    """Is ``[lo, hi)`` fully inside the (merged) interval set?"""
    if lo >= hi:
        return True
    for a, b in ivs:
        if a <= lo < b:
            lo = b
            if lo >= hi:
                return True
    return False


def _dead_transfers(tables: Sequence[Sequence[Msg]],
                    attrs_list: Sequence[SyncAttributes]
                    ) -> List[Tuple[int, Msg, int]]:
    """``(step, msg, overwriting_step)`` for every transfer whose
    destination range is fully overwritten before any read.

    Deliberately *permissive* (a union of one later superstep's writes
    counts as an overwrite, compressed supersteps are skipped as
    overwriters but their reads still protect) — this is the deadness
    the verifier accepts as justification for a dropped transfer, so it
    must never be stricter than what the optimizer actually kills."""
    out: List[Tuple[int, Msg, int]] = []
    for i, tbl in enumerate(tables):
        for m in tbl:
            if m.size <= 0:
                continue
            for j in range(i + 1, len(tables)):
                if any(_reads(r, m) for r in tables[j]):
                    break           # observed before any full overwrite
                if attrs_list[j].compress is not None:
                    continue        # lossy wire: not a clean overwrite
                writes = [(w.dst_off, w.dst_off + w.size)
                          for w in tables[j]
                          if w.dst == m.dst and w.size > 0
                          and w.dst_slot.sid == m.dst_slot.sid]
                if writes and _covered(_merge_intervals(writes),
                                       m.dst_off, m.dst_off + m.size):
                    out.append((i, m, j))
                    break
    return out


# --------------------------------------------------------------------------
# per-message extent lint (LPF004) — a non-raising Msg.validate
# --------------------------------------------------------------------------

def _lint_msg(m: Msg, p: int, step: int) -> List[Diagnostic]:
    out: List[Diagnostic] = []

    def err(text: str) -> None:
        out.append(Diagnostic("LPF004", ERROR, step, text, m))

    if not (0 <= m.src < p and 0 <= m.dst < p):
        err(f"pid out of range for p={p}")
    if m.size < 0:
        err("negative size")
    else:
        if m.src_off < 0 or m.src_off + m.size > m.src_slot.size:
            err(f"source range [{m.src_off}, {m.src_off + m.size}) exceeds "
                f"slot {m.src_slot.name}#{m.src_slot.sid} of size "
                f"{m.src_slot.size}")
        if m.dst_off < 0 or m.dst_off + m.size > m.dst_slot.size:
            err(f"destination range [{m.dst_off}, {m.dst_off + m.size}) "
                f"exceeds slot {m.dst_slot.name}#{m.dst_slot.sid} of size "
                f"{m.dst_slot.size}")
    if m.src_slot.dtype != m.dst_slot.dtype:
        err("source/destination dtype mismatch")
    if m.src != m.dst:
        need_global = {"put": (m.dst_slot,), "get": (m.src_slot,),
                       "table": (m.src_slot, m.dst_slot)}
        for slot in need_global.get(m.origin, ()):
            if slot.kind != "global":
                err(f"remotely-referred slot {slot.name}#{slot.sid} is "
                    f"register_local (origin {m.origin!r})")
    return out


# --------------------------------------------------------------------------
# the trace linter
# --------------------------------------------------------------------------

def lint_trace(steps: Sequence[ProgramStep], p: int, *,
               undefined: Iterable[int] = (),
               events: Iterable[Tuple[int, str, int]] = (),
               check_dead: bool = True) -> List[Diagnostic]:
    """Lint a recorded trace; returns diagnostics in step order.

    ``undefined`` — sids whose initial contents are undefined (output
    buffers); reads of their never-written regions are LPF002 errors.
    ``events`` — ``(step, "register"|"deregister", sid)`` slot-lifetime
    events, each taking effect *before* step ``step`` (``len(steps)``
    means after the last step); they drive LPF003.  ``check_dead=False``
    skips the LPF006 dead-transfer scan (the sanitizer does, reporting
    instead the dead transfers that *survive* optimization via
    :func:`lint_program`)."""
    steps = list(steps)
    diags: List[Diagnostic] = []

    # LPF004 — malformed messages
    for i, st in enumerate(steps):
        for m in st.msgs:
            diags.extend(_lint_msg(m, p, i))

    # LPF001 — user-asserted no_conflict vs an actual write-write race
    # (reduce_op tables combine overlapping writes by construction)
    for i, st in enumerate(steps):
        if st.attrs.no_conflict and st.attrs.reduce_op is None:
            pair = find_conflict(st.msgs)
            if pair is not None:
                diags.append(Diagnostic(
                    "LPF001", ERROR, i,
                    "table asserted no_conflict but two messages write "
                    f"overlapping destination ranges ({pair[0]} vs "
                    f"{pair[1]}) — the result depends on CRCW "
                    "arbitration order", pair[0]))

    # LPF002 — read of an undefined slot region
    undefined = set(undefined)
    if undefined:
        defined = {}        # (pid, sid) -> merged [lo, hi) interval list
        for i, st in enumerate(steps):
            for m in st.msgs:       # reads observe pre-superstep state
                if m.size > 0 and m.src_slot.sid in undefined and \
                        not _covered(defined.get((m.src, m.src_slot.sid),
                                                 ()),
                                     m.src_off, m.src_off + m.size):
                    diags.append(Diagnostic(
                        "LPF002", ERROR, i,
                        f"read of undefined region [{m.src_off}, "
                        f"{m.src_off + m.size}) of slot "
                        f"{m.src_slot.name}#{m.src_slot.sid} on pid "
                        f"{m.src}", m))
            for m in st.msgs:       # then the superstep's writes land
                if m.size > 0 and m.dst_slot.sid in undefined:
                    key = (m.dst, m.dst_slot.sid)
                    defined[key] = _merge_intervals(
                        list(defined.get(key, []))
                        + [[m.dst_off, m.dst_off + m.size]])

    # LPF003 — slot lifetime vs the trace
    events = sorted(events, key=lambda e: e[0])
    if events:
        by_step: dict = {}
        for (estep, kind, sid) in events:
            by_step.setdefault(estep, []).append((kind, sid))
        dereg_at: dict = {}         # sid -> step it was deregistered before
        live_regs: set = set()      # registered during the trace, not freed
        for i in range(len(steps) + 1):
            for kind, sid in by_step.get(i, ()):
                if kind == "register":
                    dereg_at.pop(sid, None)
                    live_regs.add(sid)
                else:
                    dereg_at[sid] = i
                    live_regs.discard(sid)
            if i == len(steps):
                break
            for m in steps[i].msgs:
                for slot, role in ((m.src_slot, "source"),
                                   (m.dst_slot, "destination")):
                    if slot.sid in dereg_at:
                        diags.append(Diagnostic(
                            "LPF003", ERROR, i,
                            f"{role} slot {slot.name}#{slot.sid} was "
                            f"deregistered before step "
                            f"{dereg_at[slot.sid]} (use after "
                            "deregister)", m))
        for sid in sorted(live_regs):
            diags.append(Diagnostic(
                "LPF003", WARNING, -1,
                f"slot #{sid} registered during the recording is never "
                "deregistered (leaks across the recording)"))

    # LPF005 — overlapping shifted self-message (memmove-style aliasing)
    for i, st in enumerate(steps):
        for m in st.msgs:
            if (m.src == m.dst and m.src_slot.sid == m.dst_slot.sid
                    and m.size > 0 and m.src_off != m.dst_off
                    and _overlaps(m.src_off, m.size, m.dst_off, m.size)):
                diags.append(Diagnostic(
                    "LPF005", WARNING, i,
                    "self-message source and destination ranges overlap "
                    "but are shifted — the copy aliases itself", m))

    # LPF006 — dead transfers in the raw trace
    if check_dead:
        tables = [list(st.msgs) for st in steps]
        for (i, m, j) in _dead_transfers(tables,
                                         [st.attrs for st in steps]):
            diags.append(Diagnostic(
                "LPF006", WARNING, i,
                f"dead transfer: destination range fully overwritten by "
                f"step[{j}] before any read", m))

    diags.sort(key=lambda d: (d.step if d.step >= 0 else len(steps),
                              d.code))
    return diags


def lint_program(prog: SuperstepProgram, steps: Sequence[ProgramStep],
                 order: Optional[Sequence[int]] = None
                 ) -> List[Diagnostic]:
    """LPF006 over the *optimized* schedule: dead transfers that
    survived optimization (the cost gate refused the kill, or the
    overwrite needed a union of writes the single-message eliminator
    cannot see).  ``steps`` is the recorded trace the program was built
    from (or any trace with the same signature)."""
    steps = list(steps)
    if order is None:
        order = canonical_order(steps) if prog.canonical \
            else list(range(len(steps)))
    entries = prog.materialize(steps, order=order)
    tables = [e[0] for e in entries]
    attrs_list = [e[1] for e in entries]
    return [Diagnostic(
                "LPF006", WARNING, i,
                f"dead transfer survives optimization: destination range "
                f"fully overwritten by scheduled step[{j}] before any "
                "read", m)
            for (i, m, j) in _dead_transfers(tables, attrs_list)]
