"""Schedule verifier: a machine-checked legality certificate for every
optimized :class:`repro.core.SuperstepProgram`.

Given the recorded trace and the :class:`repro.core.OptimizedStep`
schedule the optimizer emitted for it, :func:`verify_program`
*independently re-derives* the must-precede conflict DAG of the
surviving transfers and certifies, without executing anything:

==========  ==========================================================
``LPF101``  schedule structure: ``merged_from`` ranks partition the
            recorded trace, overlap groups are consecutive ranges,
            canonical slot indices resolve against the trace
``LPF102``  the issue order is a legal topological order of the
            must-precede DAG (conflicting recorded supersteps keep
            their staged relative order)
``LPF103``  every merged superstep's members commute under the merge
            contract: no member reads an earlier member's write (RAW),
            no cross-member destination overlap (WAW), the member's
            CRCW slot-pair application order is preserved, and attrs
            are unchanged unless a rewrite is declared
``LPF104``  every overlap group satisfies the ``_can_overlap``
            contract: members pairwise commute (no RAW either way, no
            WAW) and every member's planned method is overlappable
``LPF105``  every Valiant rewrite sits on a ``conflict_free`` table,
            has a scratch slot, and rewrote only valiant-eligible
            members (no reduce/compress, method auto|direct)
``LPF106``  cost compliance: every cached plan equals a freshly
            planned one (method + cost), and ``ledger_costs`` entries
            equal the plans' predicted costs (``overlap_cost`` for
            groups) — what execution will ledger is what the model
            predicts
``LPF107``  transfer survival: every recorded transfer is either
            carried (possibly coalesced) by its scheduled superstep or
            provably dead, and no scheduled transfer moves bytes the
            recording never staged
==========  ==========================================================

All verifier diagnostics are error severity; ``ok`` means zero.  The
hazard predicates are re-implemented locally (three-liners) rather than
imported from the optimizer, so the certificate does not inherit the
optimizer's bugs.  Known limitation: multiplicity of *overlapping*
``reduce_op`` contributions is not tracked (the range-coverage survival
check is count-blind); the differential oracle covers that axis.

The certificate is cheap (pure Python, one fresh plan per scheduled
superstep) and is memoized per :class:`repro.core.ProgramCache` entry
by :meth:`~repro.core.ProgramCache.certify`; compiled XLA artifacts are
only cached for certified keys.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..core.cost import overlap_cost
from ..core.errors import LPFFatalError
from ..core.program import (ProgramStep, SuperstepProgram, canonical_order,
                            trace_slot_map)
from ..core.sync import (Msg, OVERLAPPABLE_METHODS, find_conflict,
                         plan_sync)
from .linter import (Diagnostic, ERROR, _covered, _dead_transfers,
                     _merge_intervals, _reads, _waw)

__all__ = ["VerifierReport", "verify_program"]


@dataclasses.dataclass(frozen=True)
class VerifierReport:
    """The checkable certificate: ``ok`` iff zero diagnostics."""

    ok: bool
    n_steps: int
    n_groups: int
    n_rewrites: int
    diagnostics: Tuple[Diagnostic, ...] = ()

    def summary(self) -> str:
        if self.ok:
            return (f"verified: {self.n_steps} steps, {self.n_groups} "
                    f"groups, {self.n_rewrites} rewrites, 0 diagnostics")
        codes = ",".join(sorted({d.code for d in self.diagnostics}))
        return (f"NOT verified: {len(self.diagnostics)} diagnostics "
                f"({codes})")


def _conflict_witness(ta: Sequence[Msg], tb: Sequence[Msg]
                      ) -> Optional[Tuple[Msg, Msg]]:
    """First non-commuting pair across two tables: a RAW in either
    direction or a destination overlap (WAW)."""
    for ma in ta:
        for mb in tb:
            if _reads(mb, ma) or _reads(ma, mb) or _waw(ma, mb):
                return (ma, mb)
    return None


def _slot_pair_order(msgs: Sequence[Msg]) -> List[Tuple[int, int]]:
    """Slot-pair groups in first-occurrence order — the cross-group
    CRCW application order of the direct executor."""
    seen: List[Tuple[int, int]] = []
    for m in msgs:
        k = (m.src_slot.sid, m.dst_slot.sid)
        if k not in seen:
            seen.append(k)
    return seen


def _same_route(a: Msg, b: Msg) -> bool:
    return (a.src == b.src and a.dst == b.dst
            and a.src_slot.sid == b.src_slot.sid
            and a.dst_slot.sid == b.dst_slot.sid
            and a.origin == b.origin)


def _covering(r: Msg, table: Sequence[Msg]) -> Optional[Msg]:
    """The scheduled message carrying recorded transfer ``r``: same
    route, same src->dst shift (coalescing is contiguous in both
    offsets), and ``r``'s source range inside it."""
    for m in table:
        if (_same_route(r, m)
                and m.src_off <= r.src_off
                and r.src_off + r.size <= m.src_off + m.size
                and m.dst_off - m.src_off == r.dst_off - r.src_off):
            return m
    return None


def verify_program(steps: Sequence[ProgramStep], prog: SuperstepProgram,
                   scratch=None,
                   order: Optional[Sequence[int]] = None
                   ) -> VerifierReport:
    """Certify that ``prog`` is a legal schedule of the recorded trace
    ``steps``.  ``scratch`` must be the same scratch slot the optimizer
    planned with (it parameterizes Valiant plans); ``order`` is an
    optional precomputed :func:`repro.core.canonical_order` of
    ``steps``."""
    steps = list(steps)
    diags: List[Diagnostic] = []
    seen: Set[Tuple[str, int]] = set()

    def fail(code: str, step: int, message: str,
             msg: Optional[Msg] = None) -> None:
        if (code, step) in seen:
            return              # one diagnostic per (code, anchor step)
        seen.add((code, step))
        diags.append(Diagnostic(code, ERROR, step, message, msg))

    n_groups = len(prog.groups())
    n_rewrites = sum(1 for st in prog.steps if st.rewrite)

    def report() -> VerifierReport:
        return VerifierReport(ok=not diags, n_steps=len(prog.steps),
                              n_groups=n_groups, n_rewrites=n_rewrites,
                              diagnostics=tuple(diags))

    # ---- LPF101: structure -------------------------------------------
    n_rec = len(steps)
    if prog.n_recorded != n_rec:
        fail("LPF101", -1,
             f"program records {prog.n_recorded} supersteps but the "
             f"trace has {n_rec}")
        return report()
    ranks = sorted(r for st in prog.steps for r in st.merged_from)
    if ranks != list(range(n_rec)):
        fail("LPF101", -1,
             "merged_from ranks do not partition the recorded trace")
        return report()
    groups = prog.groups()
    flat = [i for grp in groups for i in grp]
    if flat != list(range(len(prog.steps))) or any(
            tuple(grp) != tuple(range(grp[0], grp[0] + len(grp)))
            for grp in groups):
        fail("LPF101", -1, "overlap groups are not consecutive ranges "
             "partitioning the schedule")
        return report()

    if prog.canonical:
        if order is None:
            order = canonical_order(steps)
    else:
        order = list(range(n_rec))
    ordered = [steps[i] for i in order]
    slot_map = trace_slot_map(steps, order)

    mats: List[List[Msg]] = []
    for si, st in enumerate(prog.steps):
        try:
            mats.append([Msg(src, dst, slot_map[s_i], so, slot_map[d_i],
                             do, sz, origin=o)
                         for (src, dst, s_i, so, d_i, do, sz, o)
                         in st.table])
        except IndexError:
            fail("LPF101", si,
                 "canonical slot index out of range for this trace")
            return report()

    step_of: Dict[int, int] = {}
    for si, st in enumerate(prog.steps):
        for r in st.merged_from:
            step_of[r] = si
    group_of: Dict[int, int] = {}
    for gi, grp in enumerate(groups):
        for i in grp:
            group_of[i] = gi

    # ---- LPF107: transfer survival -----------------------------------
    rec_tables = [list(st.msgs) for st in ordered]
    rec_attrs = [st.attrs for st in ordered]
    dead = {(i, id(m)) for (i, m, _) in
            _dead_transfers(rec_tables, rec_attrs)}

    surv: List[List[Msg]] = [[] for _ in range(n_rec)]
    for k in range(n_rec):
        si = step_of[k]
        for r in rec_tables[k]:
            if r.size == 0:
                continue
            if _covering(r, mats[si]) is not None:
                surv[k].append(r)
            elif (k, id(r)) not in dead:
                fail("LPF107", si,
                     f"recorded transfer of canonical rank {k} was "
                     "dropped but is not provably dead", r)
    for si, st in enumerate(prog.steps):
        for m in mats[si]:
            if m.size == 0:
                continue
            pieces = [(r.src_off, r.src_off + r.size)
                      for k in st.merged_from for r in rec_tables[k]
                      if _same_route(r, m) and r.size > 0
                      and m.src_off <= r.src_off
                      and r.src_off + r.size <= m.src_off + m.size
                      and m.dst_off - m.src_off == r.dst_off - r.src_off]
            if not _covered(_merge_intervals(pieces), m.src_off,
                            m.src_off + m.size):
                fail("LPF107", si,
                     "scheduled transfer moves bytes no recorded "
                     "transfer of its members staged", m)

    # ---- LPF103 / LPF105: merge + rewrite legality -------------------
    for si, st in enumerate(prog.steps):
        mf = st.merged_from
        if st.rewrite == "":
            for k in mf:
                if ordered[k].attrs != st.attrs:
                    fail("LPF103", si,
                         f"attrs of canonical rank {k} changed without "
                         "a declared rewrite")
        elif st.rewrite == "valiant":
            if scratch is None:
                fail("LPF105", si,
                     "valiant rewrite but no scratch slot to route "
                     "phase 1 through")
            a = st.attrs
            if a.method != "valiant" or a.reduce_op is not None \
                    or a.compress is not None:
                fail("LPF105", si,
                     f"valiant rewrite carries non-valiant attrs {a}")
            for k in mf:
                ra = ordered[k].attrs
                if ra.reduce_op is not None or ra.compress is not None \
                        or ra.method not in ("auto", "direct"):
                    fail("LPF105", si,
                         f"canonical rank {k} is not valiant-eligible "
                         "(reduce/compress/explicit method) — a method "
                         "rewrite may not change its semantics")
            pair = find_conflict(mats[si])
            if pair is not None:
                fail("LPF105", si,
                     "valiant rewrite on a table that is not "
                     "conflict_free — two-phase routing would arbitrate "
                     "CRCW winners in intermediate-pid order", pair[0])
        else:
            fail("LPF105", si, f"unknown rewrite {st.rewrite!r}")
        if len(mf) > 1:
            for q in range(1, len(mf)):
                earlier = [m for k in mf[:q] for m in surv[k]]
                later = surv[mf[q]]
                for m2 in later:
                    raw = next((m1 for m1 in earlier if _reads(m2, m1)),
                               None)
                    if raw is not None:
                        fail("LPF103", si,
                             "merged member reads an earlier member's "
                             "write (RAW) — merged reads observe "
                             "pre-superstep state", m2)
                    if st.rewrite == "":
                        waw = next((m1 for m1 in earlier
                                    if _waw(m1, m2)), None)
                        if waw is not None:
                            fail("LPF103", si,
                                 "merged members write overlapping "
                                 "destination ranges (WAW) — merging "
                                 "re-arbitrates the winner", m2)
                if st.rewrite == "" and st.attrs.reduce_op is None:
                    later_groups = set(_slot_pair_order(later))
                    merged = [g for g in
                              _slot_pair_order(earlier + list(later))
                              if g in later_groups]
                    if merged != _slot_pair_order(later):
                        fail("LPF103", si,
                             "merge reorders the member's CRCW "
                             "slot-pair application order")

    # ---- LPF104: overlap groups --------------------------------------
    for gi, grp in enumerate(groups):
        if len(grp) == 1:
            continue
        for i in grp:
            if prog.steps[i].plan.method not in OVERLAPPABLE_METHODS:
                fail("LPF104", i,
                     f"overlap group member planned method "
                     f"{prog.steps[i].plan.method!r} is not "
                     "overlappable")
        for ai in range(len(grp)):
            for bi in range(ai + 1, len(grp)):
                w = _conflict_witness(mats[grp[ai]], mats[grp[bi]])
                if w is not None:
                    fail("LPF104", grp[bi],
                         f"overlap group members {grp[ai]} and "
                         f"{grp[bi]} do not commute (finish order "
                         "would be observable)", w[1])

    # ---- LPF102: topological order of the must-precede DAG -----------
    reads_fp = [{(m.src, m.src_slot.sid) for m in surv[k]}
                for k in range(n_rec)]
    writes_fp = [{(m.dst, m.dst_slot.sid) for m in surv[k]}
                 for k in range(n_rec)]
    for a in range(n_rec):
        for b in range(a + 1, n_rec):
            if step_of[a] == step_of[b]:
                continue            # intra-merge: LPF103's domain
            if group_of[step_of[a]] == group_of[step_of[b]]:
                continue            # intra-group: LPF104's domain
            if not ((writes_fp[a] & reads_fp[b])
                    or (writes_fp[b] & reads_fp[a])
                    or (writes_fp[a] & writes_fp[b])):
                continue
            w = _conflict_witness(surv[a], surv[b])
            if w is None:
                continue
            if group_of[step_of[a]] > group_of[step_of[b]]:
                fail("LPF102", step_of[b],
                     f"canonical rank {a} must precede rank {b} (they "
                     "conflict) but the schedule issues it later — not "
                     "a topological order of the must-precede DAG",
                     w[0])

    # ---- LPF106: cost compliance -------------------------------------
    fresh_costs = []
    for si, st in enumerate(prog.steps):
        try:
            fresh = plan_sync(mats[si], prog.p, st.attrs, scratch)
        except LPFFatalError as e:
            fail("LPF106", si,
                 f"re-planning the scheduled table failed: {e}")
            fresh_costs.append(None)
            continue
        if fresh.method != st.plan.method or fresh.cost != st.plan.cost:
            fail("LPF106", si,
                 f"cached plan (method {st.plan.method!r}, "
                 f"{st.plan.cost}) diverges from a fresh plan (method "
                 f"{fresh.method!r}, {fresh.cost})")
        fresh_costs.append(fresh.cost)
    if all(c is not None for c in fresh_costs):
        ledger = prog.ledger_costs()
        for gi, grp in enumerate(groups):
            exp = fresh_costs[grp[0]] if len(grp) == 1 else \
                overlap_cost([fresh_costs[i] for i in grp])
            got = dataclasses.replace(ledger[gi], label="")
            if got != dataclasses.replace(exp, label=""):
                fail("LPF106", grp[0],
                     f"ledger entry of issue group {gi} does not equal "
                     "the plans' predicted cost")

    return report()
