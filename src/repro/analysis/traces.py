"""Canned LPF traces — the communication shapes of the paper's target
workloads, as recorded ``ProgramStep`` lists.

Shared by ``benchmarks/schedule_search.py`` (which prices their
searched schedules against the DCN machine model and guards
``GUARD_BOUNDS_US``) and by the ``python -m repro.analysis`` CLI (which
lints them and verifies their optimized schedules nightly).  Every
builder returns ``(p, slots, steps, scratch)``; slots are synthetic
handles (generation 0) that never enter a :class:`SlotRegistry`.
"""

from __future__ import annotations

import numpy as np

from ..core import LPF_SYNC_DEFAULT, Msg, ProgramStep, Slot, SyncAttributes

__all__ = ["CANNED_TRACES", "canned_fft_trace", "canned_bucketed_trace",
           "canned_fragmented_trace", "canned_pagerank_trace"]


def _slot(sid, size, dtype="int32"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind="global", orig_shape=(size,))


def canned_fft_trace(p: int = 8, w: int = 64):
    """Two interleaved FFT instances: redistribute + reorder each, the
    reorder reading its own redistribute's destination slot."""
    steps = []
    slots = []
    for inst in ("A", "B"):
        src = _slot(len(slots) + 100, p * w)
        buf = _slot(len(slots) + 101, p * w)
        out = _slot(len(slots) + 102, p * w)
        slots += [src, buf, out]
        redist = tuple(Msg(s, d, src, d * w, buf, s * w, w)
                       for s in range(p) for d in range(p))
        reorder = tuple(Msg(s, d, buf, d * w, out, s * w, w)
                        for s in range(p) for d in range(p))
        steps.append(ProgramStep(redist, LPF_SYNC_DEFAULT,
                                 f"fft{inst}.redistribute"))
        steps.append(ProgramStep(reorder, LPF_SYNC_DEFAULT,
                                 f"fft{inst}.reorder"))
    return p, slots, steps, None


def canned_bucketed_trace(p: int = 8, n_buckets: int = 4, w: int = 64):
    """The DDP bucket shape: per bucket a fused reduce-scatter into a
    chunk slot, then a fused all-gather of the chunks."""
    steps = []
    slots = []
    sid = 200
    for k in range(n_buckets):
        src = _slot(sid, p * w)
        buf = _slot(sid + 1, w)
        out = _slot(sid + 2, p * w)
        sid += 3
        slots += [src, buf, out]
        rs = tuple(Msg(s, d, src, d * w, buf, 0, w)
                   for s in range(p) for d in range(p))
        ag = tuple(Msg(s, d, buf, 0, out, s * w, w)
                   for s in range(p) for d in range(p))
        steps.append(ProgramStep(rs, SyncAttributes(reduce_op="sum"),
                                 f"b{k}.rs"))
        steps.append(ProgramStep(ag, LPF_SYNC_DEFAULT, f"b{k}.ag"))
    return p, slots, steps, None


def canned_fragmented_trace(p: int = 8):
    """Two supersteps spread over 4x4 slot pairs, one message per pair:
    direct pays one coloured round per pair (16 rounds each).  frag2
    writes exactly the ranges frag1 *reads* (WAR): commutation fails,
    so split-phase overlap is inadmissible — and the Valiant-aware
    rewrite routes each fat superstep two-phase instead (the cost gate
    declines the *merged* valiant table: 32 messages through p=8
    intermediates double the via-collisions), consolidating 2x16
    coloured rounds to 14+12 through the scratch slot."""
    A = [_slot(300 + i, 32) for i in range(4)]
    B = [_slot(310 + i, 32) for i in range(4)]
    C = [_slot(320 + i, 32) for i in range(4)]
    scratch = _slot(399, 4096)
    msgs1, msgs2 = [], []
    for ai in range(4):
        for bi in range(4):
            k = 4 * ai + bi
            m1 = Msg((k * 3) % p, (k * 5 + 1) % p, A[ai], 8 * bi,
                     B[bi], (k * 3) % 16, 4)
            msgs1.append(m1)
            # the mirror: write the exact range m1 reads, on m1's pid
            msgs2.append(Msg((k * 7 + 2) % p, m1.src, C[bi], 8 * ai,
                             A[ai], 8 * bi, 4))
    steps = [ProgramStep(tuple(msgs1), LPF_SYNC_DEFAULT, "frag1"),
             ProgramStep(tuple(msgs2), LPF_SYNC_DEFAULT, "frag2")]
    return p, A + B + C, steps, scratch


def canned_pagerank_trace(p: int = 8, w: int = 8):
    """The PageRank iteration shape: an irregular halo permutation, an
    accumulating reduction of a 3-word stats vector to pid 0, and its
    broadcast back."""
    rank = _slot(300, p * w)
    halo = _slot(301, w)
    stats = _slot(302, 3)
    tot = _slot(303, 3)
    halo_msgs = tuple(Msg(s, (s * 3 + 1) % p, rank, (s % 4) * w, halo, 0, w)
                      for s in range(p))
    red = tuple(Msg(s, 0, stats, 0, tot, 0, 3) for s in range(p))
    bcast = tuple(Msg(0, d, tot, 0, tot, 0, 3) for d in range(1, p))
    steps = [ProgramStep(halo_msgs, LPF_SYNC_DEFAULT, "pr.halo"),
             ProgramStep(red, SyncAttributes(reduce_op="sum"), "pr.red"),
             ProgramStep(bcast, LPF_SYNC_DEFAULT, "pr.bcast")]
    return p, [rank, halo, stats, tot], steps, None


CANNED_TRACES = {
    "fft_redistribute": canned_fft_trace,
    "bucketed_sync8": canned_bucketed_trace,
    "fragmented_valiant": canned_fragmented_trace,
    "pagerank": canned_pagerank_trace,
}
