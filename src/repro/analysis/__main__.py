"""``python -m repro.analysis`` — lint and verify LPF traces from the
command line.

With no arguments, lints every canned trace (FFT redistribute, bucketed
gradient sync, fragmented Valiant relation, PageRank iteration),
optimizes each against the DCN machine model, re-lints the optimized
program, and verifies the schedule certificate.  Pass canned-trace
names to restrict the set, or ``--pickle path`` for recorded traces
saved with :mod:`pickle` (a ``[ProgramStep, ...]`` list, a
``(p, steps)`` pair, or a ``(p, slots, steps, scratch)`` tuple).

Exit status is 1 iff any error-severity diagnostic fired or a schedule
failed verification — warnings alone exit 0.  The nightly CI job runs
this over all canned traces.
"""

from __future__ import annotations

import argparse
import pickle
import sys
from typing import List, Optional, Tuple

from ..core import ProgramStep, optimize_program
from ..core.machine import TPU_V5E, probe
from .linter import ERROR, Diagnostic, lint_program, lint_trace
from .traces import CANNED_TRACES
from .verifier import verify_program

#: the machine model traces are optimized against (matches
#: ``benchmarks/schedule_search.py``)
DCN = probe({"pod": 8}, TPU_V5E)


def _load_pickle(path: str) -> Tuple[int, List[ProgramStep], Optional[object]]:
    with open(path, "rb") as fh:
        obj = pickle.load(fh)
    if isinstance(obj, (list, tuple)) and obj and \
            all(isinstance(s, ProgramStep) for s in obj):
        steps = list(obj)
        p = 1 + max((max(m.src, m.dst) for st in steps for m in st.msgs),
                    default=0)
        return p, steps, None
    if isinstance(obj, tuple) and len(obj) == 2:
        p, steps = obj
        return int(p), list(steps), None
    if isinstance(obj, tuple) and len(obj) == 4:
        p, _slots, steps, scratch = obj
        return int(p), list(steps), scratch
    raise SystemExit(
        f"{path}: expected a [ProgramStep, ...] list, a (p, steps) pair, "
        f"or a (p, slots, steps, scratch) tuple; got {type(obj).__name__}")


def _analyze(name: str, p: int, steps: List[ProgramStep],
             scratch) -> Tuple[List[Diagnostic], bool]:
    diags = list(lint_trace(steps, p, check_dead=True))
    prog = optimize_program(steps, p, DCN, scratch=scratch)
    diags += lint_program(prog, steps)
    report = verify_program(steps, prog, scratch=scratch)
    diags += report.diagnostics
    print(f"== {name}: {len(steps)} recorded supersteps, p={p}")
    for d in diags:
        print(f"   {d}")
    print(f"   {report.summary()}")
    return diags, report.ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lint and verify LPF program traces")
    ap.add_argument("traces", nargs="*", choices=[[], *CANNED_TRACES],
                    help="canned traces to analyze (default: all)")
    ap.add_argument("--pickle", action="append", default=[],
                    metavar="PATH", help="pickled recorded trace(s)")
    args = ap.parse_args(argv)

    jobs = []
    for name in (args.traces or sorted(CANNED_TRACES)):
        jobs.append((name, *CANNED_TRACES[name]()))
    for path in args.pickle:
        p, steps, scratch = _load_pickle(path)
        jobs.append((path, p, None, steps, scratch))

    bad = False
    for name, p, _slots, steps, scratch in jobs:
        diags, ok = _analyze(name, p, steps, scratch)
        bad |= (not ok) or any(d.severity == ERROR for d in diags)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
