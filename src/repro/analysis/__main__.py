"""``python -m repro.analysis`` — lint and verify LPF traces from the
command line.

With no arguments, lints every canned trace (FFT redistribute, bucketed
gradient sync, fragmented Valiant relation, PageRank iteration),
optimizes each against the DCN machine model, re-lints the optimized
program, and verifies the schedule certificate.  Pass canned-trace
names to restrict the set, or ``--pickle path`` for recorded traces
saved with :mod:`pickle` (a ``[ProgramStep, ...]`` list, a
``(p, steps)`` pair, or a ``(p, slots, steps, scratch)`` tuple).

Persistent program caches (``LPF_PROGRAM_CACHE_DIR``):

* ``--record-cache DIR`` optimizes + certifies every selected canned
  trace into the persistent cache at ``DIR`` (the nightly recorder).
* ``--cache-dir DIR`` audits an existing cache: every entry is decoded,
  its recorded trace reconstructed from the persisted canonical
  signature, and the program re-verified offline — exactly the
  certificate check a warm-starting context would run.
* ``--dump-costs PATH`` (with either of the above) writes each entry's
  predicted schedule cost as JSON; ``--diff-costs BASELINE`` compares
  such a dump against a committed baseline and fails on missing entries
  or predicted-cost regressions beyond 1%.

Exit status is 1 iff any error-severity diagnostic fired, a schedule
failed verification, a cache entry failed to load or re-verify, or the
cost diff regressed — warnings alone exit 0.  The nightly CI job runs
this over all canned traces and over the cache it just recorded.
"""

from __future__ import annotations

import argparse
import json
import pickle
import sys
from typing import List, Optional, Tuple

from ..core import ProgramStep, optimize_program
from ..core.cost import schedule_seconds
from ..core.machine import LPFMachine, TPU_V5E, probe
from ..core.persist import PersistentStore, steps_from_signature
from ..core.program import ProgramCache, SuperstepProgram
from .linter import ERROR, Diagnostic, lint_program, lint_trace
from .traces import CANNED_TRACES
from .verifier import verify_program

#: tolerated relative growth in an entry's predicted schedule seconds
#: before ``--diff-costs`` fails the build
COST_REGRESSION_TOL = 0.01

#: the machine model traces are optimized against (matches
#: ``benchmarks/schedule_search.py``)
DCN = probe({"pod": 8}, TPU_V5E)


def _load_pickle(path: str) -> Tuple[int, List[ProgramStep], Optional[object]]:
    with open(path, "rb") as fh:
        obj = pickle.load(fh)
    if isinstance(obj, (list, tuple)) and obj and \
            all(isinstance(s, ProgramStep) for s in obj):
        steps = list(obj)
        p = 1 + max((max(m.src, m.dst) for st in steps for m in st.msgs),
                    default=0)
        return p, steps, None
    if isinstance(obj, tuple) and len(obj) == 2:
        p, steps = obj
        return int(p), list(steps), None
    if isinstance(obj, tuple) and len(obj) == 4:
        p, _slots, steps, scratch = obj
        return int(p), list(steps), scratch
    raise SystemExit(
        f"{path}: expected a [ProgramStep, ...] list, a (p, steps) pair, "
        f"or a (p, slots, steps, scratch) tuple; got {type(obj).__name__}")


def _analyze(name: str, p: int, steps: List[ProgramStep],
             scratch) -> Tuple[List[Diagnostic], bool]:
    diags = list(lint_trace(steps, p, check_dead=True))
    prog = optimize_program(steps, p, DCN, scratch=scratch)
    diags += lint_program(prog, steps)
    report = verify_program(steps, prog, scratch=scratch)
    diags += report.diagnostics
    print(f"== {name}: {len(steps)} recorded supersteps, p={p}")
    for d in diags:
        print(f"   {d}")
    print(f"   {report.summary()}")
    return diags, report.ok


def _entry_costs(prog: SuperstepProgram, machine: LPFMachine) -> dict:
    """Cost summary of one persisted program — the quantity the nightly
    predicted-cost diff gates on."""
    plans = [st.plan for st in prog.steps]
    groups = [[plans[i].cost for i in grp] for grp in prog.groups()]
    return {
        "n_steps": len(prog.steps),
        "rounds": sum(c.rounds for c in (pl.cost for pl in plans)),
        "wire_bytes": sum(pl.cost.wire_bytes for pl in plans),
        "predicted_us": schedule_seconds(groups, machine) * 1e6,
    }


def _record_cache(directory: str, names: List[str]) -> Tuple[int, dict]:
    """``--record-cache``: optimize + certify the canned traces into the
    persistent store at ``directory``.  Returns (n_bad, costs)."""
    cache = ProgramCache(persist_dir=directory)
    bad, costs = 0, {}
    for name in names:
        p, _slots, steps, scratch = CANNED_TRACES[name]()
        prog, key = cache.get_or_build_keyed(steps, p, DCN, scratch=scratch)
        cert = cache.certify(key, steps, prog, scratch=scratch)
        from ..core.persist import entry_filename
        fname = entry_filename(key)
        print(f"== {name}: recorded {fname}  ({cert.summary()})")
        if not cert.ok:
            bad += 1
            continue
        costs[fname] = {"label": name, **_entry_costs(prog, DCN)}
    return bad, costs


def _audit_cache(directory: str) -> Tuple[int, dict]:
    """``--cache-dir``: decode, reconstruct, and re-verify every entry of
    a persisted cache.  Returns (n_bad, costs)."""
    store = PersistentStore(directory)
    bad, costs, n = 0, {}, 0
    for fname, err, key, prog, cert in store.entries():
        n += 1
        if err is not None:
            print(f"== {fname}: INVALID — {err}")
            bad += 1
            continue
        sig, g, l = key
        p = sig[0]
        machine = LPFMachine(p=p, g=g, l=l, r=DCN.r)
        try:
            p2, steps, scratch = steps_from_signature(sig)
            report = verify_program(steps, prog, scratch=scratch,
                                    order=list(range(len(steps))))
        except Exception as exc:          # noqa: BLE001 — audit must not die
            print(f"== {fname}: INVALID — re-verification raised {exc!r}")
            bad += 1
            continue
        print(f"== {fname}: p={p}  {report.summary()}")
        if not report.ok:
            bad += 1
            continue
        costs[fname] = _entry_costs(prog, machine)
    print(f"cache audit: {n} entries, {n - bad} verified, {bad} bad")
    return bad, costs


def _diff_costs(costs: dict, baseline_path: str) -> int:
    """``--diff-costs``: fail on entries missing from the current dump or
    whose predicted time regressed beyond ``COST_REGRESSION_TOL``."""
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    bad = 0
    for fname, base in sorted(baseline.items()):
        cur = costs.get(fname)
        label = base.get("label", fname)
        if cur is None:
            print(f"costs: {label}: MISSING from current cache")
            bad += 1
            continue
        b, c = base["predicted_us"], cur["predicted_us"]
        rel = (c - b) / b if b else 0.0
        verdict = "REGRESSED" if rel > COST_REGRESSION_TOL else "ok"
        print(f"costs: {label}: {b:.3f}us -> {c:.3f}us ({rel:+.2%}) "
              f"{verdict}")
        bad += verdict == "REGRESSED"
    for fname in sorted(set(costs) - set(baseline)):
        print(f"costs: {fname}: new entry (not in baseline)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lint and verify LPF program traces")
    ap.add_argument("traces", nargs="*", choices=[[], *CANNED_TRACES],
                    help="canned traces to analyze (default: all)")
    ap.add_argument("--pickle", action="append", default=[],
                    metavar="PATH", help="pickled recorded trace(s)")
    ap.add_argument("--record-cache", metavar="DIR",
                    help="record the selected canned traces into a "
                         "persistent program cache")
    ap.add_argument("--cache-dir", metavar="DIR",
                    help="audit a persisted program cache: decode, "
                         "reconstruct, and re-verify every entry")
    ap.add_argument("--dump-costs", metavar="PATH",
                    help="write per-entry predicted costs as JSON "
                         "(with --record-cache or --cache-dir)")
    ap.add_argument("--diff-costs", metavar="BASELINE",
                    help="compare the per-entry costs against a baseline "
                         "dump; fail on >1%% regressions or missing keys")
    args = ap.parse_args(argv)

    if args.cache_dir or args.record_cache:
        names = list(args.traces or sorted(CANNED_TRACES))
        nbad, costs = 0, {}
        if args.record_cache:
            b, costs = _record_cache(args.record_cache, names)
            nbad += b
        if args.cache_dir:
            b, audit_costs = _audit_cache(args.cache_dir)
            nbad += b
            # audit costs win: they price what is actually on disk, but
            # keep the recorder's trace labels when both modes ran
            for fname, c in audit_costs.items():
                label = costs.get(fname, {}).get("label")
                costs[fname] = {"label": label, **c} if label else c
        if args.dump_costs:
            with open(args.dump_costs, "w") as fh:
                json.dump(costs, fh, indent=2, sort_keys=True)
            print(f"costs: wrote {len(costs)} entries to {args.dump_costs}")
        if args.diff_costs:
            nbad += _diff_costs(costs, args.diff_costs)
        return 1 if nbad else 0

    if args.diff_costs or args.dump_costs:
        ap.error("--dump-costs/--diff-costs require --record-cache "
                 "or --cache-dir")

    jobs = []
    for name in (args.traces or sorted(CANNED_TRACES)):
        jobs.append((name, *CANNED_TRACES[name]()))
    for path in args.pickle:
        p, steps, scratch = _load_pickle(path)
        jobs.append((path, p, None, steps, scratch))

    bad = False
    for name, p, _slots, steps, scratch in jobs:
        diags, ok = _analyze(name, p, steps, scratch)
        bad |= (not ok) or any(d.severity == ERROR for d in diags)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
