"""repro.analysis — static race detection, schedule verification, and
sanitizer support for the LPF program IR.

The paper's model-compliance stance is that every primitive has strict,
checkable semantics.  The numpy differential oracle and the ledger tests
enforce those semantics *dynamically*, after execution; this package
proves the optimizer's legality invariants *statically*, on the IR:

* :mod:`repro.analysis.linter` — race/hazard lint over recorded traces
  with stable diagnostic codes LPF001–LPF006;
* :mod:`repro.analysis.verifier` — an independent re-derivation of the
  must-precede conflict DAG that certifies an optimized schedule
  (topological order, commuting merges, overlap contracts, Valiant
  rewrites on conflict-free tables, cost compliance) — the certificate
  :meth:`repro.core.ProgramCache.certify` attaches to every cache entry
  and :meth:`~repro.core.ProgramCache.set_compiled` requires;
* :mod:`repro.analysis.traces` — the canned benchmark traces, shared
  with ``benchmarks/schedule_search.py``;
* ``python -m repro.analysis`` — the CLI (see ``__main__.py``).

Sanitizer mode (``LPF_SANITIZE=1`` or ``LPFContext(sanitize=True)``)
runs the linter on every recorded trace at flush time: error
diagnostics raise :class:`repro.core.LPFAnalysisError`, warnings
accumulate on ``ctx.diagnostics``.
"""

from .linter import Diagnostic, ERROR, WARNING, lint_program, lint_trace
from .verifier import VerifierReport, verify_program
from .traces import (CANNED_TRACES, canned_bucketed_trace,
                     canned_fft_trace, canned_fragmented_trace,
                     canned_pagerank_trace)

__all__ = [
    "Diagnostic", "ERROR", "WARNING", "lint_trace", "lint_program",
    "VerifierReport", "verify_program",
    "CANNED_TRACES", "canned_fft_trace", "canned_bucketed_trace",
    "canned_fragmented_trace", "canned_pagerank_trace",
]
