import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step).lower(...).compile()`` runs the full XLA SPMD partitioner
for the production mesh; sharding mismatches, unsupported collectives and
compile-time OOMs all surface here.  The compiled artifact yields

  * ``memory_analysis()``  — per-device bytes (does it fit 16 GB HBM?),
  * ``cost_analysis()``    — FLOPs / bytes for the §Roofline terms,
  * the HLO text          — collective bytes via ``parse_collectives``.

One cell per invocation (isolation against compile OOM); ``--all`` runs
the whole matrix through subprocesses of this same module.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _attach(sds_tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shardings)


def _lower_cell(cfg, cell, mesh, batch_sds, overrides):
    """Build the right step for the cell kind and return its `lowered`."""
    from repro.models import Runtime, init_caches, init_params, prefill
    from repro.runtime.train_step import build_serve_step, build_train_step
    from repro.sharding.rules import batch_specs, param_specs
    from jax.sharding import NamedSharding, PartitionSpec as P

    def shard_tree(specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    if cell.kind == "train":
        from repro.optim import adamw_init
        accum = overrides.get("grad_accum")
        if accum is None:
            # wide configs need microbatching to fit 16 GB/chip
            accum = 8 if cfg.d_model >= 7168 else \
                (4 if cfg.d_model >= 3584 else 1)
        from repro.optim import AdamWConfig
        opt_cfg = AdamWConfig()
        if cfg.param_dtype == "bfloat16":
            # 671B memory policy (DESIGN §7): bf16 moments, as the model's
            # own training recipe uses low-precision optimizer state
            opt_cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
        ts = build_train_step(cfg, mesh, grad_sync=overrides.get(
            "grad_sync", "gspmd"), grad_accum=accum, opt_cfg=opt_cfg,
            axis_roles=overrides.get("axis_roles", "fsdp_tp"))
        p_sds = jax.eval_shape(partial(init_params, cfg=cfg),
                               jax.random.PRNGKey(0))
        o_sds = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_sds)
        b_sds = _attach(batch_sds, shard_tree(batch_specs(batch_sds, mesh)))
        return ts.step_fn.lower(p_sds, o_sds, b_sds)
    if cell.kind == "prefill":
        from repro.launch.mesh import dp_axes_of, model_axis_of
        rt = Runtime(mesh, dp_axes=dp_axes_of(mesh),
                     model_axis=model_axis_of(mesh), sp=True)
        p_sds = jax.eval_shape(partial(init_params, cfg=cfg),
                               jax.random.PRNGKey(0))
        p_shard = shard_tree(param_specs(p_sds, mesh))
        b_sds = _attach(batch_sds, shard_tree(batch_specs(batch_sds, mesh)))
        fn = jax.jit(lambda p, b: prefill(p, b, cfg, rt),
                     in_shardings=(p_shard, None))
        return fn.lower(p_sds, b_sds)
    # decode — serving holds parameters in bf16 (inference checkpoints);
    # serve_layout=tp_only replicates weights over `data` (no per-token
    # FSDP gathers); serve_quant=int8 stores weights int8-at-rest
    layout = overrides.get("serve_layout")
    ss = build_serve_step(cfg, mesh, global_batch=cell.global_batch,
                          cache_len=cell.seq_len,
                          param_axes=("model",) if layout == "tp_only"
                          else None)
    p_sds = jax.eval_shape(partial(init_params, cfg=cfg),
                           jax.random.PRNGKey(0))
    wdt = jnp.int8 if overrides.get("serve_quant") == "int8" else jnp.bfloat16
    p_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, wdt)
        if l.dtype == jnp.float32 and len(l.shape) > 1 else l, p_sds)
    c_sds = jax.eval_shape(
        lambda: init_caches(cfg, cell.global_batch, cell.seq_len))
    args = [p_sds, c_sds, batch_sds["token"], batch_sds["pos"]]
    if cfg.encoder_groups:
        args.append(batch_sds["enc_out"])
    return ss.step_fn.lower(*args)


def _measure(compiled, loop_aware: bool = False):
    """flops/bytes from cost_analysis (loop bodies counted ONCE — callers
    extrapolate); collectives + traffic from the HLO census, loop-aware
    for the main scanned compile (exact trip-count multipliers)."""
    from repro.core.hlo_analysis import loop_aware_census, parse_collectives
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    if loop_aware:
        colls, traffic = loop_aware_census(text)
    else:
        colls = parse_collectives(text)
        traffic = 0.0
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "traffic": float(traffic),
        "coll": float(colls.total_bytes),
        "coll_by_kind": dict(colls.bytes_by_kind),
        "coll_counts": dict(colls.count_by_kind),
    }


def _with_repeats(cfg, reps: dict):
    """cfg with each group's repeat count overridden ({name: n})."""
    import dataclasses as dc
    g2 = tuple(dc.replace(g, repeats=reps.get(g.name, g.repeats))
               for g in cfg.groups)
    e2 = tuple(dc.replace(g, repeats=reps.get(g.name, g.repeats))
               for g in cfg.encoder_groups)
    return dc.replace(cfg, groups=g2, encoder_groups=e2)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: dict) -> dict:
    from repro.configs import SHAPES, applicable, get_config, input_specs
    from repro.models import count_params
    from repro.launch.mesh import make_production_mesh

    ok, why = applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch, ep_degree=mesh.shape["model"])
    import dataclasses as dc
    for k, v in overrides.items():
        if k in {f.name for f in dc.fields(cfg)}:
            cfg = dc.replace(cfg, **{k: v})
    cell = SHAPES[shape_name]
    batch_sds = input_specs(cfg, shape_name)

    n_total = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    if cell.kind == "train":
        model_flops = 6.0 * n_active * cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        model_flops = 2.0 * n_active * cell.global_batch * cell.seq_len
    else:
        model_flops = 2.0 * n_active * cell.global_batch

    lowered = _lower_cell(cfg, cell, mesh, batch_sds, overrides)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1
    mem = compiled.memory_analysis()
    main = _measure(compiled, loop_aware=True)

    # --- scan-body extrapolation -----------------------------------------
    # XLA's cost analysis counts a while-loop body ONCE (verified
    # empirically), so scanned layer groups are undercounted.  Calibrate
    # with *unrolled* variants: all groups at repeats=1 (baseline c0),
    # then one group at a time bumped to repeats=2; the delta is that
    # group's per-layer cost, and the full-depth cost follows linearly:
    #   cost = c0 + sum_g (R_g - 1) * (c_g - c0).
    # Memory analysis comes from the real scanned compile (scan reuses
    # buffers, so it needs no correction).
    import dataclasses as dc
    all_groups = list(cfg.groups) + list(cfg.encoder_groups)
    multi = [g for g in all_groups if g.repeats > 1]
    extrap = dict(main)
    if multi:
        base_reps = {g.name: 1 for g in all_groups}

        def calib_measure(reps):
            ccfg = dc.replace(_with_repeats(cfg, reps), unroll_layers=True)
            ovr = dict(overrides)
            ovr["grad_accum"] = 1
            return _measure(_lower_cell(ccfg, cell, mesh, batch_sds,
                                        ovr).compile())

        c0 = calib_measure(base_reps)
        extrap["flops"] = c0["flops"]
        for g in multi:
            reps = dict(base_reps)
            reps[g.name] = 2
            c1 = calib_measure(reps)
            delta = max(c1["flops"] - c0["flops"], 0.0)
            extrap["flops"] += delta * (g.repeats - 1)

    per_device_bytes = int(mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes)
    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips,
        "params_total": n_total, "params_active": n_active,
        "model_flops": model_flops,
        "hlo_flops_raw": main["flops"],
        "hlo_flops": extrap["flops"],
        "hlo_bytes_raw": main["bytes"],
        "hlo_bytes": main["traffic"],
        "collective_bytes_raw": main["bytes"],
        "collective_bytes": main["coll"],
        "collective_counts": main["coll_counts"],
        "collective_bytes_by_kind": main["coll_by_kind"],
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_bytes": per_device_bytes,
            "fits_v5e_16g": per_device_bytes <= 16e9,
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "total_s": round(time.time() - t0, 2),
        "memory_note": ("CPU XLA legalises bf16->f32 in several passes "
                        "(verified: duplicate f32 copies of bf16 stacks); "
                        "temp_bytes overstates the TPU figure by up to 2x "
                        "on bf16 buffers."),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(fn, "w") as f:
            json.dump(art, f, indent=1)
    return art


def _print_result(art: dict):
    if art["status"] == "skipped":
        print(f"SKIP {art['arch']:<24} {art['shape']:<12} {art['mesh']:<7}"
              f" {art['reason']}")
        return
    m = art["memory"]
    print(f"OK   {art['arch']:<24} {art['shape']:<12} {art['mesh']:<7}"
          f" mem/dev={m['per_device_bytes'] / 1e9:7.2f}GB"
          f" fits={str(m['fits_v5e_16g'])[0]}"
          f" flops={art['hlo_flops']:.3e}"
          f" coll={art['collective_bytes'] / 1e6:9.1f}MB"
          f" compile={art['compile_s']:7.1f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", dest="attn_impl")
    ap.add_argument("--remat")
    ap.add_argument("--q-chunk", dest="q_chunk", type=int)
    ap.add_argument("--grad-accum", dest="grad_accum", type=int)
    ap.add_argument("--grad-sync", dest="grad_sync")
    ap.add_argument("--axis-roles", dest="axis_roles")
    ap.add_argument("--serve-layout", dest="serve_layout")
    ap.add_argument("--serve-quant", dest="serve_quant")
    args = ap.parse_args()

    overrides = {k: getattr(args, k) for k in ("attn_impl", "remat",
                                               "q_chunk", "grad_accum",
                                               "grad_sync", "axis_roles",
                                               "serve_layout", "serve_quant")
                 if getattr(args, k) is not None}

    if args.all:
        from repro.configs import ARCHS, SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        results = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk,
                           "--out", args.out]
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    tail = (r.stdout or "").strip().splitlines()
                    print(tail[-1] if tail else
                          f"FAIL {arch} {shape} {mk}: {r.stderr[-400:]}")
                    results.append(r.returncode)
        sys.exit(max(results) if results else 0)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        art = run_cell(args.arch, args.shape, mk, args.out, overrides)
        _print_result(art)


if __name__ == "__main__":
    main()
