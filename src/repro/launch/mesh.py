"""Mesh construction.  A FUNCTION, not a module-level constant: importing
this module never touches jax device state."""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.core import compat

__all__ = ["make_production_mesh", "make_mesh", "dp_axes_of", "model_axis_of"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The production TPU v5e target: one 16x16 pod (256 chips) or two
    pods = 512 chips with a leading DCN ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...],
              axes: Optional[Tuple[str, ...]] = None) -> jax.sharding.Mesh:
    """Arbitrary mesh helper (tests, CPU runs, elasticity experiments)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) <= 3 \
            else tuple(f"ax{i}" for i in range(len(shape)))
    return compat.make_mesh(shape, axes)


def dp_axes_of(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_of(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None
