"""Serving launcher: the hardened continuous-batching loop over real
model decode buckets.

``python -m repro.launch.serve --arch llama3.2-1b --requests 8`` runs a
reduced config end-to-end on CPU: requests are admitted by the
model-priced controller (:class:`repro.runtime.server.LPFServer`),
batched continuously into ``(batch, cache_len)`` buckets, and decoded
through each bucket's fused whole-loop XLA computation
(``ServeStep.decode_fn``).  Full configs use the same driver under a
real mesh.

The engine here wraps :func:`repro.runtime.train_step
.build_serve_buckets`; its admission prices are *wall-calibrated* from
a warm-up decode per bucket (the model's transformer step is jax
compute, not an LPF program, so the BSP ledger does not price it —
the pure-LPF :class:`~repro.runtime.server.ProgramDecodeEngine` is
the model-priced variant the chaos soak proves exact).  Greedy decode
is row-independent, so a request's token stream is bit-identical
whether it decodes solo or fully batched; ``--check`` re-decodes every
completed request solo and verifies exactly that.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict, Sequence, Tuple


class ModelDecodeEngine:
    """Decode-engine protocol (see :class:`repro.runtime.server
    .LPFServer`) over real model buckets: one jitted per-token step and
    memoized fused decode loops per ``(batch, cache_len)`` shape.

    ``quarantine(bucket)`` (or ``--per-token``) drops the bucket to the
    per-token dispatch path — same greedy argmax stream, one jitted
    call per token instead of one XLA ``While`` per sequence."""

    def __init__(self, cfg, mesh, buckets: Sequence[Tuple[int, int]],
                 calibrate_tokens: int = 4):
        import jax
        import jax.numpy as jnp
        from repro.models import init_caches, init_params
        from repro.runtime.train_step import build_serve_buckets

        self._jax, self._jnp = jax, jnp
        self._cfg = cfg
        self._init_caches = init_caches
        self._steps = build_serve_buckets(cfg, mesh, buckets)
        self._params = {
            b: jax.device_put(init_params(jax.random.PRNGKey(0), cfg),
                              ss.param_sharding)
            for b, ss in self._steps.items()}
        self._enc = {}
        for b, ss in self._steps.items():
            self._enc[b] = (jnp.zeros((b[0], 64, cfg.d_model),
                                      jnp.bfloat16),) \
                if cfg.encoder_groups else ()
        self._quarantined: set = set()
        self._token_s: Dict[Tuple[int, int], float] = {}
        self._overhead_s: Dict[Tuple[int, int], float] = {}
        self._calibrate(calibrate_tokens)

    # -- protocol --------------------------------------------------------
    def buckets(self):
        return tuple(sorted(self._steps))

    def token_seconds(self, bucket):
        return self._token_s[tuple(bucket)]

    def overhead_seconds(self, bucket):
        return self._overhead_s[tuple(bucket)]

    def round_tokens(self, bucket, n: int) -> int:
        t = 1
        while t < n:
            t *= 2
        return min(t, bucket[1])

    def ledger_seconds(self, bucket, n_tokens: int) -> float:
        b = tuple(bucket)
        return self._overhead_s[b] + self._token_s[b] * n_tokens

    def quarantine(self, bucket) -> None:
        self._quarantined.add(tuple(bucket))

    def decode(self, bucket, reqs, n_tokens: int
               ) -> Dict[int, Tuple[int, ...]]:
        toks = self._decode_rows(
            tuple(bucket),
            [r.seed % self._cfg.vocab for r in reqs], n_tokens)
        return {r.rid: toks[i] for i, r in enumerate(reqs)}

    # -- internals -------------------------------------------------------
    def _decode_rows(self, bucket, seed_toks, n_tokens: int):
        """Decode ``n_tokens`` greedy tokens for rows seeded with
        ``seed_toks`` (one prompt token each); rows beyond the request
        count pad with token 0.  Returns per-row token tuples."""
        jax, jnp = self._jax, self._jnp
        B, C = bucket
        ss = self._steps[bucket]
        caches = jax.device_put(
            self._init_caches(self._cfg, B, C), ss.cache_sharding)
        row = [int(s) for s in seed_toks] + [0] * (B - len(seed_toks))
        tok = jnp.asarray(row, jnp.int32)
        extra = self._enc[bucket]
        if bucket in self._quarantined:
            seq = []
            for pos in range(n_tokens):
                tok, caches = ss.step_fn(self._params[bucket], caches,
                                         tok, jnp.int32(pos), *extra)
                seq.append(tok)
            out = jnp.stack(seq)            # [T, B]
        else:
            out, _caches = ss.decode_fn(n_tokens)(
                self._params[bucket], caches, tok, jnp.int32(0), *extra)
        jax.block_until_ready(out)
        return [tuple(int(t) for t in out[:, i]) for i in range(B)]

    def _calibrate(self, n_tokens: int) -> None:
        """Wall-calibrate the admission price per bucket: trace+compile
        on the first decode, then time one 1-token and one ``n``-token
        decode — the slope is the per-token price, the intercept the
        per-call overhead."""
        for b in self.buckets():
            n = min(n_tokens, b[1])
            for t in (1, n):                # compile both lengths
                self._decode_rows(b, [0], t)
            t0 = time.perf_counter()
            self._decode_rows(b, [0], 1)
            t1 = time.perf_counter()
            self._decode_rows(b, [0], n)
            t2 = time.perf_counter()
            per_tok = max((t2 - t1) - (t1 - t0), 1e-9) / max(n - 1, 1)
            self._token_s[b] = per_tok
            self._overhead_s[b] = max((t1 - t0) - per_tok, 0.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32,
                    help="max tokens per request")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic requests to serve")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--deadline-scale", type=float, default=40.0,
                    help="loose deadlines as multiples of the "
                         "calibrated per-token decode cost")
    ap.add_argument("--tight-frac", type=float, default=0.25,
                    help="fraction of deliberately unmeetable deadlines")
    ap.add_argument("--per-token", action="store_true",
                    help="dispatch one jitted call per token (the "
                         "fallback path) instead of the fused decode "
                         "loop")
    ap.add_argument("--check", action="store_true",
                    help="re-decode every completed request solo and "
                         "assert the batched stream is bit-identical")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.runtime.server import LPFServer, synthetic_requests

    mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")))
    cfg = get_config(args.arch, smoke=args.smoke,
                     ep_degree=mesh.shape.get("model", 1))
    cache_len = max(args.cache_len, args.tokens)
    buckets = sorted({(max(1, args.batch // 2), cache_len),
                      (args.batch, cache_len)})
    print(f"building decode buckets {buckets} ...")
    eng = ModelDecodeEngine(cfg, mesh, buckets)
    if args.per_token:
        for b in eng.buckets():
            eng.quarantine(b)
    for b in eng.buckets():
        print(f"  bucket {b}: {eng.token_seconds(b) * 1e3:.2f} ms/token"
              f" + {eng.overhead_seconds(b) * 1e3:.2f} ms/call")

    srv = LPFServer(eng, max_queue=args.max_queue)
    reqs = synthetic_requests(
        args.requests, args.seed, buckets,
        token_cost_s=max(eng.token_seconds(b) for b in buckets),
        deadline_scale=args.deadline_scale, tight_frac=args.tight_frac,
        max_tokens=args.tokens)
    t0 = time.perf_counter()
    for r in reqs:
        out = srv.submit(r)
        if out.status != "admitted":
            print(f"  rid {r.rid}: {out.status} ({out.reason})")
    srv.run_until_idle()
    health = srv.drain()
    dt = time.perf_counter() - t0

    outs = srv.take_outcomes()
    done = [o for o in outs.values() if o.status == "completed"]
    ntok = sum(len(o.tokens) for o in done)
    print(f"\nserved {len(done)}/{args.requests} requests "
          f"({ntok} tokens) in {dt:.3f}s wall "
          f"({ntok / dt:.1f} tok/s), vclock {health['vclock_s']:.3f}s")
    for k in ("admitted", "completed", "rejected_total", "shed",
              "deadline_misses", "batches", "decode_fallbacks",
              "level_peak", "queue_peak"):
        print(f"  {k}: {health[k]}")
    if done:
        o = min(done, key=lambda o: o.rid)
        print(f"sample stream (rid {o.rid}):",
              list(o.tokens[:16]))

    # SLO accounting gates (the CI smoke tripwire): an admitted request
    # must never miss its deadline on the admission clock, a drain must
    # leave nothing queued, and every non-completed request must carry
    # a classified refusal
    if health["deadline_misses"]:
        raise SystemExit(f"SLO violation: {health['deadline_misses']} "
                         f"admitted request(s) missed their deadline")
    if health["queue_depth"] != 0 or not health["draining"]:
        raise SystemExit("drain left work queued")
    unclassified = [o.rid for o in outs.values()
                    if o.status != "completed" and not o.classified]
    if unclassified:
        raise SystemExit(f"unclassified refusals: rids {unclassified}")

    if args.check:
        bad = 0
        for o in sorted(done, key=lambda o: o.rid):
            r = next(r for r in reqs if r.rid == o.rid)
            solo = eng.decode(o.bucket, [r],
                              eng.round_tokens(o.bucket, r.n_tokens))
            if tuple(solo[r.rid][:r.n_tokens]) != tuple(o.tokens):
                bad += 1
                print(f"  CHECK FAILED rid {o.rid}: batched stream "
                      f"differs from solo decode")
        print(f"check: {len(done) - bad}/{len(done)} completed "
              f"requests bit-identical to solo decode")
        if bad:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
