"""Serving launcher: batched greedy decode against a distributed cache.

``python -m repro.launch.serve --arch llama3.2-1b --tokens 32`` runs a
reduced config end-to-end on CPU; full configs use the same driver under
a real mesh.
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--per-token", action="store_true",
                    help="dispatch one jitted call per token (the old "
                         "path) instead of the fused decode loop")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models import init_caches, init_params
    from repro.runtime.train_step import build_serve_step

    mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")))
    cfg = get_config(args.arch, smoke=args.smoke,
                     ep_degree=mesh.shape.get("model", 1))
    ss = build_serve_step(cfg, mesh, global_batch=args.batch,
                          cache_len=args.cache_len)
    params = jax.device_put(init_params(jax.random.PRNGKey(0), cfg),
                            ss.param_sharding)
    caches = jax.device_put(init_caches(cfg, args.batch, args.cache_len),
                            ss.cache_sharding)
    enc_out = None
    extra = ()
    if cfg.encoder_groups:
        enc_out = jnp.zeros((args.batch, 64, cfg.d_model), jnp.bfloat16)
        extra = (enc_out,)

    tok = jnp.zeros((args.batch,), jnp.int32)
    if args.per_token:
        seq = [tok]
        t0 = time.perf_counter()
        for pos in range(args.tokens):
            tok, caches = ss.step_fn(params, caches, tok, jnp.int32(pos),
                                     *extra)
            seq.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        toks = jnp.stack(seq, axis=1)
    else:
        # fused decode: the whole token loop is ONE XLA While computation
        decode = ss.decode_fn(args.tokens)
        t0 = time.perf_counter()
        rest, caches = decode(params, caches, tok, jnp.int32(0), *extra)
        jax.block_until_ready(rest)
        dt = time.perf_counter() - t0
        toks = jnp.concatenate([tok[None, :], rest], axis=0).T
    print(f"decoded {args.tokens} tokens x batch {args.batch} in "
          f"{dt:.3f}s ({args.tokens * args.batch / dt:.1f} tok/s)")
    print("sample stream:", [int(t) for t in toks[0][:16]])


if __name__ == "__main__":
    main()
