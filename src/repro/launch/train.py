"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On the CPU container this drives reduced (smoke) configs end-to-end; on a
real cluster the same driver runs the full configs (jax.distributed
initialisation happens before mesh construction when JAX_COORDINATOR is
set — the TPU analogue of the paper's lpf_mpi_initialize_over_tcp).
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM (data x model), or PxDxM for multi-pod")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (CPU emulation)")
    ap.add_argument("--grad-sync", default="gspmd",
                    choices=["gspmd", "lpf"])
    ap.add_argument("--sync-every", type=int, default=0,
                    help="local-SGD period (0 = synchronous)")
    ap.add_argument("--compress", action="store_true",
                    help="int8 cross-pod gradient compression (lpf mode)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()

    import jax
    from repro.configs import get_config
    from repro.core import CompressSpec, SyncAttributes
    from repro.data import DataConfig, SyntheticStream
    from repro.launch.mesh import make_mesh
    from repro.optim import AdamWConfig, warmup_cosine
    from repro.runtime.train_loop import TrainLoopConfig, train_loop
    from repro.runtime.train_step import build_train_step

    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(shape)
    cfg = get_config(args.arch, smoke=args.smoke,
                     ep_degree=mesh.shape.get("model", 1))
    attrs = SyncAttributes(compress=CompressSpec(bits=8)
                           if args.compress else None)
    ts = build_train_step(
        cfg, mesh,
        opt_cfg=AdamWConfig(lr=warmup_cosine(args.lr, 10, args.steps)),
        grad_sync=args.grad_sync, sync_attrs=attrs,
        grad_accum=args.grad_accum)
    ts_nosync = None
    if args.sync_every > 1:
        ts_nosync = build_train_step(
            cfg, mesh, opt_cfg=AdamWConfig(
                lr=warmup_cosine(args.lr, 10, args.steps)),
            grad_sync="gspmd", grad_accum=args.grad_accum)

    stream = SyntheticStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch), cfg)

    def on_step(step, loss, verdict):
        if step % 10 == 0 or verdict.straggle:
            flag = f" [{verdict.action}]" if verdict.action != "ok" else ""
            print(f"step {step:>5}  loss {loss:.4f}  "
                  f"{verdict.duration * 1e3:7.1f} ms{flag}")

    out = train_loop(ts, stream, TrainLoopConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        sync_every=args.sync_every),
        step_fn_nosync=ts_nosync.step_fn if ts_nosync else None,
        on_step=on_step)
    print(f"final loss: {out['final_loss']:.4f}")
    if ts.ledger.records:
        print("\nLPF superstep ledger (first steps):")
        print(ts.ledger.report())


if __name__ == "__main__":
    main()
