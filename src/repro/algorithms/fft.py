"""The immortal BSP FFT (Inda & Bisseling, paper ref [10]) on LPF.

Radix-p decomposition with a *single* data redistribution, valid whenever
``n >= p**2`` (the paper's ``sqrt(n) > p`` condition).  Writing the input
index ``j = l*p + s`` (cyclic over processes) and the output index
``k = k2 + (n/p)*k1``:

    y[k2 + (n/p) k1] = sum_s  w_p^{s k1} * ( w_n^{s k2} * X_s[k2] )

where ``X_s = FFT_{n/p}(x_s)`` is a process-local FFT of the cyclic slice.
The algorithm is therefore:

  (0) local ``n/p``-point FFT of the cyclic-distributed input,
  (1) local twiddle by ``w_n^{s k2}`` (the *time-shifted* scaling the
      paper laments vendor libraries do not expose),
  (2) ONE total exchange — blocks of ``n/p**2`` — so each process owns a
      contiguous ``k2`` range for all ``s``;   cost  (n/p)g + l,
  (3) local ``p``-point DFTs across the gathered ``s`` dimension,
      evaluated as a dense [p, p] twiddle matmul (MXU-friendly on TPU),
  (4) *optional* second exchange to produce naturally-ordered output
      (``ordered=True``); the immortal algorithm's native output order is
      "k1-major blocked by k2" — exactly the unordered/decimated output
      the paper benchmarks.

BSP cost:  2 (n/p) log(n/p + p) flops  +  (n/p)(p-1)/p * itemsize * g
           + l   (unordered; ordered doubles the comm term), where
           itemsize is 8 bytes for complex64 and 16 for complex128 —
           matching ``fft_h_bytes``'s default of 8.

The process-local FFT runs through ``repro.kernels.fft_stage`` (Pallas,
TPU-tiled) when ``use_kernel=True``, else ``jnp.fft.fft``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LPFContext, LPF_SYNC_DEFAULT, SyncAttributes, exec_, hook
from jax.sharding import PartitionSpec as P

__all__ = ["bsp_fft_spmd", "bsp_fft", "fft_flops", "fft_h_bytes"]


def fft_flops(n: int) -> float:
    """Standard 5 n log2 n flop count for a complex FFT."""
    return 5.0 * n * math.log2(max(n, 2))


def fft_h_bytes(n: int, p: int, ordered: bool = True,
                itemsize: int = 8) -> int:
    """Predicted h-relation (bytes) of the BSP FFT — the immortal cost.

    ``itemsize`` is the *complex* element width: 8 for complex64 (the
    default, matching the benchmarks) and 16 for complex128."""
    if p == 1:
        return 0
    one = (n // p) * (p - 1) // p * itemsize
    return (2 * one) if ordered else one


def _local_fft(x: jnp.ndarray, use_kernel: bool) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.fft_stage import ops as fft_ops
        return fft_ops.fft(x)
    return jnp.fft.fft(x)


def bsp_fft_spmd(ctx: LPFContext, x_local: jnp.ndarray, n: int, *,
                 ordered: bool = True, use_kernel: bool = False,
                 attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                 inverse: bool = False) -> jnp.ndarray:
    """Run the immortal FFT inside an SPMD region.

    ``x_local``: this process's *cyclic* slice (x[s], x[s+p], ...) of
    length n/p, complex64/128.  Returns the local output slice: the
    contiguous block ``y[s*(n/p) : (s+1)*(n/p)]`` when ``ordered`` else
    the algorithm's native unordered block.
    """
    p, s = ctx.p, ctx.pid
    npp = n // p
    if n % (p * p) != 0 and p > 1:
        raise ValueError(f"BSP FFT requires p^2 | n (got n={n}, p={p})")
    if x_local.shape[0] != npp:
        raise ValueError(f"local slice must be n/p={npp}, got {x_local.shape}")
    ctype = x_local.dtype
    sign = 1.0 if inverse else -1.0

    # (0) local FFT of the cyclic slice (conj-trick for the inverse)
    if inverse:
        X = jnp.conj(_local_fft(jnp.conj(x_local), use_kernel))
    else:
        X = _local_fft(x_local, use_kernel)

    if p == 1:
        return X / n if inverse else X

    # (1) time-shifted twiddle  w_n^{+- s k2}, built in the real dtype
    # matching the input's precision (float64 for complex128 inputs —
    # a float32 phase costs ~1e-4 relative error at n >= 2**16)
    real_dt = jnp.finfo(ctype).dtype
    k2 = jnp.arange(npp, dtype=real_dt)
    phase = (s.astype(real_dt) * k2 / n) * real_dt.type(sign * 2.0 * np.pi)
    Z = X * jax.lax.complex(jnp.cos(phase), jnp.sin(phase)).astype(ctype)

    # (2)-(4) run recorded: the twiddle matmul is a genuine compute
    # dependency between redistribute and reorder, so the pair can never
    # batch — but the flush is dataflow-precise: reading Zk executes
    # exactly the redistribute's cone, so when this FFT runs inside an
    # enclosing recorded program (a batched spectral pipeline), the
    # caller's independent supersteps stay recorded, and the DAG
    # schedule search may hoist them — non-adjacent or not — into
    # overlap groups with this FFT's exchanges (two recorded FFTs
    # schedule as [A.redist||B.redist][A.reorder||B.reorder]; see
    # benchmarks/schedule_search.py).
    with ctx.program("bsp_fft"):
        # (2) the single redistribution: block d of my k2-range to process d
        w = npp // p  # n / p^2 elements per (src, dst) pair
        ctx.resize_memory_register(ctx.registry.n_active + 2)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global("fft.src", Z)
        dst = ctx.register_global("fft.buf", jnp.zeros(p * w, ctype))
        ctx.put_msgs([(s_, d, src, d * w, dst, s_ * w, w)
                      for s_ in range(p) for d in range(p)])
        ctx.sync(attrs, label="fft.redistribute")
        Zk = ctx.tensor(dst).reshape(p, w)      # [s, k2_local]
        ctx.deregister(src)

        # (3) p-point DFTs across s as a dense twiddle matmul (MXU-friendly)
        k1 = np.arange(p)
        Wp = np.exp(sign * 2j * np.pi * np.outer(k1, k1) / p).astype(ctype)
        Y = jnp.einsum("ts,sk->tk", jnp.asarray(Wp), Zk)   # [k1, k2_local]

        if not ordered:
            ctx.deregister(dst)
            out = Y.reshape(-1)
            return out / n if inverse else out

        # (4) ordering pass: row k1 belongs to process k1 (block distribution)
        ctx.resize_memory_register(ctx.registry.n_active + 2)
        ctx.resize_message_queue(p * p)
        osrc = ctx.register_global("fft.osrc", Y.reshape(-1))
        odst = ctx.register_global("fft.odst", jnp.zeros(npp, ctype))
        # my row k1=d (length w) goes to process d at offset (my pid)*w
        ctx.put_msgs([(s_, d, osrc, d * w, odst, s_ * w, w)
                      for s_ in range(p) for d in range(p)])
        ctx.sync(attrs, label="fft.reorder")
        yl = ctx.tensor(odst)
        ctx.deregister(dst)
        ctx.deregister(osrc)
        ctx.deregister(odst)
    return yl / n if inverse else yl


def bsp_fft(mesh: jax.sharding.Mesh, x: jnp.ndarray, *,
            axes: Optional[tuple] = None, ordered: bool = True,
            use_kernel: bool = False, inverse: bool = False,
            attrs: SyncAttributes = LPF_SYNC_DEFAULT,
            return_ledger: bool = False):
    """Whole-array driver: ``lpf_exec`` the immortal FFT over ``mesh``.

    ``x`` is the full (host) vector; it is scattered cyclically, the SPMD
    FFT runs, and the naturally-ordered result is gathered back.
    """
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    p = int(np.prod([mesh.shape[a] for a in axes]))
    n = int(x.shape[0])
    xc = x.reshape(n // p, p).T.reshape(-1)  # cyclic layout, pid-major

    def spmd(ctx, s, pp, xt):
        xl = xt.reshape(pp, n // pp)[s]
        return bsp_fft_spmd(ctx, xl, n, ordered=ordered,
                            use_kernel=use_kernel, attrs=attrs,
                            inverse=inverse)

    out = exec_(mesh, spmd, jnp.asarray(xc), axes=axes,
                out_specs=P(axes), return_ledger=return_ledger)
    if return_ledger:
        out, ledger = out
    y = out.reshape(-1)
    if not ordered:
        # undo the unordered layout on host for verification: process s
        # holds [k1, k2local] with k2local in block s
        y = y.reshape(p, p, n // (p * p))          # [s, k1, k2l]
        y = jnp.transpose(y, (1, 0, 2)).reshape(-1)  # k1-major, k2 = s*w + k2l
    return (y, ledger) if return_ledger else y
