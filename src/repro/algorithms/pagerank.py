"""PageRank on a GraphBLAS-lite SpMV over LPF (paper §4.3).

The accelerated implementation translates the canonical linear-algebra
formulation (Langville & Meyer, paper ref [11]) onto LPF supersteps:

    r' = alpha * (A r  +  1/n * sum_{dangling j} r_j)  +  (1 - alpha)/n

Each iteration is:
  superstep 1 — halo exchange: owners *put* packed rank entries to the
                processes whose rows reference them (the static plan from
                the sparsity structure — an irregular h-relation, LPF's
                natural habitat);
  local       — SpMV via segment-sum + dangling correction;
  superstep 2 — a tiny allreduce of [dangling mass, next dangling mass,
                l1 residual] fused into one 3-word vector.

Unlike the paper's "pure Spark" baseline (also reimplemented here as
:func:`dataflow_pagerank`, which all-gathers the full rank vector every
iteration and ignores dangling mass and convergence), the LPF version
handles dangling nodes and checks an l1 tolerance — the same asymmetry
the paper deliberately keeps (§4.3, "can only skew the comparison in
favour of Spark").
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import bsp
from repro.core import LPFContext, LPF_SYNC_DEFAULT, SyncAttributes, exec_
from .graphs import PartitionedGraph

__all__ = ["lpf_pagerank", "pagerank_spmd", "dataflow_pagerank",
           "reference_pagerank"]


def _halo_exchange(ctx: LPFContext, g: PartitionedGraph,
                   r_local: jnp.ndarray,
                   attrs: SyncAttributes, pack_idx: jnp.ndarray
                   ) -> jnp.ndarray:
    """One halo superstep: returns the [halo_max] remote ranks."""
    pack = r_local[pack_idx]  # static-shape gather of entries to send
    ctx.resize_memory_register(ctx.registry.n_active + 2)
    ctx.resize_message_queue(max(1, len(g.msgs)))
    s_pack = ctx.register_global("pr.pack", pack)
    s_halo = ctx.register_global("pr.halo", jnp.zeros(g.halo_max, r_local.dtype))
    ctx.put_msgs([(o, d, s_pack, po, s_halo, ho, c)
                  for (o, d, po, ho, c) in g.msgs if c > 0])
    ctx.sync(attrs, label="pr.halo")
    halo = ctx.tensor(s_halo)
    ctx.deregister(s_pack)
    ctx.deregister(s_halo)
    return halo


def pagerank_spmd(ctx: LPFContext, g: PartitionedGraph, shard: dict, *,
                  alpha: float = 0.85, tol: float = 1e-7,
                  max_iter: int = 200,
                  attrs: SyncAttributes = LPF_SYNC_DEFAULT
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run PageRank inside an SPMD region.

    ``shard``: this process's rows of the stacked arrays (squeezed):
    row_ids/col_ext/vals [nnz_max], pack_idx [send_max], dangling [rows].
    Returns (r_local [rows], iterations, l1 residual).
    """
    rows, n = g.rows, g.n
    row_ids = shard["row_ids"]
    col_ext = shard["col_ext"]
    vals = shard["vals"]
    pack_idx = shard["pack_idx"]
    dangling = shard["dangling"]
    axes = ctx.axes

    r0 = jnp.full(rows, 1.0 / n, jnp.float32)

    def reduce3(ctx2, v3):
        return bsp.allreduce(ctx2, v3, attrs=attrs, label="pr.reduce")

    def one_iter(ctx2: LPFContext, r: jnp.ndarray, dmass: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        # the whole iteration records as one program (``compile_loop``
        # opens the trace): the halo read is a *dataflow-precise* flush
        # (it executes exactly the halo superstep's cone, not whatever
        # else the trace holds), so the halo + score-update pattern
        # keeps independent supersteps — the nested stats-allreduce
        # pair — recorded across the SpMV compute barrier, where the
        # DAG schedule search may reorder or overlap them, and replays
        # per-iteration traces from the program cache
        # (reordered-but-equivalent recordings of later iterations
        # canonicalize to the same cache entry)
        halo = _halo_exchange(ctx2, g, r, attrs, pack_idx)
        x_ext = jnp.concatenate([r, halo])
        contrib = vals * x_ext[col_ext]
        spmv = jax.ops.segment_sum(contrib, row_ids,
                                   num_segments=rows + 1,
                                   indices_are_sorted=False)[:rows]
        r_new = alpha * (spmv + dmass / n) + (1.0 - alpha) / n
        # fused 3-word allreduce: next dangling mass, residual, (spare)
        stats = jnp.stack([jnp.sum(r_new * dangling),
                           jnp.sum(jnp.abs(r_new - r)),
                           jnp.zeros((), jnp.float32)])
        tot = reduce3(ctx2, stats)
        return r_new, tot[0], tot[1]

    # initial dangling mass of the uniform vector
    stats0 = bsp.allreduce(
        ctx, jnp.stack([jnp.sum(r0 * dangling),
                        jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)]),
        attrs=attrs, label="pr.init")
    d0 = stats0[0]

    def cond(carry):
        _, _, it, res = carry
        return (it < max_iter) & (res > tol)

    def body(ctx2, carry):
        r, dmass, it, _ = carry
        r_new, dnew, res = one_iter(ctx2, r, dmass)
        return (r_new, dnew, it + 1, res)

    # the whole iterated program lowers as ONE XLA While computation
    # (body traced once, per-iteration superstep costs ledgered once)
    # instead of a Python-dispatched hook per iteration
    r, dmass, iters, res = ctx.compile_loop(
        body, (r0, d0, jnp.zeros((), jnp.int32),
               jnp.full((), jnp.inf, jnp.float32)),
        cond=cond, label="pr.iter")
    return r, iters, res


def lpf_pagerank(mesh: jax.sharding.Mesh, g: PartitionedGraph, *,
                 axes: Optional[tuple] = None, alpha: float = 0.85,
                 tol: float = 1e-7, max_iter: int = 200,
                 attrs: SyncAttributes = LPF_SYNC_DEFAULT):
    """Whole-graph driver: distribute shards, run, gather [n] ranks."""
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    args = {
        "row_ids": jnp.asarray(g.row_ids), "col_ext": jnp.asarray(g.col_ext),
        "vals": jnp.asarray(g.vals), "pack_idx": jnp.asarray(g.pack_idx),
        "dangling": jnp.asarray(g.dangling),
    }
    in_specs = {k: P(axes) for k in args}

    def spmd(ctx, s, p, a):
        shard = {k: v.reshape(v.shape[1:]) for k, v in a.items()}
        return pagerank_spmd(ctx, g, shard, alpha=alpha, tol=tol,
                             max_iter=max_iter, attrs=attrs)

    r, iters, res = exec_(mesh, spmd, args, axes=axes,
                          in_specs=in_specs,
                          out_specs=(P(axes), P(), P()))
    return r.reshape(-1), int(iters), float(res)


# --------------------------------------------------------------------------
# baselines
# --------------------------------------------------------------------------

def dataflow_pagerank(edges: np.ndarray, n: int, iters: int,
                      alpha: float = 0.85) -> np.ndarray:
    """The paper's "pure Spark" analogue: contributions shuffled globally
    every iteration (here: a full gather + segment-sum in jit), *without*
    dangling handling or convergence checks — faithful to
    examples/SparkPageRank.scala which computes
    ``rank = 0.15 + 0.85 * sum(contribs)``."""
    src = jnp.asarray(edges[:, 0])
    dst = jnp.asarray(edges[:, 1])
    outdeg = jnp.asarray(np.maximum(
        np.bincount(edges[:, 0], minlength=n), 1).astype(np.float32))

    @jax.jit
    def step(r):
        contrib = r[src] / outdeg[src]
        s = jax.ops.segment_sum(contrib, dst, num_segments=n)
        return (1.0 - alpha) + alpha * s

    r = jnp.ones(n, jnp.float32)
    for _ in range(iters):
        r = step(r)
    return np.asarray(r)


def reference_pagerank(edges: np.ndarray, n: int, alpha: float = 0.85,
                       tol: float = 1e-10, max_iter: int = 500
                       ) -> Tuple[np.ndarray, int]:
    """Dense numpy oracle with dangling handling (test reference)."""
    A = np.zeros((n, n), np.float64)
    outdeg = np.bincount(edges[:, 0], minlength=n)
    for s, d in edges:
        A[d, s] = 1.0 / outdeg[s]
    dangling = (outdeg == 0).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for it in range(max_iter):
        r_new = alpha * (A @ r + np.dot(dangling, r) / n) + (1 - alpha) / n
        if np.abs(r_new - r).sum() < tol:
            return r_new, it + 1
        r = r_new
    return r, max_iter
