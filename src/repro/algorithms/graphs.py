"""Graph substrate for the PageRank immortal algorithm.

Deterministic R-MAT generator (the paper uses SuiteSparse/WebGraph
matrices; offline we synthesise power-law webgraphs), a block row
partitioner producing uniform SPMD-ready CSR shards, and the *static halo
plan*: for every (owner, requester) process pair, which rank entries must
travel each iteration.  The plan is exactly an LPF h-relation — the
communication pattern of sparse matrix-vector multiplication is known
from the sparsity structure, so every PageRank iteration is one
`lpf_put`-superstep plus one small allreduce.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["rmat_graph", "banded_graph", "PartitionedGraph", "partition_graph"]


def rmat_graph(n: int, m: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> np.ndarray:
    """Directed R-MAT edge list [m, 2] (src, dst), deduplicated, no self
    loops.  ``n`` must be a power of two."""
    assert n & (n - 1) == 0, "rmat needs power-of-two n"
    rng = np.random.default_rng(seed)
    scale = int(np.log2(n))
    edges = set()
    probs = np.array([a, b, c, 1.0 - a - b - c])
    batch = max(4 * m, 1024)
    while len(edges) < m:
        quad = rng.choice(4, size=(batch, scale), p=probs)
        src_bits = (quad >= 2).astype(np.int64)
        dst_bits = (quad % 2).astype(np.int64)
        weights = 1 << np.arange(scale - 1, -1, -1, dtype=np.int64)
        src = src_bits @ weights
        dst = dst_bits @ weights
        for s, d in zip(src, dst):
            if s != d:
                edges.add((int(s), int(d)))
                if len(edges) >= m:
                    break
    out = np.array(sorted(edges), dtype=np.int64)
    return out


def banded_graph(n: int, band: int = 4) -> np.ndarray:
    """Deterministic banded digraph (cage-matrix-like): vertex v links to
    v+1 .. v+band (mod n)."""
    src = np.repeat(np.arange(n), band)
    off = np.tile(np.arange(1, band + 1), n)
    dst = (src + off) % n
    return np.stack([src, dst], axis=1)


@dataclasses.dataclass
class PartitionedGraph:
    """Block-row partitioned column-stochastic link matrix + halo plan.

    Traced (per-process, stacked on axis 0) arrays — distribute with
    ``in_specs=P(axes)``:
      ``row_ids``  [p, nnz_max]   local row of each stored nonzero
      ``col_ext``  [p, nnz_max]   column index into [local r | halo]
      ``vals``     [p, nnz_max]   1/outdeg(src)   (0 padding)
      ``pack_idx`` [p, send_max]  local r indices to pack for neighbours
      ``dangling`` [p, rows]      1.0 where the local vertex is dangling

    Static (host) plan:
      ``msgs``     [(owner, requester, pack_off, halo_off, count)]
      ``halo_max`` / ``send_max`` reserved capacities (lpf_resize_*)
    """

    n: int
    p: int
    rows: int
    nnz_max: int
    send_max: int
    halo_max: int
    row_ids: np.ndarray
    col_ext: np.ndarray
    vals: np.ndarray
    pack_idx: np.ndarray
    dangling: np.ndarray
    msgs: List[Tuple[int, int, int, int, int]]

    def h_bytes(self, itemsize: int = 4) -> int:
        """The per-iteration halo h-relation (bytes) — the immortal cost."""
        sent = np.zeros(self.p, np.int64)
        recv = np.zeros(self.p, np.int64)
        for o, d, _, _, c in self.msgs:
            if o != d:
                sent[o] += c * itemsize
                recv[d] += c * itemsize
        return int(max(sent.max(initial=0), recv.max(initial=0)))


def partition_graph(edges: np.ndarray, n: int, p: int) -> PartitionedGraph:
    """Build the SPMD shards + halo plan for ``r' = A r`` with
    ``A[dst, src] = 1/outdeg(src)``."""
    if n % p:
        raise ValueError(f"n={n} must be divisible by p={p}")
    rows = n // p
    src, dst = edges[:, 0], edges[:, 1]
    outdeg = np.bincount(src, minlength=n).astype(np.float64)
    dangling_v = (outdeg == 0).astype(np.float32)

    owner = dst // rows            # nonzero [dst, src] lives on dst's owner
    col_owner = src // rows

    per_pid_nnz = np.bincount(owner, minlength=p)
    nnz_max = int(per_pid_nnz.max(initial=1))

    # per-pid halos: unique remote sources, grouped by owning process
    halos: List[np.ndarray] = []
    halo_groups: List[List[np.ndarray]] = []
    for d in range(p):
        mask = owner == d
        remote = np.unique(src[mask & (col_owner != d)])
        groups = [remote[(remote // rows) == o] for o in range(p)]
        halos.append(np.concatenate(groups) if groups else remote)
        halo_groups.append(groups)
    halo_max = max(1, max(h.size for h in halos))

    # owner-side pack buffers: concatenation over requesters of the
    # local indices each requester needs
    pack_lists: List[List[np.ndarray]] = [[] for _ in range(p)]
    for d in range(p):
        for o in range(p):
            g = halo_groups[d][o]
            if g.size:
                pack_lists[o].append((d, g - o * rows))
    msgs: List[Tuple[int, int, int, int, int]] = []
    pack_idx = np.zeros((p, 1), np.int32)
    send_max = 1
    packs: List[np.ndarray] = []
    for o in range(p):
        cat = []
        off = 0
        for d, loc in pack_lists[o]:
            halo_off = 0
            for oo in range(o):
                halo_off += halo_groups[d][oo].size
            msgs.append((o, d, off, halo_off, int(loc.size)))
            cat.append(loc)
            off += loc.size
        packs.append(np.concatenate(cat).astype(np.int32) if cat
                     else np.zeros(0, np.int32))
        send_max = max(send_max, off)
    pack_idx = np.zeros((p, send_max), np.int32)
    for o in range(p):
        pack_idx[o, :packs[o].size] = packs[o]

    # CSR-ish shards with extended column indices
    row_ids = np.full((p, nnz_max), rows, np.int32)  # pad -> dump bucket
    col_ext = np.zeros((p, nnz_max), np.int32)
    vals = np.zeros((p, nnz_max), np.float32)
    for d in range(p):
        mask = owner == d
        s_d, t_d = src[mask], dst[mask]
        # map source -> extended index
        remote_pos = {int(v): i for i, v in enumerate(halos[d])}
        ext = np.where(col_owner[mask] == d, s_d - d * rows,
                       np.array([rows + remote_pos.get(int(v), 0)
                                 for v in s_d]))
        k = s_d.size
        row_ids[d, :k] = (t_d - d * rows).astype(np.int32)
        col_ext[d, :k] = ext.astype(np.int32)
        vals[d, :k] = (1.0 / outdeg[s_d]).astype(np.float32)

    dang = dangling_v.reshape(p, rows)
    return PartitionedGraph(
        n=n, p=p, rows=rows, nnz_max=nnz_max, send_max=send_max,
        halo_max=halo_max, row_ids=row_ids, col_ext=col_ext, vals=vals,
        pack_idx=pack_idx, dangling=dang, msgs=msgs)
