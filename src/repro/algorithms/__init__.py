"""Immortal algorithms (paper §4): the BSP FFT and the GraphBLAS-lite
PageRank, plus their baselines."""

from .fft import bsp_fft, bsp_fft_spmd, fft_flops, fft_h_bytes
from .graphs import PartitionedGraph, banded_graph, partition_graph, rmat_graph
from .pagerank import (dataflow_pagerank, lpf_pagerank, pagerank_spmd,
                       reference_pagerank)

__all__ = [
    "bsp_fft", "bsp_fft_spmd", "fft_flops", "fft_h_bytes",
    "PartitionedGraph", "banded_graph", "partition_graph", "rmat_graph",
    "dataflow_pagerank", "lpf_pagerank", "pagerank_spmd",
    "reference_pagerank",
]
