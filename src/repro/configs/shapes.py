"""The assigned input-shape set and per-(arch x shape) input specs.

Four cells per architecture:
  train_4k     seq 4,096   global_batch 256   (train_step)
  prefill_32k  seq 32,768  global_batch 32    (serve prefill forward)
  decode_32k   seq 32,768  global_batch 128   (serve_step, 1 new token)
  long_500k    seq 524,288 global_batch 1     (decode; sub-quadratic only)

``decode_*``/``long_*`` lower ``serve_step`` — one token against a KV/SSM
cache of ``seq_len`` — not ``train_step``.  ``long_500k`` is skipped for
pure full-attention architectures (see DESIGN.md §4) and runs for the
SSM/hybrid ones.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ShapeCell", "SHAPES", "input_specs", "applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = {"mamba2-130m", "jamba-v0.1-52b"}


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 500k decode skipped (DESIGN.md §4)"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the batch pytree for ``train_step``/``prefill``.
    decode: {token, pos} (+ enc_out for enc-dec); caches are built
    separately by ``repro.models.lm.init_caches`` via eval_shape.
    """
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    cdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.compute_dtype]

    if cell.kind in ("train", "prefill"):
        batch = {}
        if cfg.modality == "vision":
            P = cfg.stub_prefix
            batch["embeds"] = _sds((B, P, cfg.d_model), cdt)
            batch["tokens"] = _sds((B, S - P), i32)
            batch["labels"] = _sds((B, S - P), i32)
        elif cfg.modality == "audio":
            batch["frames"] = _sds((B, S, cfg.d_model), cdt)
            batch["tokens"] = _sds((B, S), i32)
            batch["labels"] = _sds((B, S), i32)
        else:
            batch["tokens"] = _sds((B, S), i32)
            batch["labels"] = _sds((B, S), i32)
        return batch

    specs = {"token": _sds((B,), i32), "pos": _sds((), i32)}
    if cfg.encoder_groups:
        # encoder ran at prefill; decode consumes its output states
        specs["enc_out"] = _sds((B, 1500, cfg.d_model), cdt)
    return specs
