"""llama3.2-1b [dense]: 16L d=2048 32H (GQA kv=8) ff=8192 vocab=128256,
small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "llama3.2-1b"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=2048, vocab=128256,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 16),),
        n_heads=32, n_kv=8, head_dim=64, d_ff=8192,
        rope_theta=500_000.0, tie_embeddings=True,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 2),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        rope_theta=500_000.0, tie_embeddings=True, q_chunk=32,
        max_seq=256,
    )
