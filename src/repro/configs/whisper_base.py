"""whisper-base [audio]: enc-dec, 6+6L d=512 8H ff=2048 vocab=51865,
conv frontend STUB (``input_specs`` provides precomputed frame
embeddings), LayerNorm, sinusoidal encoder / learned decoder positions.
[arXiv:2212.04356; unverified]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "whisper-base"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=512, vocab=51865,
        encoder_groups=(Group("enc", (BlockCfg("attn", "dense",
                                               causal=False),), 6),),
        groups=(Group("dec", (BlockCfg("attn", "dense",
                                       cross_attn=True),), 6),),
        n_heads=8, n_kv=8, head_dim=64, d_ff=2048,
        norm="layer", pos_embed="learned", modality="audio",
        tie_embeddings=True,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        encoder_groups=(Group("enc", (BlockCfg("attn", "dense",
                                               causal=False),), 2),),
        groups=(Group("dec", (BlockCfg("attn", "dense",
                                       cross_attn=True),), 2),),
        n_heads=4, n_kv=4, head_dim=32, d_ff=256,
        norm="layer", pos_embed="learned", modality="audio",
        tie_embeddings=True, q_chunk=32,
        max_seq=256,
    )
