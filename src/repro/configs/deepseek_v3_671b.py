"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, 1 shared + 256 routed
experts top-8 (expert ff=2048, dense-prefix ff=18432), vocab=129280,
MTP head.  bf16 params (§DESIGN memory policy).  [arXiv:2412.19437; hf]"""

from repro.models.config import BlockCfg, Group, MLACfg, ModelConfig
from repro.models.moe import MoEConfig

ARCH = "deepseek-v3-671b"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=7168, vocab=129280,
        groups=(
            Group("dense", (BlockCfg("mla", "dense"),), 3),
            Group("moe", (BlockCfg("mla", "moe"),), 58),
        ),
        n_heads=128, n_kv=128, head_dim=128, d_ff=18432,
        rope_theta=10000.0,
        mla=MLACfg(q_lora=1536, kv_lora=512, dh_nope=128, dh_rope=64,
                   dh_v=128),
        moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                      ep_degree=ep_degree),
        shared_expert=True, mtp=True,
        param_dtype="bfloat16",
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(
            Group("dense", (BlockCfg("mla", "dense"),), 1),
            Group("moe", (BlockCfg("mla", "moe"),), 2),
        ),
        n_heads=4, n_kv=4, head_dim=32, d_ff=256,
        mla=MLACfg(q_lora=64, kv_lora=32, dh_nope=32, dh_rope=16, dh_v=32),
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=6, top_k=2,
                      ep_degree=1),
        shared_expert=True, mtp=True, q_chunk=32,
        max_seq=256,
    )
