"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) ff=17408 vocab=151936,
qk_norm + GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "qwen3-14b"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=5120, vocab=151936,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 40),),
        n_heads=40, n_kv=8, head_dim=128, d_ff=17408,
        rope_theta=1_000_000.0, qk_norm=True,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 2),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        rope_theta=1_000_000.0, qk_norm=True, q_chunk=32,
        max_seq=256,
    )
