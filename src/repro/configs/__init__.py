"""Architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``config(ep_degree)`` (the exact published geometry)
and ``smoke_config()`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from . import (deepseek_v3_671b, gemma2_9b, granite_moe_3b, jamba_v01_52b,
               llama3_2_1b, llava_next_mistral_7b, mamba2_130m, qwen1_5_110b,
               qwen3_14b, whisper_base)
from .shapes import SHAPES, ShapeCell, applicable, input_specs

_MODULES = (qwen1_5_110b, llama3_2_1b, qwen3_14b, gemma2_9b, granite_moe_3b,
            deepseek_v3_671b, mamba2_130m, llava_next_mistral_7b,
            jamba_v01_52b, whisper_base)

REGISTRY: Dict[str, Tuple[Callable, Callable]] = {
    m.ARCH: (m.config, m.smoke_config) for m in _MODULES
}

ARCHS = tuple(REGISTRY)


def get_config(arch: str, *, smoke: bool = False, ep_degree: int = 16):
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    full, small = REGISTRY[arch]
    return small() if smoke else full(ep_degree=ep_degree)


__all__ = ["REGISTRY", "ARCHS", "get_config", "SHAPES", "ShapeCell",
           "applicable", "input_specs"]
