"""mamba2-130m [ssm]: 24L d=768 attn-free, ssm_state=128, vocab=50280,
SSD (state-space duality).  [arXiv:2405.21060; unverified]"""

from repro.models.config import BlockCfg, Group, ModelConfig
from repro.models.mamba import MambaConfig

ARCH = "mamba2-130m"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=768, vocab=50280,
        groups=(Group("body", (BlockCfg("mamba", "none"),), 24),),
        n_heads=12, n_kv=12,  # unused (attn-free)
        mamba=MambaConfig(d_model=768, d_state=128, expand=2, head_dim=64,
                          n_groups=1, chunk=128),
        tie_embeddings=True, pos_embed="none",
        max_seq=1 << 20,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("mamba", "none"),), 2),),
        n_heads=4, n_kv=4,
        mamba=MambaConfig(d_model=128, d_state=16, expand=2, head_dim=32,
                          n_groups=1, chunk=32),
        tie_embeddings=True, pos_embed="none",
        max_seq=256,
    )
