"""qwen1.5-110b [dense]: 80L d=8192 64H (GQA kv=8) ff=49152 vocab=152064,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "qwen1.5-110b"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=8192, vocab=152064,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 80),),
        n_heads=64, n_kv=8, head_dim=128, d_ff=49152,
        rope_theta=1_000_000.0, qkv_bias=True,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 2),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        rope_theta=1_000_000.0, qkv_bias=True, q_chunk=32,
        max_seq=256,
    )
