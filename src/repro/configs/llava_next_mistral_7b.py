"""llava-next-mistral-7b [vlm]: mistral-7B backbone (32L d=4096 32H GQA
kv=8 ff=14336 vocab=32000); vision frontend is a STUB — ``input_specs``
provides 576 precomputed patch embeddings (anyres tiling happens before
the backbone).  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "llava-next-mistral-7b"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=4096, vocab=32000,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 32),),
        n_heads=32, n_kv=8, head_dim=128, d_ff=14336,
        rope_theta=1_000_000.0,
        modality="vision", stub_prefix=576,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "dense"),), 2),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        modality="vision", stub_prefix=16, q_chunk=32,
        max_seq=256,
    )
