"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) expert-ff=512
vocab=49155, MoE 40 experts top-8 (the spec header's 40e; the HF card's
sibling model uses 32e — we follow the header and note the discrepancy in
DESIGN.md).  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import BlockCfg, Group, ModelConfig
from repro.models.moe import MoEConfig

ARCH = "granite-moe-3b-a800m"


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=1536, vocab=49155,
        groups=(Group("body", (BlockCfg("attn", "moe"),), 32),),
        n_heads=24, n_kv=8, head_dim=64, d_ff=512,
        rope_theta=10000.0, tie_embeddings=True,
        moe=MoEConfig(d_model=1536, d_ff=512, n_experts=40, top_k=8,
                      ep_degree=ep_degree),
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "moe"),), 2),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=64,
        tie_embeddings=True, q_chunk=32,
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=6, top_k=2,
                      ep_degree=1),
        max_seq=256,
    )
