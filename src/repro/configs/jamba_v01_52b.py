"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) ff=14336
vocab=65536, Mamba:attention 7:1 interleave (attention at position 4 of
each 8-layer period), MoE 16 experts top-2 on every other layer, no
positional embeddings.  [arXiv:2403.19887; hf]

Adaptation note (DESIGN.md §4): Jamba v0.1 uses Mamba-1 (S6); this repo's
SSM mixer is the SSD (Mamba-2) formulation with Jamba's d_state=16 — the
layer pattern, widths and parallelism are what this cell reproduces.
"""

from repro.models.config import BlockCfg, Group, ModelConfig
from repro.models.mamba import MambaConfig
from repro.models.moe import MoEConfig


ARCH = "jamba-v0.1-52b"


def _unit(window=None):
    blocks = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        blocks.append(BlockCfg(mixer, ffn, window=window))
    return tuple(blocks)


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=4096, vocab=65536,
        groups=(Group("body", _unit(), 4),),
        n_heads=32, n_kv=8, head_dim=128, d_ff=14336,
        pos_embed="none",
        mamba=MambaConfig(d_model=4096, d_state=16, expand=2, head_dim=64,
                          n_groups=1, chunk=128),
        moe=MoEConfig(d_model=4096, d_ff=14336, n_experts=16, top_k=2,
                      ep_degree=ep_degree),
        max_seq=1 << 20,
    )


def smoke_config() -> ModelConfig:
    blocks = (BlockCfg("mamba", "dense"), BlockCfg("mamba", "moe"),
              BlockCfg("attn", "dense"), BlockCfg("mamba", "moe"))
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", blocks, 1),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        pos_embed="none", q_chunk=32,
        mamba=MambaConfig(d_model=128, d_state=16, expand=2, head_dim=32,
                          n_groups=1, chunk=32),
        moe=MoEConfig(d_model=128, d_ff=64, n_experts=4, top_k=2,
                      ep_degree=1),
        max_seq=256,
    )
