"""gemma2-9b [dense]: 42L d=3584 16H (GQA kv=8) ff=14336 vocab=256000,
local(4096)+global alternating, attn softcap 50, logit softcap 30,
sandwich norms, scaled embeddings.  [arXiv:2408.00118; hf]"""

from repro.models.config import BlockCfg, Group, ModelConfig

ARCH = "gemma2-9b"
WINDOW = 4096


def config(ep_degree: int = 16) -> ModelConfig:
    return ModelConfig(
        name=ARCH, d_model=3584, vocab=256000,
        groups=(Group("body", (BlockCfg("attn", "dense", window=WINDOW),
                               BlockCfg("attn", "dense")), 21),),
        n_heads=16, n_kv=8, head_dim=256, d_ff=14336,
        rope_theta=10000.0, attn_softcap=50.0, logit_softcap=30.0,
        post_norms=True, scale_embed=True, tie_embeddings=True,
        max_seq=32768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", d_model=128, vocab=512,
        groups=(Group("body", (BlockCfg("attn", "dense", window=32),
                               BlockCfg("attn", "dense")), 1),),
        n_heads=4, n_kv=2, head_dim=32, d_ff=256,
        attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
        scale_embed=True, tie_embeddings=True, q_chunk=32,
        max_seq=256,
    )
