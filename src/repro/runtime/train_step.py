"""Train/serve step builders: shardings, optimizer wiring, LPF grad sync.

Two gradient-sync modes:

* ``gspmd`` — pure jit: GSPMD inserts the reduce-scatter/all-reduce
  pattern implied by the parameter shardings (the optimised baseline).
* ``lpf``   — the step runs *manual over the pod axis* (partial
  shard_map): backward produces pod-local gradients, and the DCN hop is
  an explicit LPF superstep program (``bsp.pod_sync``) honouring sync
  attributes (int8 compression; staleness is handled by the local-SGD
  outer loop which simply skips the sync).  Intra-pod reduction stays on
  GSPMD/ICI — a two-level hierarchical all-reduce.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.bsp.pod_sync import pod_allreduce
from repro.core import CostLedger, LPF_SYNC_DEFAULT, SyncAttributes, compat
from repro.models import Runtime, init_params, loss_fn, decode_step, init_caches
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding.rules import batch_specs, cache_specs, param_specs
from repro.launch.mesh import dp_axes_of, model_axis_of

__all__ = ["TrainStep", "build_train_step", "ServeStep", "build_serve_step",
           "build_serve_buckets"]


def _split_scan_layers(grads: dict, cfg: ModelConfig):
    """Split stacked scan-group gradient leaves ``[L, ...]`` into L
    per-layer subtrees, so bucket boundaries (``bucketize`` packs leaves
    greedily, never splitting one) can fall on layer boundaries — the
    granularity at which the backward pass actually materialises
    gradients.  Returns the split tree plus the set of keys to restack.
    Leaves whose leading dim is not the group's repeat count (or groups
    of one repeat) pass through unsplit."""
    repeats = {f"dec_{g.name}": g.repeats for g in cfg.groups}
    repeats.update({f"enc_{g.name}": g.repeats for g in cfg.encoder_groups})
    split, split_keys = {}, set()
    for key, sub in grads.items():
        r = repeats.get(key, 0)
        if r > 1:
            leaves = jax.tree_util.tree_flatten(sub)[0]
            if leaves and all(l.ndim >= 1 and l.shape[0] == r
                              for l in leaves):
                split[key] = [jax.tree.map(lambda l: l[i], sub)
                              for i in range(r)]
                split_keys.add(key)
                continue
        split[key] = sub
    return split, split_keys


def _restack_scan_layers(split: dict, split_keys) -> dict:
    return {key: jax.tree.map(lambda *xs: jnp.stack(xs), *sub)
            if key in split_keys else sub
            for key, sub in split.items()}


@dataclasses.dataclass
class TrainStep:
    """Compiled pieces + specs (also consumed by dryrun/roofline)."""
    step_fn: Any                 # (params, opt, batch) -> (params, opt, metrics)
    init_fn: Any                 # (key) -> (params, opt)
    param_sharding: Any
    opt_sharding: Any
    batch_sharding: Any
    rt: Runtime
    ledger: CostLedger


def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_train_step(cfg: ModelConfig, mesh, *,
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     grad_sync: str = "gspmd",
                     sync_attrs: SyncAttributes = LPF_SYNC_DEFAULT,
                     grad_sync_method: str = "auto",
                     grad_bucket_bytes: Optional[int] = None,
                     grad_accum: int = 1,
                     axis_roles: str = "fsdp_tp",
                     donate: bool = True,
                     steps_per_call: int = 1) -> TrainStep:
    dp = dp_axes_of(mesh)
    if axis_roles == "dp_all":
        # axis-role remap for small models: the model axis carries extra
        # data parallelism; params keep ZeRO over `data` only
        batch_axes = tuple(a for a in ("pod", "data", "model")
                           if a in mesh.axis_names)
        param_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
        rt = Runtime(mesh, dp_axes=batch_axes, model_axis=None, sp=False)
    else:
        batch_axes = dp
        param_axes = None
        rt = Runtime(mesh, dp_axes=dp, model_axis=model_axis_of(mesh),
                     sp=True)
    ledger = CostLedger()
    key = jax.random.PRNGKey(0)

    p_shapes = jax.eval_shape(partial(init_params, cfg=cfg), key)
    pspecs = param_specs(p_shapes, mesh, axes=param_axes)
    o_shapes = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_shapes)
    ospecs = param_specs(o_shapes, mesh, axes=param_axes)

    npods = mesh.shape.get("pod", 1)

    def constrain_grads(grads):
        # pin gradients to the parameter sharding so the FSDP
        # reduce-scatter happens inside the layer loop, not as a giant
        # unsharded stacked buffer afterwards
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, s)), grads, pspecs)

    def loss_and_grads(params, batch, rt_=None, constrain=True):
        """Microbatched (gradient-accumulated) loss/grads: activation
        memory scales by 1/k at unchanged arithmetic — how the widest
        configs fit 16 GB/chip at global batch 256."""
        rt_ = rt_ or rt
        cg = constrain_grads if constrain else (lambda g: g)
        if grad_accum <= 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, rt_))(params)
            return loss, cg(grads)

        micro = jax.tree.map(
            lambda l: l.reshape((grad_accum, l.shape[0] // grad_accum)
                                + l.shape[1:]), batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, mb, cfg, rt_))(params)
            grads = cg(grads)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                 g_acc, grads)
            return (loss_acc + loss, g_acc), None

        g0 = cg(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, g_sum), _ = jax.lax.scan(
            acc_step, (jnp.zeros((), jnp.float32), g0), micro)
        k = float(grad_accum)
        return loss_sum / k, jax.tree.map(lambda g: g / k, g_sum)

    def plain_step(params, opt, batch):
        loss, grads = loss_and_grads(params, batch)
        params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt, metrics

    if grad_sync == "lpf" and npods > 1:
        # XLA workaround: with_sharding_constraint over Auto axes inside a
        # partial-manual (pod) region CHECK-fails in the SPMD partitioner
        # (spmd_partitioner_util.cc:504, verified by bisection), so the
        # loss runs without internal activation constraints here; GSPMD
        # propagates shardings freely.  The gspmd baseline path (and the
        # whole dry-run matrix) keeps the constraints + SP.
        rt_pod = Runtime()

        def pod_body(params, opt, batch):
            loss, grads = loss_and_grads(params, batch, rt_pod,
                                         constrain=False)
            # default ``auto`` picks the overlapped bucket pipeline when
            # ``grad_bucket_bytes`` is set (bucket k+1's reduce-scatter
            # under bucket k's all-gather), one fused reduce-scatter+
            # all-gather pair for uncompressed gradients otherwise, and
            # lax.psum rings under compression
            bucketing = grad_bucket_bytes is not None and \
                grad_sync_method in ("auto", "bucketed", "bucketed_fenced",
                                     "bucketed_overlap")
            if bucketing:
                # thread bucket boundaries through the scan-layer
                # structure: stacked [L, ...] gradient leaves split into
                # per-layer leaves so buckets align with the layers the
                # backward pass produces one by one
                gsplit, keys = _split_scan_layers(grads, cfg)
                gsplit = pod_allreduce(gsplit, npods, "pod",
                                       attrs=sync_attrs, mean=True,
                                       ledger=ledger,
                                       method=grad_sync_method,
                                       bucket_bytes=grad_bucket_bytes)
                grads = _restack_scan_layers(gsplit, keys)
            else:
                grads = pod_allreduce(grads, npods, "pod",
                                      attrs=sync_attrs, mean=True,
                                      ledger=ledger,
                                      method=grad_sync_method,
                                      bucket_bytes=grad_bucket_bytes)
            loss = jax.lax.pmean(loss, "pod")
            params, opt, metrics = adamw_update(grads, opt, params, opt_cfg)
            metrics["loss"] = loss
            return params, opt, metrics

        rep = lambda tree: jax.tree.map(lambda _: P(), tree)

        def step_core(params, opt, batch):
            bspecs = jax.tree.map(
                lambda l: P("pod", *([None] * (l.ndim - 1))), batch)
            fn = compat.shard_map(
                pod_body, mesh=mesh,
                in_specs=(rep(params), rep(opt), bspecs),
                out_specs=(rep(params), rep(opt),
                           {"grad_norm": P(), "lr": P(), "loss": P()}),
                axis_names={"pod"}, check_vma=False)
            return fn(params, opt, batch)
    else:
        step_core = plain_step

    p_shard = _shardings(pspecs, mesh)
    o_shard = _shardings(ospecs, mesh)

    def make_batch_sharding(batch_shapes):
        return _shardings(batch_specs(batch_shapes, mesh,
                                      dp_axes=batch_axes), mesh)

    def init_fn(k):
        params = init_params(k, cfg)
        return params, adamw_init(params, opt_cfg)

    init_jit = jax.jit(init_fn, out_shardings=(p_shard, o_shard))

    if steps_per_call > 1:
        # roll K optimizer steps into ONE jitted call: the batch gains a
        # leading [K] dim and the per-step sync program replays inside a
        # single XLA While — K steps' worth of supersteps at one Python
        # dispatch (and one ledger trace)
        def multi_core(params, opt, batches):
            def one(carry, batch):
                p_, o_ = carry
                p_, o_, m = step_core(p_, o_, batch)
                return (p_, o_), m
            (params, opt), metrics = compat.scan(one, (params, opt),
                                                 batches)
            return params, opt, metrics   # metrics leaves are [K]
        core = multi_core
    else:
        core = step_core

    step_jit = jax.jit(
        core,
        donate_argnums=(0, 1) if donate else (),
        in_shardings=(p_shard, o_shard, None),
        out_shardings=(p_shard, o_shard, None),
    )
    return TrainStep(step_fn=step_jit, init_fn=init_jit,
                     param_sharding=p_shard, opt_sharding=o_shard,
                     batch_sharding=make_batch_sharding, rt=rt,
                     ledger=ledger)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeStep:
    step_fn: Any                 # (params, caches, token, pos[, enc]) -> ...
    param_sharding: Any
    cache_sharding: Any
    rt: Runtime
    # (n_tokens) -> jitted (params, caches, tok0, pos0[, enc]) ->
    # (toks [T, B], caches): the whole decode loop as ONE XLA While
    # instead of a Python-dispatched step per token; memoized per length
    decode_fn: Any = None


def build_serve_step(cfg: ModelConfig, mesh, *, global_batch: int,
                     cache_len: int,
                     batch_axes: Optional[Tuple[str, ...]] = None,
                     seq_axes: Optional[Tuple[str, ...]] = None,
                     param_axes: Optional[Tuple[str, ...]] = None,
                     donate_cache: bool = True) -> ServeStep:
    axes = tuple(mesh.axis_names)
    if batch_axes is None:
        dp = dp_axes_of(mesh)
        total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        batch_axes = dp if dp and global_batch % total == 0 else ()
    if seq_axes is None:
        seq_axes = ("model",) if "model" in axes else ()
        if not batch_axes:   # batch can't shard -> widen sequence sharding
            seq_axes = tuple(a for a in ("pod", "data", "model")
                             if a in axes)
    rt = Runtime(mesh, dp_axes=batch_axes, model_axis=model_axis_of(mesh),
                 seq_axes=seq_axes)

    p_shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    pspecs = param_specs(p_shapes, mesh, axes=param_axes)
    c_shapes = jax.eval_shape(
        lambda: init_caches(cfg, global_batch, cache_len))
    cspecs = cache_specs(c_shapes, mesh, batch_axes=batch_axes,
                         seq_axes=seq_axes)
    p_shard = _shardings(pspecs, mesh)
    c_shard = _shardings(cspecs, mesh)
    tok_shard = NamedSharding(mesh, P(batch_axes or None))
    pos_shard = NamedSharding(mesh, P())

    def serve(params, caches, token, pos, enc_out=None):
        nxt, logits, new_caches = decode_step(params, token, caches, pos,
                                              cfg, rt, enc_out)
        return nxt, new_caches

    in_sh = [p_shard, c_shard, tok_shard, pos_shard]
    if cfg.encoder_groups:
        in_sh.append(NamedSharding(mesh, P(batch_axes or None, None, None)))
    step_jit = jax.jit(
        serve,
        donate_argnums=(1,) if donate_cache else (),
        in_shardings=tuple(in_sh),
        out_shardings=(tok_shard, c_shard),
    )

    toks_shard = NamedSharding(mesh, P(None, batch_axes or None))
    _decode_cache: dict = {}

    def decode_fn(n_tokens: int):
        """Jitted whole-sequence greedy decode: scan the per-token step
        ``n_tokens`` times in one XLA computation (body traced once)."""
        fn = _decode_cache.get(n_tokens)
        if fn is not None:
            return fn

        def decode(params, caches, tok0, pos0, enc_out=None):
            def one(carry, _):
                tok, caches, pos = carry
                nxt, _, caches = decode_step(params, tok, caches, pos,
                                             cfg, rt, enc_out)
                return (nxt, caches, pos + 1), nxt

            (_, caches, _), toks = compat.scan(
                one, (tok0, caches, pos0), None, length=n_tokens)
            return toks, caches   # toks [n_tokens, B]

        fn = jax.jit(
            decode,
            donate_argnums=(1,) if donate_cache else (),
            in_shardings=tuple(in_sh),
            out_shardings=(toks_shard, c_shard),
        )
        _decode_cache[n_tokens] = fn
        return fn

    return ServeStep(step_fn=step_jit, param_sharding=p_shard,
                     cache_sharding=c_shard, rt=rt, decode_fn=decode_fn)


def build_serve_buckets(cfg: ModelConfig, mesh,
                        buckets: Sequence[Tuple[int, int]],
                        **kwargs) -> Dict[Tuple[int, int], ServeStep]:
    """Build the continuous-batching server's decode buckets: one
    :class:`ServeStep` per ``(global_batch, cache_len)`` shape.  Each
    bucket owns its jitted per-token step and memoized fused decode
    variants (``decode_fn(n)``); the server routes admitted requests to
    the bucket whose shape they fit and replays that bucket's programs.
    Cache state is per-bucket too — buckets never share KV buffers, so
    quarantining one bucket's fused path cannot corrupt another's."""
    out: Dict[Tuple[int, int], ServeStep] = {}
    for batch, cache_len in buckets:
        out[(batch, cache_len)] = build_serve_step(
            cfg, mesh, global_batch=batch, cache_len=cache_len, **kwargs)
    return out
