"""Straggler detection from BSP superstep timing.

Bulk-synchrony makes stragglers *observable*: every step ends at a
barrier, so per-step wall time is exactly max over workers of their work
time.  The monitor keeps an EWMA mean/variance of step durations and
flags z-score outliers; the mitigation policy escalates:

  observe -> flag (log) -> skip-sync (stale step, bounded count) ->
  request elastic rescale (drop the worker, restore on a smaller mesh).

On the CPU container we obviously host one worker; the detector is
exercised in tests by injecting synthetic delays, and the policy output
feeds ``train_loop``'s recovery path.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, Optional

__all__ = ["StragglerMonitor", "StepVerdict", "cache_metrics"]


def cache_metrics(ctx) -> Dict[str, int]:
    """Flatten a context's memo-layer counters into one metrics dict.

    Keys are ``<layer>_<counter>`` (``plan_hits``, ``program_misses``,
    ``program_disk_hits``, ...) so the result can go straight into a
    scalar metric pipeline next to the straggler verdicts.  The program
    layer's disk counters are the persistent-cache health signal:
    ``program_disk_hits`` > 0 with ``program_misses`` == 0 is a clean
    warm start; a growing ``program_invalidated`` means the cache
    directory is stale or corrupt and is being re-built.

    Beyond the per-layer :class:`~repro.core.sync.CacheStats` fields
    (which already carry the degradation counters ``disk_errors`` and
    ``compile_fallbacks``), the program layer exports its ladder state:
    ``program_memory_only`` (1 = the persistent store was detached
    after repeated I/O failures — ``ProgramCache.memory_only_reason``
    holds the why), ``program_quarantined`` (signatures whose
    whole-program compile failed; replays run dispatched), ``program_
    pinned`` (eviction-exempt serving hot set) and ``program_entries``
    (resident programs).  A health snapshot built from this dict sees
    every rung of PR 9's degradation ladder without reaching into
    cache internals.
    """
    out: Dict[str, int] = {}
    for layer, stats in sorted(ctx.cache_stats.items()):
        for f in dataclasses.fields(stats):
            out[f"{layer}_{f.name}"] = getattr(stats, f.name)
    pc = getattr(ctx, "program_cache", None)
    if pc is not None:
        out["program_entries"] = len(pc)
        out["program_memory_only"] = int(pc.memory_only_reason is not None)
        out["program_quarantined"] = sum(
            len(axes) for axes in pc._quarantined.values())
        out["program_pinned"] = len(pc.pinned)
    return out


@dataclasses.dataclass
class StepVerdict:
    step: int
    duration: float
    z: float
    straggle: bool
    action: str          # "ok" | "flag" | "skip_sync" | "rescale"


class StragglerMonitor:
    #: default verdict-history ring capacity.  The history is a
    #: debugging/reporting surface, not the detector state (the EWMA
    #: is O(1)); unbounded growth was an OOM for long-running servers,
    #: which record one verdict per decode batch indefinitely.
    HISTORY_CAP = 4096

    def __init__(self, alpha: float = 0.1, z_flag: float = 3.0,
                 z_skip: float = 6.0, max_skips: int = 3,
                 warmup: int = 5, history_cap: Optional[int] = None):
        self.alpha = alpha
        self.z_flag = z_flag
        self.z_skip = z_skip
        self.max_skips = max_skips
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.consecutive_skips = 0
        #: bounded ring of recent verdicts (oldest dropped first)
        self.history: Deque[StepVerdict] = collections.deque(
            maxlen=self.HISTORY_CAP if history_cap is None
            else history_cap)

    def record(self, step: int, duration: float) -> StepVerdict:
        self.n += 1
        if self.mean is None:
            self.mean = duration
            v = StepVerdict(step, duration, 0.0, False, "ok")
            self.history.append(v)
            return v
        # relative floor: sub-10%-of-mean jitter is never a straggle
        std = max(math.sqrt(self.var) if self.var > 0 else 0.0,
                  0.1 * abs(self.mean))
        if std <= 0.0:
            # zero-mean/zero-variance stream (e.g. mocked clocks): any
            # on-model duration scores 0; only a genuine excursion above
            # the degenerate mean is an outlier.  Dividing by an epsilon
            # here would turn float noise into z ~ 1e9.
            z = 0.0 if duration <= self.mean else math.inf
        else:
            z = (duration - self.mean) / std
        straggle = self.n > self.warmup and z > self.z_flag
        if straggle and self.n > self.warmup and z > self.z_skip:
            self.consecutive_skips += 1
            action = ("rescale" if self.consecutive_skips > self.max_skips
                      else "skip_sync")
        elif straggle:
            action = "flag"
            self.consecutive_skips = 0
        else:
            action = "ok"
            self.consecutive_skips = 0
        # update EWMA only with non-outlier steps (don't poison the model)
        if not straggle:
            d = duration - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        v = StepVerdict(step, duration, z, straggle, action)
        self.history.append(v)
        return v
