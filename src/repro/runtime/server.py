"""Hardened continuous-batching serve loop with model-priced admission.

The paper's thesis is that a strict performance model makes
communication costs *predictable*; this module is where predictability
becomes a robustness tool.  Every decode bucket's per-token superstep
program carries a predicted ledger cost (``SuperstepCost
.predicted_seconds`` summed over the recorded program — the same
quantity the schedule search minimises), so a request's service time
can be priced **before** it is admitted.  Deadlines are therefore
promises, not hopes: the admission controller proves, on the model
clock, that the request can finish in time, or rejects it at the door
with a classified reason — never a mid-decode timeout.

Model clock
-----------
The server keeps a *virtual clock* in model seconds: each decoded
batch advances it by the batch program's ledger cost.  Deadlines and
SLO accounting run on this clock — deterministic, reproducible, and
exactly the quantity the LPF machine ``(g, l)`` promises — while wall
times are recorded alongside for reporting.  Because every executed
superstep ledgers exactly its predicted cost (the repo-wide model
compliance invariant), "admitted implies completion before deadline"
is a theorem on the model clock, checked per request.

Admission bound
---------------
A request needing ``n`` tokens from bucket ``b`` is priced at::

    c(b, n) = overhead(b) + token_seconds(b) * round_tokens(b, n)

and admitted iff ``vclock + sum(c of queued) + c(b, n) <= deadline``.
The bound is sound because batches are led by the earliest-admitted
queued request, a joining member never extends the leader's decode
length, and one batch costs at most its leader's ``c`` — so the queue
drains no slower than the sum of per-request bounds.

Degradation ladder (overload)
-----------------------------
  0. normal — admission prices into the highest-throughput bucket;
  1. **shrink** — new requests route to the smallest batch bucket
     (lower per-batch latency, lower throughput);
  2. **shed** — lowest-priority / latest-deadline queued work is
     dropped with a classified reason until the queue recovers;
  3. **reject** — a full queue (backpressure) or a backlog past the
     configured bound rejects at admission.

Failure hardening
-----------------
``serve_admit`` / ``serve_decode`` fault seams (:mod:`repro.core
.faultpoints`) let the chaos harness inject infrastructure failures at
admission and decode time.  The invariant, proved by the seeded serve
soak: under any fault-plus-overload plan every request either
completes with numerics bit-identical to the unloaded baseline, or is
rejected/shed with a classified :class:`~repro.core.errors.LPFError`
— the server itself never dies.  Decode failures quarantine the
bucket's fused path and retry once on the per-token fallback (PR 9's
taxonomy: transient faults are retried, contract violations are not
degraded around); compile failures inside the engine ride the
existing compiled-to-dispatched ladder with the ledger bit-for-bit.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

from ..core import faultpoints as _fp
from ..core.errors import LPFError, classify
from .monitor import StragglerMonitor, cache_metrics

__all__ = ["Bucket", "ServeRequest", "ServeOutcome", "ServeRejected",
           "ServeMetrics", "LPFServer", "ProgramDecodeEngine",
           "synthetic_requests"]

#: a decode bucket: (batch rows, cache length == token capacity)
Bucket = Tuple[int, int]

#: rejection / shed reason codes (the classified taxonomy of refusals)
REASONS = ("queue_full", "overloaded", "deadline_unmeetable",
           "no_bucket", "draining", "admit_fault", "decode_failed",
           "shed_overload")


class ServeRejected(LPFError):
    """A classified refusal: the server declined (or abandoned) a
    request *before* violating any promise — at admission (queue
    full, unmeetable deadline, overload, drain), by shedding under
    overload, or after the decode fallback ladder was exhausted.
    Carries the machine-readable ``reason`` code and, for fault-driven
    refusals, the classified ``cause``."""

    def __init__(self, reason: str, message: str,
                 cause: Optional[BaseException] = None):
        super().__init__(message)
        if reason not in REASONS:
            raise ValueError(f"unknown reject reason {reason!r}")
        self.reason = reason
        self.cause = cause


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One decode request: ``n_tokens`` greedy tokens wanted within
    ``deadline_s`` model-seconds of submission.  ``seed`` determines
    the request's payload (and therefore its token stream) — results
    must be a pure function of the request, never of its batchmates."""

    rid: int
    n_tokens: int
    deadline_s: float
    priority: int = 0
    #: minimum cache length the request needs (0 = any bucket whose
    #: token capacity fits ``n_tokens``)
    cache_len: int = 0
    seed: int = 0


@dataclasses.dataclass
class ServeOutcome:
    """The terminal record of one request's life in the server."""

    rid: int
    status: str                      # admitted | completed | rejected | shed
    reason: Optional[str] = None     # REASONS code for rejected/shed
    error: Optional[LPFError] = None
    tokens: Optional[Tuple[int, ...]] = None
    bucket: Optional[Bucket] = None
    admit_v: float = 0.0             # model clock at admission
    deadline_v: float = 0.0          # absolute model-clock deadline
    predicted_v: float = 0.0         # admission's completion bound
    completion_v: float = 0.0        # model clock at completion
    wall_s: float = 0.0              # wall time submit -> terminal
    fallback: bool = False           # served by the per-token path

    @property
    def classified(self) -> bool:
        """Refusals must carry a classified LPFError — the chaos
        invariant's acceptable non-completion."""
        return isinstance(self.error, LPFError)


@dataclasses.dataclass
class ServeMetrics:
    """Service counters for the health snapshot (all monotonic)."""

    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    shed: int = 0
    deadline_misses: int = 0         # admitted requests past deadline_v
    batches: int = 0
    tokens_decoded: int = 0
    decode_fallbacks: int = 0        # batches retried on per-token path
    decode_failures: int = 0         # batches failed after the ladder
    unclassified_errors: int = 0     # non-LPF causes wrapped (bug signal)
    queue_peak: int = 0
    level_peak: int = 0
    rejected: Dict[str, int] = dataclasses.field(
        default_factory=lambda: collections.Counter())

    def snapshot(self) -> Dict[str, int]:
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self) if f.name != "rejected"}
        out["rejected_total"] = sum(self.rejected.values())
        for reason, n in sorted(self.rejected.items()):
            out[f"rejected_{reason}"] = n
        return out


@dataclasses.dataclass
class _Ticket:
    req: ServeRequest
    bucket: Bucket
    cost_s: float                    # admission cost bound c(b, n)
    admit_v: float
    deadline_v: float
    predicted_v: float
    wall_t0: float


class LPFServer:
    """The hardened serve loop (see module docstring).

    ``engine`` provides the decode buckets and the model pricing —
    anything with this duck-typed surface works (the pure-LPF
    :class:`ProgramDecodeEngine`, the model engine in
    ``repro.launch.serve``, or a test fake):

    * ``buckets() -> Sequence[Bucket]``
    * ``token_seconds(bucket) / overhead_seconds(bucket) -> float``
    * ``round_tokens(bucket, n) -> int`` (decode-length bucketing)
    * ``decode(bucket, reqs, n_tokens) -> {rid: (int tokens...)}``
    * ``ledger_seconds(bucket, n_tokens) -> float``
    * ``quarantine(bucket)`` — force the per-token fallback path
    * optional ``flush() -> int`` and ``cache_stats``/``program_cache``
      (for :func:`~repro.runtime.monitor.cache_metrics`)

    The loop is deliberately synchronous and single-threaded:
    ``submit`` admits, ``step`` decodes one batch, ``drain`` finishes
    everything.  Determinism is what lets the chaos soak compare runs
    bit-for-bit; a thread/asyncio front-end can pump this object
    without changing its semantics.
    """

    def __init__(self, engine, *, max_queue: int = 64,
                 shrink_frac: float = 0.5, shed_frac: float = 0.8,
                 reject_backlog_s: Optional[float] = None,
                 monitor: Optional[StragglerMonitor] = None):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if not (0.0 < shrink_frac <= shed_frac <= 1.0):
            raise ValueError("need 0 < shrink_frac <= shed_frac <= 1")
        self.engine = engine
        self.max_queue = max_queue
        self.shrink_frac = shrink_frac
        self.shed_frac = shed_frac
        self.reject_backlog_s = reject_backlog_s
        self.vclock = 0.0
        self.queue: Deque[_Ticket] = collections.deque()
        self.metrics = ServeMetrics()
        self.monitor = monitor if monitor is not None \
            else StragglerMonitor(warmup=3)
        self.draining = False
        #: terminal outcomes by rid; callers consume via
        #: :meth:`take_outcomes` (a long-running front-end must drain
        #: this, the same boundedness contract as a response queue)
        self.outcomes: Dict[int, ServeOutcome] = {}
        self._buckets = tuple(sorted(engine.buckets()))
        if not self._buckets:
            raise ValueError("engine exposes no decode buckets")

    # ------------------------------------------------------------------
    # ladder state
    # ------------------------------------------------------------------
    @property
    def level(self) -> int:
        """Current degradation rung from queue utilisation: 0 normal,
        1 shrink, 2 shed (3, reject, is a per-request decision)."""
        u = len(self.queue) / self.max_queue
        if u >= self.shed_frac:
            return 2
        if u >= self.shrink_frac:
            return 1
        return 0

    def backlog_seconds(self) -> float:
        """Sum of queued admission cost bounds — the model-priced work
        ahead of a new arrival."""
        return sum(t.cost_s for t in self.queue)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _bucket_for(self, req: ServeRequest) -> Optional[Bucket]:
        """Cheapest feasible bucket: smallest sufficient cache length;
        within it the largest batch (throughput) at level 0, the
        smallest (latency — the *shrink* rung) under overload."""
        feas = [b for b in self._buckets
                if b[1] >= max(req.n_tokens, req.cache_len)]
        if not feas:
            return None
        min_c = min(b[1] for b in feas)
        feas = [b for b in feas if b[1] == min_c]
        return min(feas) if self.level >= 1 else max(feas)

    def cost_bound_s(self, bucket: Bucket, n_tokens: int) -> float:
        """The admission price ``c(b, n)`` (module docstring)."""
        return (self.engine.overhead_seconds(bucket)
                + self.engine.token_seconds(bucket)
                * self.engine.round_tokens(bucket, n_tokens))

    def _reject(self, req: ServeRequest, reason: str, msg: str,
                cause: Optional[BaseException] = None,
                status: str = "rejected") -> ServeOutcome:
        err = ServeRejected(reason, msg, cause)
        out = ServeOutcome(rid=req.rid, status=status, reason=reason,
                           error=err, admit_v=self.vclock,
                           deadline_v=self.vclock + req.deadline_s)
        if status == "shed":
            self.metrics.shed += 1
        else:
            self.metrics.rejected[reason] += 1
        if cause is not None and not isinstance(
                cause, (LPFError, OSError, TimeoutError)) \
                and type(cause).__name__ != "InjectedFault":
            self.metrics.unclassified_errors += 1
        self.outcomes[req.rid] = out
        return out

    def _shed_for(self, incoming: _Ticket) -> bool:
        """The *shed* rung: drop the worst queued ticket — lowest
        priority, then latest deadline — until the queue is back under
        the shed threshold.  The incoming ticket competes on the same
        ranking; ``False`` means it lost and must be rejected."""
        limit = max(1, int(self.shed_frac * self.max_queue))
        while len(self.queue) + 1 > limit:
            worst = min(self.queue,
                        key=lambda t: (t.req.priority, -t.deadline_v))
            wkey = (worst.req.priority, -worst.deadline_v)
            ikey = (incoming.req.priority, -incoming.deadline_v)
            if ikey <= wkey:
                return False          # the newcomer is the worst: reject
            self.queue.remove(worst)
            out = self._reject(
                worst.req, "shed_overload",
                f"shed under overload (level 2): priority="
                f"{worst.req.priority} deadline_v={worst.deadline_v:.6f}",
                status="shed")
            out.bucket = worst.bucket
            out.admit_v = worst.admit_v
            out.deadline_v = worst.deadline_v
            out.predicted_v = worst.predicted_v
            out.wall_s = time.perf_counter() - worst.wall_t0
        return True

    def submit(self, req: ServeRequest) -> ServeOutcome:
        """Admit or refuse ``req``.  Returns the admission outcome:
        ``status == "admitted"`` (terminal outcome arrives in
        :attr:`outcomes` when the request completes or is shed) or a
        terminal classified refusal.  Never raises for a per-request
        problem — robustness means the loop survives its inputs."""
        self.metrics.submitted += 1
        wall_t0 = time.perf_counter()
        if self.draining:
            return self._reject(req, "draining",
                                "server is draining; not admitting")
        # the admission fault seam: an injected infrastructure failure
        # here must classify and refuse, never propagate
        try:
            _fp.fire("serve_admit", rid=req.rid)
        except Exception as e:                    # noqa: BLE001
            return self._reject(
                req, "admit_fault",
                f"admission fault ({classify(e)}): "
                f"{type(e).__name__}: {e}", cause=e)
        if req.n_tokens < 1:
            return self._reject(req, "no_bucket",
                                "request decodes zero tokens")
        bucket = self._bucket_for(req)
        if bucket is None:
            return self._reject(
                req, "no_bucket",
                f"no bucket fits n_tokens={req.n_tokens} "
                f"cache_len>={req.cache_len} "
                f"(buckets: {list(self._buckets)})")
        # rung 3a — backpressure: a bounded queue refuses, it does not
        # grow; the client sees the refusal immediately
        if len(self.queue) >= self.max_queue:
            return self._reject(
                req, "queue_full",
                f"queue at capacity ({self.max_queue}); backpressure")
        cost = self.cost_bound_s(bucket, req.n_tokens)
        ticket = _Ticket(req=req, bucket=bucket, cost_s=cost,
                         admit_v=self.vclock,
                         deadline_v=self.vclock + req.deadline_s,
                         predicted_v=0.0, wall_t0=wall_t0)
        # rung 2 — shed: over the shed threshold the worst queued work
        # is dropped (classified) to keep room for better work
        if self.level >= 2 and not self._shed_for(ticket):
            return self._reject(
                req, "overloaded",
                "overloaded (level 2) and the request ranks below "
                "all queued work")
        # rung 3b — backlog bound: even meetable deadlines are refused
        # past the configured model-seconds backlog (wall-clock and
        # memory protection for the pathological all-loose-deadlines
        # arrival pattern)
        backlog = self.backlog_seconds()
        if self.reject_backlog_s is not None \
                and backlog + cost > self.reject_backlog_s:
            return self._reject(
                req, "overloaded",
                f"backlog {backlog + cost:.6f}s over bound "
                f"{self.reject_backlog_s:.6f}s")
        # THE model-priced admission decision: predicted completion on
        # the model clock must not pass the deadline.  Rejecting here
        # is the whole point — a request that cannot make it is told
        # now, not after burning a slot and timing out mid-decode.
        predicted = self.vclock + backlog + cost
        if predicted > ticket.deadline_v:
            return self._reject(
                req, "deadline_unmeetable",
                f"predicted completion {predicted:.6f}s (vclock "
                f"{self.vclock:.6f} + backlog {backlog:.6f} + cost "
                f"{cost:.6f}) past deadline {ticket.deadline_v:.6f}s")
        ticket.predicted_v = predicted
        self.queue.append(ticket)
        self.metrics.admitted += 1
        self.metrics.queue_peak = max(self.metrics.queue_peak,
                                      len(self.queue))
        self.metrics.level_peak = max(self.metrics.level_peak, self.level)
        return ServeOutcome(rid=req.rid, status="admitted", bucket=bucket,
                            admit_v=ticket.admit_v,
                            deadline_v=ticket.deadline_v,
                            predicted_v=predicted)

    # ------------------------------------------------------------------
    # the decode loop
    # ------------------------------------------------------------------
    def _form_batch(self) -> List[_Ticket]:
        """Continuous batching: the earliest-admitted ticket leads;
        same-bucket tickets join in admission order provided they do
        not extend the leader's decode length (that monotonicity is
        what makes the admission bound a theorem), up to the bucket's
        batch rows."""
        leader = self.queue[0]
        batch = [leader]
        rows, _cap = leader.bucket
        for t in list(self.queue)[1:]:
            if len(batch) >= rows:
                break
            if t.bucket == leader.bucket \
                    and t.req.n_tokens <= leader.req.n_tokens:
                batch.append(t)
        for t in batch:
            self.queue.remove(t)
        return batch

    def _fail_batch(self, batch: List[_Ticket], err: BaseException) -> None:
        """The ladder's terminal rung for a batch: every member is
        refused with the classified cause.  The server stays up."""
        self.metrics.decode_failures += 1
        for t in batch:
            out = self._reject(
                t.req, "decode_failed",
                f"decode failed after fallback ({classify(err)}): "
                f"{type(err).__name__}: {err}", cause=err)
            out.bucket = t.bucket
            out.admit_v = t.admit_v
            out.deadline_v = t.deadline_v
            out.predicted_v = t.predicted_v
            out.wall_s = time.perf_counter() - t.wall_t0

    def step(self) -> List[ServeOutcome]:
        """Decode one batch from the queue head.  Returns the batch's
        terminal outcomes ([] when idle).  All failure handling is in
        here: a decode fault quarantines the bucket's fused path and
        retries once per-token; a second failure refuses the batch
        classified.  This method never raises."""
        if not self.queue:
            return []
        batch = self._form_batch()
        leader = batch[0]
        bucket = leader.bucket
        n_tokens = self.engine.round_tokens(bucket, leader.req.n_tokens)
        reqs = [t.req for t in batch]
        wall0 = time.perf_counter()
        fellback = False
        try:
            _fp.fire("serve_decode", bucket=bucket, n=len(batch))
            results = self.engine.decode(bucket, reqs, n_tokens)
        except Exception as first:                # noqa: BLE001
            kind = classify(first)
            if kind == "fatal" and isinstance(first, LPFError):
                # contract violations are never degraded around
                self._fail_batch(batch, first)
                return [self.outcomes[t.req.rid] for t in batch]
            # transient/mitigable: quarantine the fused path and retry
            # once on the per-token fallback (PR 9's ladder shape)
            self.engine.quarantine(bucket)
            self.metrics.decode_fallbacks += 1
            fellback = True
            try:
                _fp.fire("serve_decode", bucket=bucket, n=len(batch),
                         fallback=True)
                results = self.engine.decode(bucket, reqs, n_tokens)
            except Exception as second:           # noqa: BLE001
                self._fail_batch(batch, second)
                return [self.outcomes[t.req.rid] for t in batch]
        wall = time.perf_counter() - wall0
        # the model clock advances by the batch program's ledger cost —
        # which, by model compliance, equals its predicted cost
        self.vclock += self.engine.ledger_seconds(bucket, n_tokens)
        self.metrics.batches += 1
        self.metrics.tokens_decoded += n_tokens * len(batch)
        self.monitor.record(self.metrics.batches, wall)
        done: List[ServeOutcome] = []
        for t in batch:
            toks = tuple(int(x)
                         for x in results[t.req.rid][:t.req.n_tokens])
            missed = self.vclock > t.deadline_v
            if missed:
                self.metrics.deadline_misses += 1
            out = ServeOutcome(
                rid=t.req.rid, status="completed", bucket=bucket,
                tokens=toks, admit_v=t.admit_v,
                deadline_v=t.deadline_v, predicted_v=t.predicted_v,
                completion_v=self.vclock,
                wall_s=time.perf_counter() - t.wall_t0,
                fallback=fellback)
            self.metrics.completed += 1
            self.outcomes[t.req.rid] = out
            done.append(out)
        return done

    def run_until_idle(self, max_batches: int = 1_000_000) -> int:
        """Pump :meth:`step` until the queue is empty; returns the
        number of batches decoded."""
        n = 0
        while self.queue and n < max_batches:
            self.step()
            n += 1
        return n

    # ------------------------------------------------------------------
    # drain / health
    # ------------------------------------------------------------------
    def drain(self) -> Dict[str, Any]:
        """Graceful shutdown: stop admitting (new submissions are
        refused with reason ``draining``), finish every queued decode,
        and flush the engine's caches (persistent entries written
        back).  Idempotent.  Returns the final :meth:`health`."""
        self.draining = True
        self.run_until_idle()
        flush = getattr(self.engine, "flush", None)
        if flush is not None:
            flush()
        return self.health()

    def take_outcomes(self) -> Dict[int, ServeOutcome]:
        """Consume (return and clear) the accumulated terminal
        outcomes — the response-delivery surface."""
        out, self.outcomes = self.outcomes, {}
        return out

    def health(self) -> Dict[str, Any]:
        """The service metrics snapshot: queue/ladder state, SLO
        counters, and the cache layer's degradation counters
        (:func:`~repro.runtime.monitor.cache_metrics`) including
        memory-only mode and the compile quarantine."""
        snap: Dict[str, Any] = {
            "vclock_s": self.vclock,
            "queue_depth": len(self.queue),
            "backlog_s": self.backlog_seconds(),
            "level": self.level,
            "draining": self.draining,
        }
        snap.update(self.metrics.snapshot())
        if getattr(self.engine, "cache_stats", None) is not None:
            snap.update(cache_metrics(self.engine))
            pc = getattr(self.engine, "program_cache", None)
            if pc is not None and pc.memory_only_reason:
                snap["program_memory_only_reason"] = pc.memory_only_reason
        hist = list(self.monitor.history)
        snap["stragglers_flagged"] = sum(1 for v in hist if v.straggle)
        return snap


# ==========================================================================
# the pure-LPF decode engine
# ==========================================================================

class ProgramDecodeEngine:
    """Decode engine whose per-token step is a recorded LPF superstep
    program — the serve path the cost model can price exactly.

    Per bucket ``(B, C)`` the per-token step ring-shifts the batch's
    ``[B, W]`` state tile (``W = max(1, C // 4)``) across the mesh and
    mixes it row-locally; ``n`` tokens roll into ONE XLA ``While`` via
    ``ctx.compile_loop`` with the body's program replayed from this
    engine's private :class:`~repro.core.program.ProgramCache` (hot
    bucket entries pinned after warm-up).  Rows never mix, so a
    request's token stream is a pure function of its seed — the
    bit-identical-under-batching invariant the chaos soak asserts.

    Pricing comes from the recorded program's ledger: ``token_seconds``
    is the per-iteration predicted cost under the probed machine, and
    every decode call's ledger equals prediction by model compliance —
    the admission controller and the executed program cannot disagree.

    ``quarantine(bucket)`` (or a transient decode failure) flips the
    bucket to the per-token fallback: the same math recorded and
    replayed one token at a time (no whole-loop scan), bit-identical
    numerics at higher dispatch cost.
    """

    #: decode lengths are bucketed to powers of two (capped by the
    #: cache length) so distinct request lengths share XLA programs
    ROUND_POW2 = True

    def __init__(self, buckets: Sequence[Bucket] = ((2, 16), (4, 16)),
                 persist_dir: Optional[str] = None,
                 cache_maxsize: int = 256, pin_hot: bool = True):
        import jax
        from ..core import (CPU_HOST, PlanCache, ProgramCache, compat,
                            probe)
        self._jax = jax
        self._compat = compat
        self._buckets = tuple(sorted(tuple(b) for b in buckets))
        self.n_devices = jax.device_count()
        self.mesh = compat.make_mesh((self.n_devices,), ("x",))
        self.plan_cache = PlanCache()
        self.program_cache = ProgramCache(maxsize=cache_maxsize,
                                          persist_dir=persist_dir)
        self.machine = probe({"x": self.n_devices}, CPU_HOST)
        self._fns: Dict[Tuple[Bucket, int, bool], Any] = {}
        self._step_costs: Dict[Bucket, list] = {}
        self._quarantined: set = set()
        self._warmup(pin=pin_hot)

    # -- protocol surface ------------------------------------------------
    def buckets(self) -> Tuple[Bucket, ...]:
        return self._buckets

    def token_seconds(self, bucket: Bucket) -> float:
        """Model-predicted seconds per decoded token: the bucket's
        recorded per-token program priced on the probed machine."""
        return sum(c.predicted_seconds(self.machine)
                   for c in self._step_costs[bucket])

    def overhead_seconds(self, bucket: Bucket) -> float:
        """Per-call overhead on the model clock: zero — the BSP model
        prices communication; dispatch overhead is a wall-clock
        concern the benchmarks measure separately."""
        return 0.0

    def round_tokens(self, bucket: Bucket, n: int) -> int:
        if not self.ROUND_POW2:
            return min(n, bucket[1])
        t = 1
        while t < n:
            t *= 2
        return min(t, bucket[1])

    def ledger_seconds(self, bucket: Bucket, n_tokens: int) -> float:
        """The decode call's ledger cost on the model clock.  Equal to
        ``token_seconds * n_tokens`` by construction: the loop body is
        ONE recorded program replayed per token, and every executed
        superstep ledgers exactly its predicted cost (the fused and
        per-token paths ledger identically — PR 6/9 invariants)."""
        return self.token_seconds(bucket) * n_tokens

    def quarantine(self, bucket: Bucket) -> None:
        """Force the per-token fallback path for ``bucket`` (the serve
        ladder calls this when the fused decode fails)."""
        self._quarantined.add(tuple(bucket))

    @property
    def cache_stats(self):
        """Duck-typed for :func:`~repro.runtime.monitor.cache_metrics`."""
        return {"plan": self.plan_cache.stats,
                "program": self.program_cache.stats}

    def flush(self) -> int:
        """Write back certified programs to the persistent store (the
        drain hook); 0 without one."""
        return self.program_cache.flush()

    # -- internals -------------------------------------------------------
    def _width(self, bucket: Bucket) -> int:
        return max(1, bucket[1] // 4)

    def _decode_fn(self, bucket: Bucket, n_tokens: int, fused: bool):
        """Build (and memoize) the jitted decode entry point for one
        (bucket, rounded length, path) triple."""
        key = (bucket, n_tokens, fused)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        jax = self._jax
        compat = self._compat
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from ..core import LPFContext

        B, C = bucket
        W = self._width(bucket)
        label = f"serve[{B}x{C}]"
        plan_cache, program_cache = self.plan_cache, self.program_cache
        box: Dict[str, Any] = {}

        def body(c2, carry):
            c2.resize_memory_register(2)
            c2.resize_message_queue(c2.p)
            a = c2.register_global("tile", carry)
            b = c2.register_global("nxt", jnp.zeros_like(carry))
            c2.put(a, b, to=lambda s: (s + 1) % c2.p, size=B * W)
            c2.sync(label=label)
            mixed = c2.value(b).reshape(B, W)
            out = 0.5 * carry + 0.25 * mixed + 1.0
            c2.deregister(a)
            c2.deregister(b)
            return out

        def wrapped(seeds):
            ctx = LPFContext(("x",), plan_cache=plan_cache,
                             program_cache=program_cache)
            carry = (seeds[:, None] * 1e-3
                     + jnp.arange(W, dtype=jnp.float32)[None, :] * 1e-2
                     + ctx.pid.astype(jnp.float32) * 0.1)
            if fused:
                _final, ys = ctx.compile_loop(
                    body, carry, n_iters=n_tokens, label=label,
                    collect=lambda c: c)
            else:
                # per-token fallback: the same body recorded and
                # replayed one token at a time — no whole-loop scan,
                # every program still certified and cache-served
                outs = []
                for _ in range(n_tokens):
                    sub = LPFContext(("x",), plan_cache=plan_cache,
                                     program_cache=program_cache,
                                     _parent=ctx)
                    with sub.program(label):
                        carry = body(sub, carry)
                    for c in sub.ledger.records:
                        ctx.ledger.add(c)
                    outs.append(carry)
                ys = jnp.stack(outs)
            box["records"] = list(ctx.ledger.records)
            return ys

        fn_jit = jax.jit(compat.shard_map(
            wrapped, mesh=self.mesh, in_specs=(P(),),
            out_specs=P(None, None, "x"), check_vma=False))

        def call(seeds_np):
            ys = fn_jit(jnp.asarray(seeds_np, jnp.float32))
            return ys, box.get("records")
        self._fns[key] = call
        return call

    def _warmup(self, pin: bool) -> None:
        """Record/price every bucket's per-token program (one 1-token
        decode each) and pin the resulting cache entries: the hot
        serving set must survive any burst of cold signatures."""
        import numpy as np
        for bucket in self._buckets:
            call = self._decode_fn(bucket, 1, fused=True)
            _ys, records = call(np.zeros(bucket[0], np.float32))
            if not records:       # pragma: no cover - trace always runs
                raise LPFError(f"warmup traced no ledger for {bucket}")
            self._step_costs[bucket] = list(records)
        if pin:
            for key in self.program_cache.keys():
                self.program_cache.pin(key)

    def decode(self, bucket: Bucket, reqs: Sequence[ServeRequest],
               n_tokens: int) -> Dict[int, Tuple[int, ...]]:
        """Decode ``n_tokens`` greedy tokens for up to ``B`` requests
        sharing ``bucket``.  Rows are seeded per request and never
        mix: the returned stream for a request is identical whether it
        decodes alone or fully batched."""
        import numpy as np
        bucket = tuple(bucket)
        B, _C = bucket
        if len(reqs) > B:
            raise LPFError(f"batch of {len(reqs)} into bucket {bucket}")
        fused = bucket not in self._quarantined
        call = self._decode_fn(bucket, n_tokens, fused)
        seeds = np.zeros(B, np.float32)
        for i, r in enumerate(reqs):
            seeds[i] = float(r.seed % 9973) + 1.0
        ys, _records = call(seeds)
        ys = np.asarray(ys)       # [T, B, W * n_devices]
        # token t of row r: a deterministic digest of the row's state
        toks = (np.round(ys.sum(axis=2) * 16.0).astype(np.int64)
                % np.int64(65521))
        return {r.rid: tuple(int(x) for x in toks[:, i])
                for i, r in enumerate(reqs)}


# ==========================================================================
# request generation (CLI / chaos / benchmarks)
# ==========================================================================

def synthetic_requests(n: int, seed: int, buckets: Sequence[Bucket],
                       *, token_cost_s: float = 2e-5,
                       deadline_scale: float = 40.0,
                       tight_frac: float = 0.25,
                       max_tokens: Optional[int] = None
                       ) -> List[ServeRequest]:
    """A deterministic mixed-deadline workload: token counts drawn
    across the buckets' capacities, most deadlines loose (admissible
    with queueing headroom), ``tight_frac`` of them deliberately
    unmeetable so the admission path is always exercised.  Deadlines
    are model-seconds, priced in multiples of ``token_cost_s`` (pass
    the engine's ``token_seconds`` for a calibrated mix)."""
    import random as _random
    rng = _random.Random(seed)
    cap = max(b[1] for b in buckets)
    if max_tokens is not None:
        cap = min(cap, max_tokens)
    reqs = []
    for rid in range(n):
        n_tok = rng.randint(1, cap)
        tight = rng.random() < tight_frac
        scale = (0.5 if tight else deadline_scale
                 * (1.0 + rng.random()))
        reqs.append(ServeRequest(
            rid=rid, n_tokens=n_tok,
            deadline_s=scale * n_tok * token_cost_s,
            priority=rng.randint(0, 2), seed=rng.randint(0, 1 << 30)))
    return reqs
