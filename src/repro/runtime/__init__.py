"""Runtime: step builders, training loop, straggler monitor."""
from .monitor import StepVerdict, StragglerMonitor
from .train_step import ServeStep, TrainStep, build_serve_step, build_train_step
__all__ = ["StepVerdict", "StragglerMonitor", "ServeStep", "TrainStep",
           "build_serve_step", "build_train_step"]
