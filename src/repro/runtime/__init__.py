"""Runtime: step builders, training loop, straggler monitor."""
from .monitor import StepVerdict, StragglerMonitor, cache_metrics
from .train_step import ServeStep, TrainStep, build_serve_step, build_train_step
__all__ = ["StepVerdict", "StragglerMonitor", "cache_metrics",
           "ServeStep", "TrainStep", "build_serve_step", "build_train_step"]
