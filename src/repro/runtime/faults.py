"""Deterministic fault injection and the chaos soak harness.

The LPF paper's error contract promises that *mitigable* errors are
side-effect-free (the caller may resize and retry) and that anything
else is classified before communication is issued.  This module makes
that contract testable: a :class:`FaultPlan` is a deterministic,
seedable schedule of infrastructure failures fired at the execution
stack's defined seams (see :mod:`repro.core.faultpoints`):

========================  ==================================================
seam                      injected failure
========================  ==================================================
``persist_save``          ``OSError`` out of ``PersistentStore.save``
                          (full disk / read-only cache dir)
``persist_load``          ``OSError``, truncated, or bit-flipped read out
                          of ``PersistentStore._read``
``compile``               XLA compilation failure out of
                          ``compile_program`` (:class:`InjectedFault`)
``straggler``             wall-clock delay before a schedule issues
``capacity``              mitigable ``LPFCapacityError`` at staging time
``serve_admit``           :class:`InjectedFault` during request admission
                          (``LPFServer.submit``)
``serve_decode``          :class:`InjectedFault` before a decode batch
                          issues (``LPFServer.step``)
========================  ==================================================

No seam fires unless a plan is **armed** (:func:`arm` / :func:`inject`
/ the ``LPF_FAULT_PLAN`` env var), and an unarmed seam is a single
``is None`` check — the zero-fault path is byte-identical with the
machinery in the tree.

Plan grammar (``FaultPlan.parse`` / ``.spec()`` round-trip)::

    LPF_FAULT_PLAN="compile@0;persist_load@1:bitflip;straggler@2=0.05"

    event   := seam "@" at ["x" repeat] [":" mode] ["=" arg]
    at      := 0-based invocation index of the seam at which to fire
    repeat  := consecutive firings from `at` (default 1, -1 = forever)
    mode    := persist_load only: oserror | truncate | bitflip
    arg     := straggler only: delay seconds (default 0.02)

The chaos soak harness (``python -m repro.runtime.faults --chaos
--seeds N``) replays warm-start, bucketed-sync, decode, and serve
workloads under seeded random plans and asserts the core invariant:
every run either completes with numerics and ledger **identical** to
the fault-free run, or raises a **classified**
:class:`repro.core.LPFError` before any communication is issued —
never an unclassified exception, never an unverified execution.
``--smoke`` runs one fixed plan per seam (the CI tripwire that keeps
the seams from rotting).

The ``serve`` workload's invariant is per *request*, not per run
(:func:`_serve_compare`): under any fault-plus-overload plan every
request either completes with tokens bit-identical to its unloaded
solo decode, or terminates refused with a classified
:class:`~repro.runtime.server.ServeRejected` — and the server object
itself must survive the whole arrival sequence (an exception escaping
the serve loop fails the run even if it is an LPFError).
"""

from __future__ import annotations

import argparse
import collections
import contextlib
import dataclasses
import errno
import os
import random
import re
import sys
from typing import Dict, List, Optional, Sequence, Tuple

# NOTE: module level stays stdlib-only — arming a plan (e.g. from
# LPFContext reading LPF_FAULT_PLAN) must not drag in jax; the chaos
# harness imports the heavy stack lazily inside its functions.
from ..core.faultpoints import SEAMS, InjectedFault, _install
from ..core import faultpoints as _faultpoints

__all__ = ["FaultEvent", "FaultPlan", "FaultInjector", "InjectedFault",
           "SEAMS", "arm", "disarm", "active", "inject",
           "ensure_env_plan", "SMOKE_PLANS", "chaos_main"]

#: default injected straggler delay (seconds) when an event has no arg
DEFAULT_DELAY = 0.02

_MODES = {
    "persist_save": ("",),
    "persist_load": ("oserror", "truncate", "bitflip"),
    "compile": ("",),
    "straggler": ("",),
    "capacity": ("",),
    "serve_admit": ("",),
    "serve_decode": ("",),
}

_EVENT_RE = re.compile(
    r"^(?P<seam>[a-z_]+)@(?P<at>\d+)"
    r"(?:x(?P<repeat>-?\d+))?"
    r"(?::(?P<mode>[a-z_]+))?"
    r"(?:=(?P<arg>[0-9.eE+\-]+))?$")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: fire at the ``at``-th invocation of
    ``seam`` (0-based), for ``repeat`` consecutive invocations
    (-1 = every invocation from ``at`` on)."""

    seam: str
    at: int
    mode: str = ""
    arg: float = 0.0
    repeat: int = 1

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; one of {SEAMS}")
        if self.mode and self.mode not in _MODES[self.seam]:
            raise ValueError(
                f"seam {self.seam!r} has no mode {self.mode!r}")
        if self.at < 0:
            raise ValueError("event index must be >= 0")
        if self.repeat == 0:
            raise ValueError("repeat must be nonzero (-1 = forever)")

    def due(self, idx: int) -> bool:
        if idx < self.at:
            return False
        return self.repeat < 0 or idx < self.at + self.repeat

    def spec(self) -> str:
        s = f"{self.seam}@{self.at}"
        if self.repeat != 1:
            s += f"x{self.repeat}"
        if self.mode:
            s += f":{self.mode}"
        if self.arg:
            s += f"={self.arg:g}"
        return s


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`; the unit the
    chaos harness seeds, replays, and prints on failure."""

    events: Tuple[FaultEvent, ...]
    seed: Optional[int] = None

    def spec(self) -> str:
        """The parseable textual form (``LPF_FAULT_PLAN`` syntax)."""
        return ";".join(e.spec() for e in self.events)

    def seams(self) -> Tuple[str, ...]:
        return tuple(sorted({e.seam for e in self.events}))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(f"malformed fault event {part!r} "
                                 f"(grammar: seam@at[xN][:mode][=arg])")
            events.append(FaultEvent(
                seam=m.group("seam"), at=int(m.group("at")),
                mode=m.group("mode") or "",
                arg=float(m.group("arg") or 0.0),
                repeat=int(m.group("repeat") or 1)))
        return cls(events=tuple(events))

    @classmethod
    def random(cls, seed: int, seams: Sequence[str] = SEAMS,
               max_events: int = 3) -> "FaultPlan":
        """A seed-deterministic plan over ``seams`` (stdlib ``random``
        so the draw never skews across numpy versions)."""
        rng = random.Random(seed)
        events = []
        for _ in range(rng.randint(1, max_events)):
            seam = rng.choice(list(seams))
            mode = rng.choice(_MODES[seam]) if seam == "persist_load" \
                else ""
            # mostly one-shot faults; occasionally a *persistent* one
            # (every invocation fails) to drive the degradation ladder
            # to its terminal rung (memory-only mode / classified error)
            repeat = -1 if rng.random() < 0.2 else 1
            arg = round(rng.uniform(0.001, DEFAULT_DELAY), 4) \
                if seam == "straggler" else 0.0
            events.append(FaultEvent(seam=seam, at=rng.randint(0, 2),
                                     mode=mode, arg=arg, repeat=repeat))
        return cls(events=tuple(events), seed=seed)


class FaultInjector:
    """Counts seam invocations and fires the armed plan's due events.

    ``fired`` records every injected failure as ``(seam, invocation
    index, mode)`` so tests can assert a plan actually exercised its
    target (a plan that never fires proves nothing)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counts: Dict[str, int] = collections.Counter()
        self.fired: List[Tuple[str, int, str]] = []

    def _next(self, seam: str) -> Optional[FaultEvent]:
        idx = self.counts[seam]
        self.counts[seam] = idx + 1
        for e in self.plan.events:
            if e.seam == seam and e.due(idx):
                self.fired.append((seam, idx, e.mode or "default"))
                return e
        return None

    # -- seam entry points (see repro.core.faultpoints) -----------------
    def fire(self, seam: str, **info) -> None:
        e = self._next(seam)
        if e is None:
            return
        if seam == "persist_save":
            raise OSError(errno.ENOSPC, "injected fault: disk full")
        if seam == "compile":
            raise InjectedFault("injected fault: XLA compilation failed")
        if seam == "capacity":
            from ..core.errors import LPFCapacityError
            staged = int(info.get("staged", 0))
            new = int(info.get("new", 1))
            cap = int(info.get("capacity", 0))
            raise LPFCapacityError(
                f"injected fault: message queue capacity exhausted "
                f"({staged} staged + {new} new > effective capacity)",
                required=staged + new, capacity=cap, kind="queue")
        if seam == "serve_admit":
            raise InjectedFault(
                f"injected fault: admission infrastructure failure "
                f"(rid={info.get('rid')})")
        if seam == "serve_decode":
            raise InjectedFault(
                f"injected fault: decode launch failure "
                f"(bucket={info.get('bucket')}, "
                f"fallback={bool(info.get('fallback'))})")
        raise AssertionError(f"seam {seam!r} has no fire() action")

    def corrupt(self, seam: str, blob: bytes) -> bytes:
        e = self._next(seam)
        if e is None:
            return blob
        mode = e.mode or "oserror"
        if mode == "oserror":
            raise OSError(errno.EIO, "injected fault: read failure")
        if mode == "truncate":
            return blob[:len(blob) // 2]
        # bitflip: corrupt one payload byte; the checksum must catch it
        pos = len(blob) // 2
        flipped = bytes([blob[pos] ^ 0x40])
        return blob[:pos] + flipped + blob[pos + 1:]

    def delay(self, seam: str, **info) -> float:
        e = self._next(seam)
        if e is None:
            return 0.0
        return e.arg if e.arg > 0 else DEFAULT_DELAY


# ==========================================================================
# arming
# ==========================================================================

def arm(plan: FaultPlan) -> FaultInjector:
    """Arm ``plan`` process-wide (replacing any armed injector) and
    return its injector."""
    inj = FaultInjector(plan)
    _install(inj)
    return inj


def disarm() -> None:
    _install(None)


def active() -> Optional[FaultInjector]:
    """The armed injector, or ``None`` on the zero-fault path."""
    return _faultpoints._INJECTOR


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """``with inject(plan) as inj: ...`` — arm for the block, restore
    the previously armed injector (usually none) on exit."""
    inj = FaultInjector(plan)
    prev = _install(inj)
    try:
        yield inj
    finally:
        _install(prev)


def ensure_env_plan() -> Optional[FaultInjector]:
    """Arm the ``LPF_FAULT_PLAN`` env plan if one is set and nothing is
    armed yet (idempotent: a root :class:`LPFContext` calls this on
    construction)."""
    spec = os.environ.get("LPF_FAULT_PLAN")
    if not spec or _faultpoints.armed():
        return active()
    return arm(FaultPlan.parse(spec))


# ==========================================================================
# chaos workloads
# ==========================================================================
#
# Each workload is a deterministic function returning a comparable
# result (numerics + ledger / predicted costs); the harness runs it
# fault-free once (the baseline), then under each seeded plan, and
# asserts identical-result-or-classified-error.  Workloads declare
# which seams they can reach so random plans are drawn to actually
# fire (a persist fault cannot fire in a workload with no store).

def _np():
    import numpy as np
    return np


def _wl_warm_start() -> dict:
    """Record every canned trace into a persistent cache, then
    warm-start a fresh cache from the same directory — the PR-8
    cross-process claim, here as a fault target for the persist-I/O
    seams.  Pure Python (no devices): disk faults must be absorbed by
    the degradation ladder, so this workload ALWAYS completes and must
    always match the baseline."""
    import tempfile
    from ..analysis.traces import CANNED_TRACES
    from ..core import CPU_HOST, PlanCache, ProgramCache, probe
    machine = probe({"x": 8}, CPU_HOST)
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        for phase in ("record", "warm"):
            pc = ProgramCache(persist_dir=tmp)
            plan_cache = PlanCache()
            for name, builder in sorted(CANNED_TRACES.items()):
                p, _slots, steps, scratch = builder()
                prog, key = pc.get_or_build_keyed(
                    steps, p, machine, plan_cache=plan_cache,
                    scratch=scratch)
                cert = pc.certify(key, steps, prog, scratch=scratch)
                if not cert.ok:   # pragma: no cover - verifier backstop
                    raise AssertionError(f"uncertified schedule: {name}")
                out[(phase, name)] = tuple(st.plan.cost
                                           for st in prog.steps)
    return {"costs": out}


def _run_mesh_trace(steps, slots, *, use_with_capacity: bool = True):
    """Issue a canned trace through the real ``ctx.program`` path on
    the host mesh; returns values + the ledger records."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from ..core import LPFContext, PlanCache, ProgramCache, compat

    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("x",))
    pc, pgc = PlanCache(), ProgramCache()
    box = {}

    def wrapped(_):
        ctx = LPFContext(("x",), plan_cache=pc, program_cache=pgc)
        ctx.resize_memory_register(len(slots) + 1)
        smap = {}
        for s in slots:
            init = (jnp.arange(s.size, dtype=jnp.int32) * 7
                    + s.sid * 1000 + ctx.pid.astype(jnp.int32) * 37)
            smap[s.sid] = ctx.register_global(s.name, init)

        def region(c):
            with c.program("chaos"):
                for st in steps:
                    c.put_msgs([(m.src, m.dst, smap[m.src_slot.sid],
                                 m.src_off, smap[m.dst_slot.sid],
                                 m.dst_off, m.size) for m in st.msgs])
                    c.sync(st.attrs, label=st.label)
            return tuple(c.value(smap[s.sid]) for s in slots)

        ctx.resize_message_queue(max(len(st.msgs) for st in steps))
        if use_with_capacity:
            outs = ctx.with_capacity(region)
        else:
            outs = region(ctx)
        box["ledger"] = list(ctx.ledger.records)
        return outs

    fn = jax.jit(compat.shard_map(
        wrapped, mesh=mesh, in_specs=(P(),),
        out_specs=tuple(P("x") for _ in slots), check_vma=False))
    outs = fn(jnp.zeros(1))
    values = {s.sid: np.asarray(v).reshape(n, s.size)
              for s, v in zip(slots, outs)}
    return {"values": values, "ledger": box["ledger"]}


def _wl_bucketed_sync() -> dict:
    """The DDP bucketed gradient sync shape on the host mesh: the
    compile seam exercises the compiled→dispatched fallback (ledger
    must stay bit-for-bit), capacity exercises resize-and-retry, the
    straggler seam only costs wall clock."""
    import jax
    from ..analysis.traces import canned_bucketed_trace
    p, slots, steps, _scratch = canned_bucketed_trace(
        p=jax.device_count(), n_buckets=3, w=8)
    return _run_mesh_trace(steps, slots)


def _wl_decode() -> dict:
    """A decode-step-shaped loop: ``compile_loop`` rolls an iterated
    one-superstep ring shift (the serve path's per-token program) into
    one XLA scan; faults land on the body's single recorded program."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from ..core import LPFContext, PlanCache, ProgramCache, compat

    n = jax.device_count()
    mesh = compat.make_mesh((n,), ("x",))
    pc, pgc = PlanCache(), ProgramCache()
    box = {}

    def wrapped(_):
        ctx = LPFContext(("x",), plan_cache=pc, program_cache=pgc)

        def body(c2, carry):
            c2.resize_memory_register(2)
            c2.resize_message_queue(c2.p)
            a = c2.register_global("tok", carry)
            b = c2.register_global("nxt", jnp.zeros_like(carry))
            c2.put(a, b, to=lambda s_: (s_ + 1) % c2.p, size=4)
            c2.sync(label="decode.shift")
            out = c2.value(b) + 1.0
            c2.deregister(a)
            c2.deregister(b)
            return out

        x0 = jnp.arange(4.0) + ctx.pid
        final = ctx.compile_loop(body, x0, n_iters=4, label="decode")
        box["ledger"] = list(ctx.ledger.records)
        return final

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh, in_specs=(P(),),
                                  out_specs=P("x"), check_vma=False))
    out = _np().asarray(fn(jnp.zeros(1))).reshape(n, 4)
    return {"values": {0: out}, "ledger": box["ledger"]}


def _wl_serve() -> dict:
    """The hardened serve loop under fault-plus-overload: a burst
    arrival pattern into a small bounded queue (driving the ladder
    through shrink, shed, and backpressure) while the ``serve_admit``
    and ``serve_decode`` seams (plus the program layer's ``compile`` /
    ``straggler``) fire.  The result carries every request's terminal
    state AND the per-request solo-decode reference streams; the
    invariant is per request (:func:`_serve_compare`), because under
    faults a *different* admission mix is legitimate — what is never
    legitimate is a completed request whose tokens differ from its
    unloaded solo decode, an unclassified refusal, a missed deadline
    for an admitted request, or a dead server."""
    from ..runtime.server import (LPFServer, ProgramDecodeEngine,
                                  synthetic_requests)
    eng = ProgramDecodeEngine(buckets=((2, 8), (4, 8)))
    reqs = synthetic_requests(
        24, seed=7, buckets=eng.buckets(),
        token_cost_s=eng.token_seconds((4, 8)), deadline_scale=60.0)
    # the unloaded baseline: every request decoded solo, fault-free as
    # far as the serve seams go (they fire only inside LPFServer).
    # Both serve buckets share cache_len, so streams are bucket-
    # independent and one solo decode per request suffices.
    ref = {}
    for r in reqs:
        t = eng.round_tokens((2, 8), r.n_tokens)
        ref[r.rid] = eng.decode((2, 8), [r], t)[r.rid][:r.n_tokens]
    served: Dict[int, tuple] = {}
    try:
        srv = LPFServer(eng, max_queue=6)
        # bursts of 4 submissions per decode step: the queue saturates,
        # the ladder climbs, and admission keeps being exercised
        for i in range(0, len(reqs), 4):
            for r in reqs[i:i + 4]:
                srv.submit(r)
            srv.step()
        srv.drain()
    except BaseException as e:   # noqa: BLE001 - the invariant under test
        return {"server_died": f"{type(e).__name__}: {e}", "ref": ref,
                "served": served, "health": {}}
    for rid, out in srv.take_outcomes().items():
        if out.status == "completed":
            ok_deadline = out.completion_v <= out.predicted_v + 1e-12
            served[rid] = ("completed", out.tokens, ok_deadline)
        else:
            served[rid] = (out.status, out.reason, out.classified)
    return {"server_died": None, "ref": ref, "served": served,
            "health": srv.health()}


def _serve_compare(res: dict, baseline: dict) -> Tuple[bool, str]:
    """The serve chaos invariant, request by request (see
    :func:`_wl_serve`).  ``res`` may legitimately admit a different
    mix than ``baseline``; only ``baseline['ref']`` (the unloaded
    solo-decode streams) anchors the numeric comparison."""
    if res["server_died"]:
        return False, f"server died: {res['server_died']}"
    h = res["health"]
    if h.get("deadline_misses", 0) != 0:
        return False, f"{h['deadline_misses']} admitted request(s) " \
                      f"missed their model-clock deadline"
    ref = baseline["ref"]
    if set(res["served"]) != set(ref):
        return False, "request(s) vanished without a terminal outcome"
    for rid, term in sorted(res["served"].items()):
        if term[0] == "completed":
            _, tokens, ok_deadline = term
            if not ok_deadline:
                return False, f"rid {rid}: completed past its " \
                              f"admission-predicted bound"
            if tuple(tokens) != tuple(ref[rid]):
                return False, f"rid {rid}: tokens differ from the " \
                              f"unloaded solo decode"
        else:
            status, reason, classified = term
            if not classified:
                return False, f"rid {rid}: {status} ({reason}) " \
                              f"without a classified LPFError"
    return True, ""


#: workload name -> (fn, seams random plans may draw from)
WORKLOADS = {
    "warm_start": (_wl_warm_start, ("persist_save", "persist_load")),
    "bucketed_sync": (_wl_bucketed_sync,
                      ("compile", "straggler", "capacity")),
    "decode": (_wl_decode, ("compile", "straggler", "capacity")),
    "serve": (_wl_serve, ("serve_admit", "serve_decode", "compile",
                          "straggler")),
}

#: workloads whose pass criterion is not whole-result equality; the
#: comparator returns ``(ok, why_not)`` against the fault-free baseline
_COMPARATORS = {
    "serve": _serve_compare,
}

#: the CI smoke matrix: one fixed plan per seam (and per persist_load
#: corruption mode), each pinned to a workload that can reach it
SMOKE_PLANS = (
    ("warm_start", "persist_save@0"),
    ("warm_start", "persist_save@0x-1"),
    ("warm_start", "persist_load@0:oserror"),
    ("warm_start", "persist_load@0:truncate"),
    ("warm_start", "persist_load@0:bitflip"),
    ("bucketed_sync", "compile@0"),
    ("bucketed_sync", "straggler@0=0.005"),
    ("bucketed_sync", "capacity@0"),
    ("decode", "compile@0"),
    ("decode", "capacity@0"),
    ("serve", "serve_admit@0"),
    ("serve", "serve_admit@0x-1"),
    ("serve", "serve_decode@0"),
    # both the fused attempt and the per-token retry fail: the whole
    # ladder runs and every affected request must end classified
    ("serve", "serve_decode@0x-1"),
    ("serve", "compile@0x-1"),
)


def _results_equal(a: dict, b: dict) -> bool:
    np = _np()
    if a.keys() != b.keys():
        return False
    for k in a:
        if k == "values":
            if a[k].keys() != b[k].keys():
                return False
            for sid in a[k]:
                if not np.array_equal(a[k][sid], b[k][sid]):
                    return False
        elif a[k] != b[k]:
            return False
    return True


def _run_one(workload: str, plan: Optional[FaultPlan],
             baselines: dict) -> Tuple[str, str]:
    """Run ``workload`` under ``plan`` (or fault-free) and classify the
    outcome against the chaos invariant.  Returns ``(verdict, detail)``
    where verdict is ``identical`` / ``classified`` (both pass) or
    ``MISMATCH`` / ``UNCLASSIFIED`` (both fail)."""
    from ..core.errors import LPFError
    fn, _seams = WORKLOADS[workload]
    if workload not in baselines:
        disarm()
        baselines[workload] = fn()
    fired: List[Tuple[str, int, str]] = []
    try:
        if plan is None:
            res = fn()
        else:
            with inject(plan) as inj:
                res = fn()
                fired = list(inj.fired)
    except LPFError as e:
        # classified before any communication was issued for the
        # failing operation — the contract's acceptable outcome
        return "classified", f"{type(e).__name__}: {e}"
    except Exception as e:   # noqa: BLE001 - the invariant under test
        return "UNCLASSIFIED", f"{type(e).__name__}: {e}"
    compare = _COMPARATORS.get(workload)
    if compare is not None:
        ok, why = compare(res, baselines[workload])
        if not ok:
            return "MISMATCH", why
    elif not _results_equal(res, baselines[workload]):
        return "MISMATCH", "result differs from fault-free baseline"
    return "identical", f"{len(fired)} fault(s) fired"


# ==========================================================================
# CLI
# ==========================================================================

def chaos_main(argv: Optional[Sequence[str]] = None) -> int:
    # the mesh workloads want p=8 host devices, like the test suite;
    # must be decided before jax first imports
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.faults",
        description="Deterministic fault injection: chaos soak harness "
                    "and fixed-plan smoke runs.")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded random-plan soak across the workloads")
    ap.add_argument("--smoke", action="store_true",
                    help="one fixed plan per seam (CI tripwire)")
    ap.add_argument("--seeds", type=int, default=100,
                    help="number of seeded plans for --chaos")
    ap.add_argument("--seed0", type=int, default=0,
                    help="first seed (shard long soaks across jobs)")
    ap.add_argument("--plan", type=str, default=None,
                    help="run one explicit plan spec (needs --workload)")
    ap.add_argument("--workload", type=str, default=None,
                    help="workload for --plan")
    ap.add_argument("--workloads", type=str,
                    default=",".join(WORKLOADS),
                    help="comma list to rotate --chaos seeds over")
    args = ap.parse_args(argv)

    baselines: dict = {}
    failures: List[str] = []
    tally = collections.Counter()

    def run(workload: str, plan: Optional[FaultPlan], tag: str) -> None:
        verdict, detail = _run_one(workload, plan, baselines)
        tally[verdict] += 1
        spec = plan.spec() if plan is not None else "<none>"
        line = f"[{tag}] {workload:<14} plan={spec:<40} {verdict}: {detail}"
        print(line)
        if verdict in ("MISMATCH", "UNCLASSIFIED"):
            failures.append(line)

    if args.plan is not None:
        if args.workload not in WORKLOADS:
            ap.error(f"--plan needs --workload (one of {list(WORKLOADS)})")
        run(args.workload, FaultPlan.parse(args.plan), "plan")
    elif args.smoke:
        for workload, spec in SMOKE_PLANS:
            run(workload, FaultPlan.parse(spec), "smoke")
    elif args.chaos:
        names = [w.strip() for w in args.workloads.split(",") if w.strip()]
        for w in names:
            if w not in WORKLOADS:
                ap.error(f"unknown workload {w!r}")
        for i in range(args.seeds):
            seed = args.seed0 + i
            workload = names[seed % len(names)]
            plan = FaultPlan.random(seed, seams=WORKLOADS[workload][1])
            run(workload, plan, f"seed {seed}")
    else:
        ap.error("pick a mode: --chaos, --smoke, or --plan SPEC")

    print(f"\nchaos summary: {dict(tally)}")
    if failures:
        print(f"\n{len(failures)} INVARIANT VIOLATION(S):",
              file=sys.stderr)
        for line in failures:
            print("  " + line, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(chaos_main())
