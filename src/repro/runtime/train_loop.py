"""The training loop: checkpointing, failure recovery, straggler policy,
and the local-SGD (stale-sync) outer loop.

Fault-tolerance contract:
  * checkpoints are atomic + async; on (re)start the loop resumes from the
    newest published step — crash-at-any-point safe;
  * the data pipeline is a pure function of (seed, step): no iterator
    state can be lost;
  * step wall-times feed the BSP straggler monitor; the policy escalates
    flag -> skip-sync (stale steps, bounded) -> elastic rescale (restore
    onto a smaller mesh — exercised in tests via checkpoint/restore).

Local SGD (the paper's STALE attribute realised at loop level): the inner
loop runs `sync_every` steps with the cross-pod sync OFF (two jitted step
variants — no traced conditionals around collectives), then one outer
step averages parameters across pods.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data import SyntheticStream
from .monitor import StragglerMonitor
from .train_step import TrainStep

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    resume: bool = True
    # local SGD / stale sync: 0 = every step is synchronous
    sync_every: int = 0


def train_loop(ts: TrainStep, stream: SyntheticStream,
               cfg: TrainLoopConfig, *,
               step_fn_nosync: Optional[Callable] = None,
               on_step: Optional[Callable] = None) -> Dict[str, Any]:
    """Run training; returns summary metrics + the monitor history."""
    key = jax.random.PRNGKey(0)
    start = 0
    params = opt = None
    ckpt = AsyncCheckpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None

    if ckpt and cfg.resume:
        last = latest_step(cfg.ckpt_dir)
        if last is not None:
            p_shapes = jax.eval_shape(lambda k: ts.init_fn(k), key)
            state = restore(cfg.ckpt_dir, last, p_shapes,
                            shardings=(ts.param_sharding, ts.opt_sharding))
            params, opt = state
            start = last

    if params is None:
        params, opt = ts.init_fn(key)

    monitor = StragglerMonitor()
    losses: List[float] = []
    for step in range(start, cfg.steps):
        batch_np = stream.batch(step)
        batch = jax.tree.map(jnp.asarray, batch_np)
        use_nosync = (cfg.sync_every > 1 and step_fn_nosync is not None
                      and (step + 1) % cfg.sync_every != 0)
        fn = step_fn_nosync if use_nosync else ts.step_fn
        t0 = time.time()
        params, opt, metrics = fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        verdict = monitor.record(step, dt)
        losses.append(loss)
        if on_step:
            on_step(step, loss, verdict)
        if verdict.action == "rescale":
            # policy surface: callers handle elastic restore; we record it
            pass
        if ckpt and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt),
                      meta={"loss": loss, "data": stream.state(step + 1)})
    if ckpt:
        ckpt.save(cfg.steps, (params, opt),
                  meta={"data": stream.state(cfg.steps)})
        ckpt.wait()
    return {
        "params": params, "opt": opt, "losses": losses,
        "monitor": monitor.history, "final_loss": losses[-1] if losses
        else float("nan"),
    }
