"""The training loop: checkpointing, failure recovery, straggler policy,
and the local-SGD (stale-sync) outer loop.

Fault-tolerance contract:
  * checkpoints are atomic + async; on (re)start the loop resumes from the
    newest published step — crash-at-any-point safe;
  * the data pipeline is a pure function of (seed, step): no iterator
    state can be lost;
  * step wall-times feed the BSP straggler monitor; the policy escalates
    flag -> skip-sync (stale steps, bounded) -> elastic rescale (restore
    onto a smaller mesh — exercised in tests via checkpoint/restore);
  * step exceptions route through the :class:`StepSupervisor`, which
    applies the LPF error taxonomy (:func:`repro.core.classify`):
    *transient* failures (I/O, injected faults, timeouts) are retried
    from the newest published checkpoint with bounded backoff
    (``max_restarts``); *fatal* and *mitigable* errors propagate — a
    contract violation must never be silently retried, and a capacity
    error belongs to ``ctx.with_capacity``'s resize-and-retry, not to
    checkpoint rollback.

Local SGD (the paper's STALE attribute realised at loop level): the inner
loop runs `sync_every` steps with the cross-pod sync OFF (two jitted step
variants — no traced conditionals around collectives), then one outer
step averages parameters across pods.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.core.errors import classify
from repro.data import SyntheticStream
from .monitor import StragglerMonitor, StepVerdict
from .train_step import TrainStep

__all__ = ["TrainLoopConfig", "Anomaly", "StepSupervisor", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    resume: bool = True
    # local SGD / stale sync: 0 = every step is synchronous
    sync_every: int = 0
    # recovery supervision: how many checkpoint-restore retries a run
    # may spend on *transient* step failures before the error
    # propagates, and the (doubling) backoff before each retry
    max_restarts: int = 2
    restart_backoff: float = 0.05
    # flight-recorder ring capacity (see StepSupervisor.ANOMALY_CAP)
    anomaly_cap: Optional[int] = None


@dataclasses.dataclass
class Anomaly:
    """One supervision event, in the order it happened — the run's
    flight recorder (returned in the ``train_loop`` summary)."""

    step: int
    kind: str        # "straggler" | "transient" | "restart" | "give_up"
    action: str      # verdict action, "restore", "propagate", ...
    detail: str = ""


class StepSupervisor:
    """Per-step recovery policy: classify, escalate, bound.

    Verdicts from the :class:`StragglerMonitor` are recorded as
    anomalies when they escalate past "ok" (``flag`` warns,
    ``skip_sync``/``rescale`` are policy surface for the caller).  Step
    exceptions are classified with the LPF taxonomy: *transient* errors
    are absorbed up to ``max_restarts`` times — each absorption asks the
    caller to restore from the newest published checkpoint after a
    doubling backoff — everything else propagates unchanged.  Retries
    are bounded per RUN, not per step: a fault that keeps recurring
    must eventually surface, classified, to the operator."""

    #: default flight-recorder ring capacity: the anomalies list is a
    #: post-mortem surface, and a long-running job with a chronically
    #: flagged straggler appends one entry per step — unbounded, that
    #: is an OOM with extra steps; bounded, the newest (most relevant)
    #: evidence survives
    ANOMALY_CAP = 1024

    def __init__(self, max_restarts: int = 2, backoff: float = 0.05,
                 anomaly_cap: Optional[int] = None):
        self.max_restarts = max_restarts
        self.backoff = backoff
        self.restarts = 0
        #: bounded ring of supervision events (oldest dropped first)
        self.anomalies: Deque[Anomaly] = collections.deque(
            maxlen=self.ANOMALY_CAP if anomaly_cap is None
            else anomaly_cap)

    def on_verdict(self, verdict: StepVerdict) -> None:
        if verdict.action != "ok":
            self.anomalies.append(Anomaly(
                step=verdict.step, kind="straggler",
                action=verdict.action,
                detail=f"z={verdict.z:.2f} dt={verdict.duration:.4f}s"))

    def on_error(self, step: int, err: BaseException) -> bool:
        """Decide the fate of a step that raised: ``True`` = absorb and
        retry from the latest checkpoint (the caller restores), after
        sleeping the backoff; ``False`` = propagate."""
        kind = classify(err)
        if kind != "transient" or self.restarts >= self.max_restarts:
            self.anomalies.append(Anomaly(
                step=step, kind=kind, action="propagate",
                detail=f"{type(err).__name__}: {err}"))
            return False
        self.restarts += 1
        self.anomalies.append(Anomaly(
            step=step, kind="transient", action="restore",
            detail=f"restart {self.restarts}/{self.max_restarts}: "
                   f"{type(err).__name__}: {err}"))
        time.sleep(self.backoff * (2 ** (self.restarts - 1)))
        return True


def train_loop(ts: TrainStep, stream: SyntheticStream,
               cfg: TrainLoopConfig, *,
               step_fn_nosync: Optional[Callable] = None,
               on_step: Optional[Callable] = None) -> Dict[str, Any]:
    """Run training; returns summary metrics + the monitor history."""
    key = jax.random.PRNGKey(0)
    start = 0
    params = opt = None
    ckpt = AsyncCheckpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
    p_shapes = jax.eval_shape(lambda k: ts.init_fn(k), key)
    # NOT `(ts.param_sharding, ts.opt_sharding)` unconditionally: jax
    # flattens None as an *empty* subtree, so a (None, None) shardings
    # pytree would flatten to zero leaves and break restore's zip
    shards = (None if ts.param_sharding is None and ts.opt_sharding is None
              else (ts.param_sharding, ts.opt_sharding))

    if ckpt and cfg.resume:
        last = latest_step(cfg.ckpt_dir)
        if last is not None:
            state = restore(cfg.ckpt_dir, last, p_shapes,
                            shardings=shards)
            params, opt = state
            start = last

    if params is None:
        params, opt = ts.init_fn(key)

    monitor = StragglerMonitor()
    supervisor = StepSupervisor(max_restarts=cfg.max_restarts,
                                backoff=cfg.restart_backoff,
                                anomaly_cap=cfg.anomaly_cap)
    losses: List[float] = []
    step = start
    while step < cfg.steps:
        batch_np = stream.batch(step)
        batch = jax.tree.map(jnp.asarray, batch_np)
        use_nosync = (cfg.sync_every > 1 and step_fn_nosync is not None
                      and (step + 1) % cfg.sync_every != 0)
        fn = step_fn_nosync if use_nosync else ts.step_fn
        t0 = time.time()
        try:
            params, opt, metrics = fn(params, opt, batch)
            loss = float(metrics["loss"])
        except Exception as err:
            if not supervisor.on_error(step, err):
                raise
            # transient, absorbed: roll back to the newest published
            # state and re-run from there.  Without a checkpointer the
            # live (params, opt) are still pre-step — the step that
            # raised never committed its update — so retrying in place
            # is the same rollback with a zero-step window.
            if ckpt:
                rstep, state = ckpt.restore_latest(p_shapes,
                                                   shardings=shards)
                if rstep is not None:
                    params, opt = state
                    del losses[max(0, rstep - start):]
                    step = rstep
            continue
        dt = time.time() - t0
        verdict = monitor.record(step, dt)
        supervisor.on_verdict(verdict)
        losses.append(loss)
        if on_step:
            on_step(step, loss, verdict)
        if verdict.action == "rescale":
            # policy surface: callers handle elastic restore; we record it
            pass
        if ckpt and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt),
                      meta={"loss": loss, "data": stream.state(step + 1)})
        step += 1
    if ckpt:
        ckpt.save(cfg.steps, (params, opt),
                  meta={"data": stream.state(cfg.steps)})
        ckpt.wait()
    return {
        "params": params, "opt": opt, "losses": losses,
        "monitor": monitor.history, "final_loss": losses[-1] if losses
        else float("nan"),
        "anomalies": supervisor.anomalies,
        "restarts": supervisor.restarts,
    }
