"""SuperstepProgram — record/replay whole LPF programs.

PR 1 made a single ``lpf_sync`` plan-once/execute-many.  The paper's
immortal-algorithm argument, however, is about whole *programs*: the
FFT's redistribute+reorder pair, PageRank's per-iteration h-relation, a
training step's per-layer gradient syncs.  Re-entering the planner
superstep by superstep ships many small h-relations where the BSP cost
model says fewer, fatter ones are cheaper — every extra superstep pays
another ``l``.  Following pMR's persistent communication objects, this
module lifts the plan/cache/execute architecture one level up:

* **record** — :meth:`repro.core.LPFContext.record` (or the
  ``ctx.program()`` context manager) turns ``ctx.sync`` into a deferred
  operation: each sync snapshots its ``(message table, attrs, label)``
  into a pending trace instead of executing.  Local compute acts as a
  *dataflow-precise* barrier: reading a slot executes exactly the
  pending supersteps in its dependency cone (:func:`dependency_cone` —
  the slot's writers, closed backwards under must-precede conflicts),
  leaving independent supersteps recorded, so interleaved compute keeps
  its sequential semantics without narrowing the batching/overlap
  window.
* **optimize** — :func:`optimize_program` is a cost-model-driven
  *schedule search* over the trace's dependency DAG.  The trace is
  first brought into :func:`canonical_order` — a deterministic
  topological order of the must-precede DAG keyed by step content, so
  reordered-but-equivalent recordings canonicalize (and cache)
  identically — then rewritten:

  1. *coalescing* — same-``(src, dst, slot-pair)`` messages contiguous
     in both offsets merge into one fatter message (kept only when the
     plan of the rewritten table is not predicted slower — round
     padding can inflate wire bytes);
  2. *dead-transfer elimination* — a message whose destination range is
     completely overwritten by a later superstep before any read (and
     before the trace ends) is dropped, gated the same way (removing a
     message can demote a fused classification);
  3. *superstep batching as list scheduling* — the scheduler walks the
     must-precede DAG and grows each emitted superstep with any
     still-unscheduled step whose predecessors are already placed —
     **non-adjacent** independent supersteps hoist over intervening
     steps whenever commutation permits — merging equal-attribute
     steps cost-gated by the BSP model (``h_merged*g + l <
     sum(h_i*g + l)``, ``h``/rounds from the planned schedules);
  4. *Valiant-aware attr rewrites* — when the merge gate refuses on
     differing attrs or prices the merged plan higher, and for a
     skewed/fragmented fat superstep on its own, the scheduler may
     *rewrite* the step's attributes to route it through two-phase
     Valiant routing; admissible only on conflict-free tables
     (``repro.core.sync.conflict_free`` — a method rewrite must not
     change CRCW winners) and accepted iff the planned cost strictly
     improves;
  5. *split-phase overlap as list scheduling* — independent supersteps
     the merge gate keeps separate (differing attrs, or a merged plan
     priced higher) are grouped for overlapped issue, again hoisting
     **non-adjacent** ready supersteps over intervening ones: all
     members' reads and collectives launch back-to-back, then all
     writes apply (:func:`repro.core.sync.execute_overlapped`).  A
     k-member group is priced ``max_i(h_i)g + max_i(rounds_i)l +
     (k-1)*l_overlap`` (:func:`repro.core.cost.overlap_cost`) and
     admitted only below the sequential sum; members must commute, and
     valiant supersteps never overlap (phase-1 scratch writes land in
     the start half).

  ``search=False`` restores the pre-search behaviour — the adjacent
  pairs-only peephole — kept as the measurable baseline
  (``benchmarks/schedule_search.py``); the cached/executed path always
  searches.  :meth:`SuperstepProgram.explain` renders the found
  schedule (groups, hoists, rewrites, predicted vs in-order BSP cost).

* **replay** — optimized traces are cached in a :class:`ProgramCache`
  keyed by the canonical program signature (steps in canonical order,
  slot ids renamed by first occurrence *across the whole ordered
  trace*), so repeated invocations — a collective called per layer, an
  FFT called per batch, and legal reorderings of either — skip the
  optimizer and the planner entirely and go straight to
  :func:`repro.core.sync.execute_plan` with pre-planned supersteps.

Every optimized superstep carries its :class:`SuperstepPlan`, so the
ledger entry appended at execution is *by construction* the plan's
predicted :class:`SuperstepCost` — optimization never breaks the
compliance audit.

:func:`simulate_program` is a pure-numpy reference interpreter of the
p >= 2 superstep semantics (reads observe pre-superstep state; CRCW
writes arbitrate in ascending ``(src, dst, dst_off)`` order per
slot-pair group, groups in first-occurrence order; ``reduce_op``
supersteps combine with first-write-replaces semantics).  The
differential harness in ``tests/test_program_equivalence.py`` checks
optimized traces against it bit-for-bit.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Callable, Dict, Hashable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from . import faultpoints as _fp
from .attrs import SyncAttributes
from .cost import SuperstepCost, overlap_cost, schedule_seconds
from .errors import LPFAnalysisError, LPFFatalError
from .machine import LPFMachine
from .memslot import Slot
from .sync import (CacheStats, Msg, OVERLAPPABLE_METHODS, PlanCache,
                   SuperstepPlan, ValueStore, conflict_free,
                   execute_schedule, plan_sync)

__all__ = [
    "ProgramStep", "OptimizedStep", "SuperstepProgram", "ProgramCache",
    "CompiledProgram", "compile_program", "global_program_cache",
    "program_signature", "optimize_program", "simulate_program",
    "dependency_cone", "canonical_order", "trace_slot_map",
]

#: combined planned rounds at which the scheduler bothers pricing a
#: two-phase Valiant route for a (merged) superstep: thin well-formed
#: relations never profit from the doubled wire, so the rewrite search
#: is reserved for skewed/fragmented fat schedules
VALIANT_REWRITE_MIN_ROUNDS = 4

#: completions the canonical-form tie-break may explore per trace:
#: ties that survive :func:`_structural_ranks` (WL-equivalent but
#: non-automorphic steps — e.g. a hexagon and two triangles of
#: slot-sharing between bit-identical steps refine to one colour) are
#: broken by *comparing the finished signatures* of each candidate's
#: completion; the budget bounds the branching on adversarially
#: symmetric traces, beyond which the recorded-index fallback applies
TIE_BRANCH_BUDGET = 256

#: canonical message: (src, dst, src_slot_idx, src_off, dst_slot_idx,
#: dst_off, size, origin) with slot indices assigned by first occurrence
#: across the whole trace
CanonMsg = Tuple[int, int, int, int, int, int, int, str]


@dataclasses.dataclass(frozen=True)
class ProgramStep:
    """One recorded ``sync``: the staged table + its attributes."""

    msgs: Tuple[Msg, ...]
    attrs: SyncAttributes
    label: str


@dataclasses.dataclass(frozen=True)
class OptimizedStep:
    """One superstep of the optimized trace, in canonical (slot-renamed)
    form plus its pre-computed plan.  ``merged_from`` names the
    *canonical ranks* (positions in :func:`canonical_order` of the
    recorded trace) this superstep executes; ``unchanged`` marks a step
    no rewrite touched, letting replay reuse the staged messages
    verbatim instead of rebuilding them from the canonical table.
    ``rewrite`` records an attr rewrite the scheduler applied (e.g.
    ``"valiant"`` — the step's attrs are no longer the recorded ones)."""

    table: Tuple[CanonMsg, ...]
    attrs: SyncAttributes
    label: str
    plan: SuperstepPlan
    merged_from: Tuple[int, ...]
    unchanged: bool = False
    rewrite: str = ""


@dataclasses.dataclass(frozen=True)
class SuperstepProgram:
    """An optimized, replayable trace (the program-level IR)."""

    p: int
    steps: Tuple[OptimizedStep, ...]
    n_recorded: int          # supersteps in the raw trace
    n_coalesced: int         # messages removed by coalescing
    n_eliminated: int        # messages removed as dead transfers
    n_merged: int            # supersteps saved by batching
    #: partition of ``range(len(steps))`` into overlap groups, in step
    #: order: a group of k >= 2 adjacent compute-independent supersteps is
    #: issued split-phase (all starts, then all dones) and ledgered as ONE
    #: entry costing ``max_i(h_i)*g + max_i(rounds_i)*l + (k-1)*l_overlap``
    overlap_groups: Tuple[Tuple[int, ...], ...] = ()
    n_overlapped: int = 0    # supersteps hidden under another's wire time
    n_rewritten: int = 0     # supersteps whose attrs the scheduler rewrote
    n_hoisted: int = 0       # non-adjacent merge/overlap moves performed
    #: how this program's ``merged_from`` ranks and canonical slot
    #: indices were assigned: ``True`` = :func:`canonical_order` of the
    #: recorded trace (the searched/cached path), ``False`` = recorded
    #: order (a ``search=False`` peephole program) — ``materialize``
    #: must resolve ranks the same way the program was built
    canonical: bool = True
    #: the recorded supersteps' own planned costs (canonical order) —
    #: the in-order baseline :meth:`explain` reports the search against
    in_order_costs: Tuple[SuperstepCost, ...] = ()

    def groups(self) -> Tuple[Tuple[int, ...], ...]:
        """``overlap_groups``, defaulting to one singleton per step."""
        if self.overlap_groups:
            return self.overlap_groups
        return tuple((i,) for i in range(len(self.steps)))

    def predicted_seconds(self, machine: LPFMachine) -> float:
        """BSP time of the optimized schedule, overlap priced in."""
        return schedule_seconds(
            [[self.steps[i].plan.cost for i in grp]
             for grp in self.groups()], machine)

    def in_order_seconds(self, machine: LPFMachine) -> float:
        """BSP time of executing the recorded trace superstep by
        superstep, each under its own plan — the baseline the schedule
        search starts from."""
        return sum(c.predicted_seconds(machine)
                   for c in self.in_order_costs)

    def explain(self, machine: Optional[LPFMachine] = None,
                steps: Optional[Sequence["ProgramStep"]] = None,
                scratch: Optional[Slot] = None) -> str:
        """Human-readable rendering of the searched schedule: issue
        groups with member labels, merges/hoists/attr rewrites applied,
        and (when ``machine`` is given) the predicted BSP time of every
        group plus the in-order-vs-scheduled comparison.  The last line
        is the schedule verifier's certificate summary — computed
        fresh from the recorded ``steps`` when given, else the one
        :meth:`ProgramCache.certify` attached."""
        lines = [
            f"SuperstepProgram: {self.n_recorded} recorded -> "
            f"{len(self.steps)} supersteps in {len(self.groups())} "
            f"issue groups",
            f"  rewrites: {self.n_coalesced} coalesced msgs, "
            f"{self.n_eliminated} dead transfers, {self.n_merged} merged, "
            f"{self.n_overlapped} overlapped, {self.n_rewritten} "
            f"attr-rewritten, {self.n_hoisted} non-adjacent hoists",
        ]
        for gi, grp in enumerate(self.groups()):
            costs = [self.steps[i].plan.cost for i in grp]
            c = costs[0] if len(costs) == 1 else overlap_cost(costs)
            head = " || ".join(self.steps[i].label for i in grp)
            line = (f"  [{gi}] {head:<36} {c.method:<28} "
                    f"wire {c.wire_bytes:>8}B  rounds {c.rounds}")
            if machine is not None:
                line += f"  {c.predicted_seconds(machine) * 1e6:>9.2f}us"
            lines.append(line)
            for i in grp:
                st = self.steps[i]
                notes = []
                if len(st.merged_from) > 1:
                    notes.append("merged from recorded steps "
                                 f"{tuple(st.merged_from)}")
                if st.rewrite:
                    notes.append(f"attrs rewritten -> {st.rewrite}")
                if notes:
                    lines.append(f"        {st.label}: "
                                 + "; ".join(notes))
        if machine is not None and self.in_order_costs:
            in_order = self.in_order_seconds(machine)
            sched = self.predicted_seconds(machine)
            ratio = in_order / sched if sched > 0 else float("inf")
            lines.append(
                f"  in-order BSP time {in_order * 1e6:.2f}us -> "
                f"scheduled {sched * 1e6:.2f}us  ({ratio:.2f}x)")
        cert = getattr(self, "_certificate", None)
        if steps is not None:
            from ..analysis.verifier import verify_program
            cert = verify_program(steps, self, scratch=scratch)
        if cert is not None:
            lines.append(f"  {cert.summary()}")
        return "\n".join(lines)

    def slot_map(self, steps: Sequence[ProgramStep]) -> List[Slot]:
        """The slot list this program's canonical indices refer to, for
        a replaying trace ``steps`` — first occurrence in
        :func:`canonical_order` for searched programs, recorded order
        for ``search=False`` ones.  Use this (or pass ``steps``
        directly) rather than a bare ``trace_slot_map`` call, whose
        default ordering only matches canonical programs."""
        return trace_slot_map(
            steps, None if self.canonical else list(range(len(steps))))

    def materialize(self, slot_map_or_steps,
                    labels: Optional[Sequence[str]] = None,
                    order: Optional[Sequence[int]] = None
                    ) -> List[Tuple[List[Msg], SyncAttributes, str,
                                    SuperstepPlan]]:
        """Rebind the canonical tables to actual slots.  Pass either the
        replaying trace's raw :class:`ProgramStep` list (untouched steps
        reuse their staged messages verbatim; rewritten ones rebuild
        from the canonical table via the trace's canonical-order
        first-occurrence slot map) or a pre-computed slot list.
        ``labels`` are the replaying trace's per-step labels *in
        recorded order*, so a cached program replayed under new labels
        ledgers under those (merged supersteps join theirs with ``+``);
        ``merged_from`` ranks are resolved through the replaying trace's
        own :func:`canonical_order`, which — the signature being shared
        — matches the order the program was built in."""
        raw_steps: Optional[Sequence[ProgramStep]] = None
        slot_map: Optional[List[Slot]] = None
        if slot_map_or_steps and isinstance(slot_map_or_steps[0],
                                            ProgramStep):
            raw_steps = slot_map_or_steps
            if not self.canonical:
                order = list(range(len(raw_steps)))
            elif order is None:
                order = canonical_order(raw_steps)
        else:
            slot_map = list(slot_map_or_steps)
            if labels is not None and order is None:
                if self.canonical:
                    # ranks are canonical; without the steps (or an
                    # explicit order) recorded labels cannot be mapped
                    raise LPFFatalError(
                        "materialize(slot_list, labels=...) on a "
                        "searched program needs order= (or pass the "
                        "raw steps), else labels would be resolved by "
                        "canonical rank instead of recorded position")
                order = list(range(self.n_recorded))
        out = []
        for st in self.steps:
            if raw_steps is not None and st.unchanged:
                msgs = list(raw_steps[order[st.merged_from[0]]].msgs)
            else:
                if slot_map is None:
                    slot_map = trace_slot_map(raw_steps, order)
                msgs = [Msg(src, dst, slot_map[si], so, slot_map[di], do,
                            sz, origin=origin)
                        for (src, dst, si, so, di, do, sz, origin)
                        in st.table]
            if labels is None:
                label = st.label
            else:
                label = "+".join(
                    labels[i if order is None else order[i]]
                    for i in st.merged_from)
            out.append((msgs, st.attrs, label, st.plan))
        return out

    def ledger_costs(self, labels: Optional[Sequence[str]] = None,
                     order: Optional[Sequence[int]] = None
                     ) -> List[SuperstepCost]:
        """The exact ledger entries replaying this program appends, in
        issue order: one ``plan.cost_with_label`` per singleton group and
        one :func:`repro.core.cost.overlap_cost` entry per overlap group
        — precisely what :func:`repro.core.sync.execute_schedule`
        returns.  Labels resolve the way :meth:`materialize` resolves
        them (``labels`` in recorded order, ``merged_from`` ranks mapped
        through ``order``), so the compiled whole-program path — which
        cannot thread cost records through a jitted body — ledgers
        bit-for-bit what the step-by-step path would."""
        out: List[SuperstepCost] = []
        for grp in self.groups():
            lbls = []
            for i in grp:
                st = self.steps[i]
                if labels is None:
                    lbls.append(st.label)
                else:
                    lbls.append("+".join(
                        labels[j if order is None else order[j]]
                        for j in st.merged_from))
            if len(grp) == 1:
                out.append(self.steps[grp[0]].plan.cost_with_label(
                    lbls[0]))
            else:
                out.append(overlap_cost(
                    [self.steps[i].plan.cost for i in grp],
                    label="||".join(lbls)))
        return out


# ==========================================================================
# canonicalization + signatures
# ==========================================================================

_DTYPE_STR: Dict[object, str] = {}


def _dtype_str(dtype) -> str:
    s = _DTYPE_STR.get(dtype)
    if s is None:
        s = _DTYPE_STR[dtype] = str(np.dtype(dtype))
    return s


def _slot_canon() -> Tuple[Dict[int, int], List[Tuple[int, str, str]],
                           Callable[[Slot], int]]:
    canon: Dict[int, int] = {}
    descrs: List[Tuple[int, str, str]] = []

    def key(slot: Slot) -> int:
        idx = canon.get(slot.sid)
        if idx is None:
            idx = canon[slot.sid] = len(canon)
            descrs.append((slot.size, _dtype_str(slot.dtype), slot.kind))
        return idx

    return canon, descrs, key


def trace_slot_map(steps: Sequence[ProgramStep],
                   order: Optional[Sequence[int]] = None) -> List[Slot]:
    """Actual slots of a raw trace in canonical-order first-occurrence —
    the inverse of the canonical renaming.  ``order`` (a precomputed
    :func:`canonical_order`) avoids recomputing the DAG sort.  The
    default ordering matches *searched* programs only; when holding a
    :class:`SuperstepProgram`, prefer :meth:`SuperstepProgram.slot_map`
    (or pass the steps straight to ``materialize``), which honours the
    program's own rank ordering (``search=False`` programs use recorded
    order)."""
    if order is None:
        order = canonical_order(steps)
    seen: Dict[int, Slot] = {}
    for i in order:
        for m in steps[i].msgs:
            for slot in (m.src_slot, m.dst_slot):
                if slot.sid not in seen:
                    seen[slot.sid] = slot
    return list(seen.values())


def _attrs_key(attrs: SyncAttributes) -> Hashable:
    return (attrs.method, attrs.no_conflict, attrs.reduce_op,
            attrs.compress, attrs.stale, attrs.valiant_seed)


def _sortable_attrs_key(attrs: SyncAttributes) -> Tuple:
    """Like :func:`_attrs_key` but totally ordered (no ``None``/object
    fields), so ready-step keys can be compared during canonicalization."""
    return (attrs.method, bool(attrs.no_conflict), attrs.reduce_op or "",
            "" if attrs.compress is None else repr(attrs.compress),
            attrs.stale, attrs.valiant_seed)


def _structural_ranks(steps: Sequence[ProgramStep],
                      preds: Sequence[set]) -> List[int]:
    """Order-invariant structural rank of every step — the canonical-tie
    break.  Steps with bit-identical content keys can still be
    structurally distinct: one may feed a later reader (a conflict-DAG
    successor) or share a slot with a step the other never touches.
    Recorded position cannot break such ties — two legal reorderings
    disagree on it, splitting one program into two cache entries — so
    ties are broken by iterated (Weisfeiler-Leman style) colour
    refinement over structure only:

    * initial colour: the step's order-free content (attrs footprint +
      message table with slots named by per-step first occurrence and
      descriptor — the table *shape*);
    * refinement relations: directed must-precede edges (identical
      across legal reorderings — only non-conflicting steps may be
      reordered) and undirected slot-sharing edges labelled by the
      (role-set, role-set, descriptor) of each shared slot — read-read
      sharing creates no DAG edge yet distinguishes a step whose output
      is observed from an identical one whose output is not.

    Colours are re-ranked to dense ints each round until the partition
    stabilizes.  Steps left in one colour class are symmetric under
    both relations: picking either yields the same signature, so the
    caller's recorded-index fallback is then safe."""
    n = len(steps)

    def dense_ranks(ks: List[Tuple]) -> List[int]:
        rank = {k: r for r, k in enumerate(sorted(set(ks)))}
        return [rank[k] for k in ks]

    def static_key(st: ProgramStep) -> Tuple:
        local: Dict[int, int] = {}

        def ref(slot: Slot) -> Tuple:
            li = local.setdefault(slot.sid, len(local))
            return (slot.size, _dtype_str(slot.dtype), slot.kind, li)

        return (_sortable_attrs_key(st.attrs),
                tuple((m.src, m.dst, ref(m.src_slot), m.src_off,
                       ref(m.dst_slot), m.dst_off, m.size, m.origin)
                      for m in st.msgs))

    colors = dense_ranks([static_key(st) for st in steps])

    descr: Dict[int, Tuple] = {}
    roles: List[Dict[int, Tuple]] = []
    for st in steps:
        rmap: Dict[int, set] = {}
        for m in st.msgs:
            rmap.setdefault(m.src_slot.sid, set()).add("r")
            rmap.setdefault(m.dst_slot.sid, set()).add("w")
            for slot in (m.src_slot, m.dst_slot):
                descr.setdefault(slot.sid, (slot.size,
                                            _dtype_str(slot.dtype),
                                            slot.kind))
        roles.append({sid: tuple(sorted(rs)) for sid, rs in rmap.items()})

    edges: List[List[Tuple[Tuple, int]]] = [[] for _ in range(n)]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            labs: List[Tuple] = []
            if i in preds[j]:
                labs.append(("dag", "succ"))
            if j in preds[i]:
                labs.append(("dag", "pred"))
            for sid in roles[i].keys() & roles[j].keys():
                labs.append(("slot", roles[i][sid], roles[j][sid],
                             descr[sid]))
            if labs:
                edges[i].append((tuple(sorted(labs)), j))

    for _ in range(n):
        refined = dense_ranks([
            (colors[i], tuple(sorted((lab, colors[j])
                                     for lab, j in edges[i])))
            for i in range(n)])
        if refined == colors:
            break
        colors = refined
    return colors


def _order_sig(steps: Sequence[ProgramStep],
               order: Sequence[int]) -> Tuple:
    """Totally-ordered content signature of a completed order — what the
    canonical-form tie-break compares.  Same renaming discipline as
    :func:`program_signature` (slots by first occurrence across the
    ordered trace) but with :func:`_sortable_attrs_key` so candidate
    signatures compare under ``min`` even when attrs hold ``None`` or
    :class:`CompressSpec` fields."""
    _, _, key = _slot_canon()
    out = []
    for i in order:
        st = steps[i]
        out.append((_sortable_attrs_key(st.attrs),
                    tuple((m.src, m.dst, key(m.src_slot), m.src_off,
                           key(m.dst_slot), m.dst_off, m.size, m.origin)
                          for m in st.msgs)))
    return tuple(out)


def canonical_order(steps: Sequence[ProgramStep]) -> List[int]:
    """A deterministic topological order of the trace's must-precede DAG,
    chosen by step *content* rather than recorded position: among ready
    steps the one with the smallest content key (attributes + message
    table, slots referred to by their already-assigned canonical index
    or, when unseen, by descriptor) is scheduled first.

    Two recordings that are legal reorderings of each other have the
    same DAG and the same step contents, so they canonicalize to the
    same sequence — which is what lets :func:`program_signature` give
    them one :class:`ProgramCache` entry.  Steps with bit-identical
    content keys are separated by :func:`_structural_ranks` (footprint +
    table-shape colour refinement over the conflict DAG and slot-sharing
    relation — order-invariant, so both reorderings break the tie the
    same way).  Refinement is incomplete (it is 1-WL): steps can share a
    colour class without any automorphism mapping one to the other, and
    there the recorded-index fallback would split one program into two
    cache entries.  Such residual ties are resolved by *canonical-form
    comparison*: each tied candidate's completion is computed and the
    one whose finished :func:`_order_sig` is smallest wins — a choice
    that depends only on content, never on recorded position.  Truly
    symmetric candidates produce equal signatures, so either completion
    is the same signature and the pick is free.  The branching is
    bounded by :data:`TIE_BRANCH_BUDGET`; past it the recorded-index
    fallback applies (benign only for automorphic ties)."""
    n = len(steps)
    if n <= 1:
        return list(range(n))
    preds = _conflict_dag([st.msgs for st in steps])
    succs: List[List[int]] = [[] for _ in range(n)]
    for j, pr in enumerate(preds):
        for i in pr:
            succs[i].append(j)
    sids = [{m.src_slot.sid for m in st.msgs}
            | {m.dst_slot.sid for m in st.msgs} for st in steps]
    ranks_box: List[Optional[List[int]]] = [None]  # lazy: ties are rare
    budget = [TIE_BRANCH_BUDGET]

    def step_key(st: ProgramStep, canon: Dict[int, int]) -> Tuple:
        local: Dict[int, int] = {}

        def ref(slot: Slot) -> Tuple:
            idx = canon.get(slot.sid)
            if idx is not None:
                return (0, idx, "", "", 0)
            li = local.setdefault(slot.sid, len(local))
            return (1, slot.size, _dtype_str(slot.dtype), slot.kind, li)

        return (_sortable_attrs_key(st.attrs),
                tuple((m.src, m.dst, ref(m.src_slot), m.src_off,
                       ref(m.dst_slot), m.dst_off, m.size, m.origin)
                      for m in st.msgs))

    def place(i: int, canon: Dict[int, int], npreds: List[int],
              ready: List[int], keys: Dict[int, Tuple],
              order: List[int]) -> None:
        ready.remove(i)
        order.append(i)
        newly: set = set()
        for m in steps[i].msgs:
            for slot in (m.src_slot, m.dst_slot):
                if slot.sid not in canon:
                    canon[slot.sid] = len(canon)
                    newly.add(slot.sid)
        if newly:
            # a slot just gained its canonical index: keys that referred
            # to it by descriptor must be recomputed
            for k in ready:
                if sids[k] & newly:
                    keys.pop(k, None)
        for j in succs[i]:
            npreds[j] -= 1
            if npreds[j] == 0:
                ready.append(j)

    def complete(canon: Dict[int, int], npreds: List[int],
                 ready: List[int], order: List[int]) -> List[int]:
        keys: Dict[int, Tuple] = {}
        while ready:
            for i in ready:
                if i not in keys:
                    keys[i] = step_key(steps[i], canon)
            best = min(ready, key=lambda i: (keys[i], i))
            tied = [i for i in ready if keys[i] == keys[best]]
            if len(tied) > 1:
                if ranks_box[0] is None:
                    ranks_box[0] = _structural_ranks(steps, preds)
                ranks = ranks_box[0]
                rbest = min(ranks[i] for i in tied)
                tied = [i for i in tied if ranks[i] == rbest]
                best = min(tied)
                if len(tied) > 1 and budget[0] >= len(tied):
                    # canonical-form comparison: finish the order once
                    # per candidate, keep the smallest finished
                    # signature (content-only, order-invariant)
                    budget[0] -= len(tied)
                    cands = []
                    for i in tied:
                        c2, np2 = dict(canon), list(npreds)
                        r2, o2 = list(ready), list(order)
                        place(i, c2, np2, r2, {}, o2)
                        done = complete(c2, np2, r2, o2)
                        cands.append((_order_sig(steps, done), done))
                    return min(cands, key=lambda c: c[0])[1]
            place(best, canon, npreds, ready, keys, order)
        return order

    npreds0 = [len(pr) for pr in preds]
    return complete({}, npreds0,
                    [i for i in range(n) if npreds0[i] == 0], [])


def program_signature(steps: Sequence[ProgramStep], p: int,
                      scratch: Optional[Slot] = None,
                      order: Optional[Sequence[int]] = None) -> Hashable:
    """Canonical key of a recorded trace: steps taken in
    :func:`canonical_order` — so legal reorderings of the same program
    share one key — with slot ids renamed by first occurrence across
    *all* ordered supersteps (a slot reused by two supersteps must keep
    the same index — cross-superstep dataflow is part of the program),
    plus per-step attributes and message order."""
    if order is None:
        order = canonical_order(steps)
    _, descrs, key = _slot_canon()
    step_sigs = []
    for i in order:
        st = steps[i]
        table = tuple((m.src, m.dst, key(m.src_slot), m.src_off,
                       key(m.dst_slot), m.dst_off, m.size, m.origin)
                      for m in st.msgs)
        step_sigs.append((_attrs_key(st.attrs), table))
    scratch_sig = None if scratch is None else \
        (scratch.size, _dtype_str(scratch.dtype))
    return (p, scratch_sig, tuple(descrs), tuple(step_sigs))


# ==========================================================================
# the optimizer
# ==========================================================================

def _ranges_overlap(a_off: int, a_size: int, b_off: int, b_size: int) -> bool:
    return a_off < b_off + b_size and b_off < a_off + a_size


def _writes_overlap(a: Msg, b: Msg) -> bool:
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and _ranges_overlap(a.dst_off, a.size, b.dst_off, b.size))


def _reads_write(reader: Msg, writer: Msg) -> bool:
    """Does ``reader``'s source range observe ``writer``'s destination?"""
    return (reader.src == writer.dst
            and reader.src_slot.sid == writer.dst_slot.sid
            and _ranges_overlap(reader.src_off, reader.size,
                                writer.dst_off, writer.size))


def _coalesce_step(msgs: List[Msg], attrs: SyncAttributes
                   ) -> Tuple[List[Msg], int]:
    """Merge same-(src, dst, slot-pair, origin) messages contiguous in
    both offsets.  With CRCW semantics a merged write must not conflict
    with any *other* message of the step (merging would move it in the
    arbitration order); accumulating supersteps combine commutatively,
    so contiguity alone suffices."""
    if len(msgs) < 2:
        return msgs, 0
    groups: "collections.OrderedDict[Tuple, List[int]]" = \
        collections.OrderedDict()
    for i, m in enumerate(msgs):
        groups.setdefault((m.src, m.dst, m.src_slot.sid, m.dst_slot.sid,
                           m.origin), []).append(i)
    merged: Dict[int, Msg] = {}      # first-piece index -> merged msg
    dropped: set = set()
    for idxs in groups.values():
        if len(idxs) < 2:
            continue
        run = sorted(idxs, key=lambda i: msgs[i].src_off)
        k = 0
        while k < len(run):
            first = run[k]
            cur = msgs[first]
            pieces = [first]
            while k + 1 < len(run):
                nxt = msgs[run[k + 1]]
                if (cur.src_off + cur.size == nxt.src_off
                        and cur.dst_off + cur.size == nxt.dst_off):
                    cur = dataclasses.replace(cur, size=cur.size + nxt.size)
                    pieces.append(run[k + 1])
                    k += 1
                else:
                    break
            k += 1
            if len(pieces) == 1:
                continue
            if attrs.reduce_op is None:
                others = [m for j, m in enumerate(msgs)
                          if j not in pieces]
                if any(_writes_overlap(cur, o) for o in others):
                    continue   # merging would reorder a CRCW conflict
            merged[min(pieces)] = cur
            dropped.update(p_ for p_ in pieces if p_ != min(pieces))
    if not merged:
        return msgs, 0
    out = [merged.get(i, m) for i, m in enumerate(msgs) if i not in dropped]
    return out, len(dropped)


def _group_order(msgs: Sequence[Msg]) -> List[Tuple[int, int]]:
    """Slot-pair groups in first-occurrence order — the order the direct
    executor applies them in (cross-group CRCW arbitration)."""
    seen: List[Tuple[int, int]] = []
    for m in msgs:
        k = (m.src_slot.sid, m.dst_slot.sid)
        if k not in seen:
            seen.append(k)
    return seen


def _dead_msgs(tables: List[List[Msg]],
               attrs_list: List[SyncAttributes], i: int) -> List[int]:
    """Indices into ``tables[i]`` of messages whose destination range is
    completely overwritten by a single later message before any read
    (message sources are the only reads inside a trace; local compute
    flushes the trace, so a flushed trace has no interior compute reads;
    the trace end is a read of everything)."""
    dead = []
    for k, m in enumerate(tables[i]):
        for j in range(i + 1, len(tables)):
            if any(_reads_write(r, m) for r in tables[j]):
                break               # observed before any full overwrite
            if attrs_list[j].compress is not None:
                continue            # lossy wire: not a clean overwrite
            if any(w.dst == m.dst
                   and w.dst_slot.sid == m.dst_slot.sid
                   and w.dst_off <= m.dst_off
                   and w.dst_off + w.size >= m.dst_off + m.size
                   for w in tables[j]):
                dead.append(k)
                break
    return dead


def _msgs_conflict(ma: Msg, mb: Msg) -> bool:
    """Do two messages from different supersteps fail to commute?
    True when either reads the other's write (RAW/WAR) or their
    destination ranges overlap (WAW — ordering would elect the winner).
    The single source of truth for both the cone flush's must-precede
    relation and the overlap gate's commutation check."""
    return (_reads_write(mb, ma) or _reads_write(ma, mb)
            or _writes_overlap(ma, mb))


def _must_precede(a: ProgramStep, b: ProgramStep) -> bool:
    """Must ``a`` (staged before ``b``) still execute before ``b``?
    True when reordering them is observable: ``b`` reads ``a``'s writes
    (RAW), ``a`` reads ranges ``b`` writes (WAR — executing ``b`` first
    would leak its writes into ``a``'s reads), or their destination
    ranges overlap (WAW — arbitration order would flip)."""
    return _tables_conflict(a.msgs, b.msgs)


def dependency_cone(steps: Sequence[ProgramStep], sid: int,
                    include_reads: bool = False) -> List[int]:
    """The dataflow-precise flush set: indices (sorted, ascending) of the
    pending supersteps a local read of slot ``sid`` depends on — the
    steps that write the slot, closed backwards under
    :func:`_must_precede` so that executing the cone now and the
    remaining steps later is indistinguishable from executing the whole
    trace in order.  With ``include_reads`` (a local *write* of the
    slot) steps that read the slot join the initial set too (they must
    observe the pre-write value)."""
    need: set = set()
    for i, st in enumerate(steps):
        for m in st.msgs:
            if m.dst_slot.sid == sid or (include_reads
                                         and m.src_slot.sid == sid):
                need.add(i)
                break
    # backward closure only: a deferred step *after* a cone step keeps
    # its original relative order when it flushes later, so only earlier
    # steps can be pulled in.  Worklist form: each step enters the
    # frontier once, so every (x, y) pair is tested at most once —
    # O(n^2) _must_precede calls per flush, not a fixpoint re-scan.
    frontier = sorted(need, reverse=True)
    while frontier:
        y = frontier.pop()
        for x in range(y):
            if x not in need and _must_precede(steps[x], steps[y]):
                need.add(x)
                frontier.append(x)
    return sorted(need)


def _independent(earlier: Sequence[Msg], later: Sequence[Msg],
                 reduce_op: Optional[str]) -> bool:
    """May ``later`` run in the same superstep as ``earlier``?  Requires
    that no later message reads an earlier write (merged reads observe
    pre-superstep state) and no destination ranges overlap across the
    two (merged CRCW arbitration could elect a different winner; merged
    accumulation would combine instead of overwrite).  For CRCW steps
    the concatenation must also preserve ``later``'s internal group
    order: a slot-pair group already present in ``earlier`` would hoist
    to its position, reordering ``later``'s own cross-group conflicts."""
    for m2 in later:
        for m1 in earlier:
            if _reads_write(m2, m1) or _writes_overlap(m1, m2):
                return False
    if reduce_op is None:
        later_groups = set(_group_order(later))
        merged_order = [g for g in _group_order(list(earlier) + list(later))
                        if g in later_groups]
        if merged_order != _group_order(later):
            return False
    return True


def _cost_of(plan: SuperstepPlan, machine: LPFMachine) -> float:
    return plan.cost.wire_bytes * machine.g + plan.cost.rounds * machine.l


def _can_overlap(earlier: Sequence[Msg], later: Sequence[Msg]) -> bool:
    """May ``later`` issue split-phase alongside ``earlier``?  The two
    supersteps must *commute*: no read of either may observe a write of
    the other (RAW in both directions — the split-phase lowering runs
    all reads before all writes, but commutation is what the reference
    interpreter validates and what keeps the members order-free), and no
    destination ranges may overlap (WAW — finish order would elect the
    winner).  Note this is weaker than :func:`_independent`: the tables
    are never concatenated, so each member keeps its own attributes,
    plan and internal CRCW arbitration order."""
    for m2 in later:
        for m1 in earlier:
            if _msgs_conflict(m1, m2):
                return False
    return True


def _tables_conflict(ta: Sequence[Msg], tb: Sequence[Msg]) -> bool:
    """Must-precede over rewritten tables (post coalesce/DTE): same
    relation as :func:`_must_precede`, on message lists."""
    for ma in ta:
        for mb in tb:
            if _msgs_conflict(ma, mb):
                return True
    return False


def _conflict_dag(tables: Sequence[Sequence[Msg]]) -> List[set]:
    """``preds[j] = {i < j : tables[i] must precede tables[j]}`` — the
    single must-precede DAG builder shared by :func:`canonical_order`
    and the scheduler passes, with a cheap (pid, slot) footprint
    prefilter: two steps can only conflict when a write footprint meets
    the other's read or write footprint, so the O(m_a*m_b) interval
    scan runs only on overlapping footprints."""
    n = len(tables)
    reads = [{(m.src, m.src_slot.sid) for m in t} for t in tables]
    writes = [{(m.dst, m.dst_slot.sid) for m in t} for t in tables]
    preds: List[set] = [set() for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if ((writes[i] & reads[j]) or (writes[j] & reads[i])
                    or (writes[i] & writes[j])) \
                    and _tables_conflict(tables[i], tables[j]):
                preds[j].add(i)
    return preds


def _merge_reads_ok(earlier: Sequence[Msg], later: Sequence[Msg]) -> bool:
    """No message of ``later`` reads a range ``earlier`` writes — the
    RAW half of merge legality (merged reads observe pre-superstep
    state; WAR is legal in a merge, WAW is checked by the caller via
    :func:`repro.core.sync.conflict_free` for method rewrites)."""
    for m2 in later:
        for m1 in earlier:
            if _reads_write(m2, m1):
                return False
    return True


@dataclasses.dataclass
class _Group:
    """Scheduler working state for one output superstep."""

    msgs: List[Msg]
    attrs: SyncAttributes
    label: str
    members: List[int]          # canonical ranks merged into this step
    plan: SuperstepPlan
    rewrite: str = ""


def optimize_program(steps: Sequence[ProgramStep], p: int,
                     machine: LPFMachine,
                     plan_cache: Optional[PlanCache] = None,
                     scratch: Optional[Slot] = None,
                     search: bool = True,
                     order: Optional[Sequence[int]] = None
                     ) -> SuperstepProgram:
    """Rewrite one recorded trace: coalesce, eliminate dead transfers,
    then run the cost-gated DAG list-scheduling search — non-adjacent
    superstep batching, Valiant-aware attr rewrites, non-adjacent
    split-phase overlap grouping — and plan every surviving superstep.
    Pure trace-time Python — no JAX ops.

    ``search=False`` keeps the trace in recorded order and restores the
    adjacent-pairs peephole (the pre-search optimizer), as the baseline
    the schedule benchmarks measure against.  ``order`` is an optional
    precomputed :func:`canonical_order` (the caller may share one with
    :func:`program_signature`)."""
    plan = (plan_cache.get_or_plan if plan_cache is not None
            else lambda m, p_, a, s=None: plan_sync(m, p_, a, s))

    def plan_of(msgs: List[Msg], attrs: SyncAttributes) -> SuperstepPlan:
        return plan(msgs, p, attrs, scratch)

    if not search:
        order = list(range(len(steps)))
    elif order is None:
        order = canonical_order(steps)
    steps = [steps[i] for i in order]

    tables = [list(st.msgs) for st in steps]
    attrs_list = [st.attrs for st in steps]
    labels = [st.label for st in steps]
    modified = [False] * len(tables)

    # (1) coalesce within each superstep, gated on the planned cost
    n_coalesced = 0
    for i in range(len(tables)):
        cand, n = _coalesce_step(tables[i], attrs_list[i])
        if n == 0:
            continue
        if _cost_of(plan_of(cand, attrs_list[i]), machine) <= \
                _cost_of(plan_of(tables[i], attrs_list[i]), machine):
            tables[i] = cand
            modified[i] = True
            n_coalesced += n

    # (2) dead-transfer elimination across supersteps, gated per step —
    # removing a message can demote a fused classification (a total
    # exchange minus one message is coloured rounds), so a rewrite only
    # lands when the planned cost does not regress
    n_eliminated = 0
    for i in range(len(tables)):
        dead = _dead_msgs(tables, attrs_list, i)
        if not dead:
            continue
        # removing a group's first message can reorder the cross-group
        # CRCW application order; admit kills one by one, keeping the
        # surviving groups' relative order intact
        kill: List[int] = []
        for k in dead:
            trial = set(kill) | {k}
            cand = [m for idx, m in enumerate(tables[i])
                    if idx not in trial]
            surviving = {(m.src_slot.sid, m.dst_slot.sid) for m in cand}
            old_order = [g for g in _group_order(tables[i])
                         if g in surviving]
            if attrs_list[i].reduce_op is not None or \
                    _group_order(cand) == old_order:
                kill.append(k)
        if not kill:
            continue
        cand = [m for idx, m in enumerate(tables[i])
                if idx not in set(kill)]
        if _cost_of(plan_of(cand, attrs_list[i]), machine) <= \
                _cost_of(plan_of(tables[i], attrs_list[i]), machine):
            tables[i] = cand
            modified[i] = True
            n_eliminated += len(kill)

    n = len(tables)
    n_hoisted = 0
    n_rewritten = 0

    def merged_plan_or_none(cand: List[Msg], attrs: SyncAttributes
                            ) -> Optional[SuperstepPlan]:
        try:
            return plan_of(cand, attrs)
        except LPFFatalError:       # e.g. bruck multigraph limits,
            return None             # valiant scratch overflow

    def valiant_eligible(attrs: SyncAttributes) -> bool:
        # a method rewrite must not change CRCW winners or combine
        # semantics, and needs the context's scratch slot provisioned
        return (scratch is not None and attrs.reduce_op is None
                and attrs.compress is None
                and attrs.method in ("auto", "direct"))

    def valiant_attrs(a: SyncAttributes,
                      b: Optional[SyncAttributes] = None) -> SyncAttributes:
        no_conf = a.no_conflict and (b is None or b.no_conflict)
        return a.replace(method="valiant", no_conflict=no_conf)

    # the rewritten tables are fixed from here on: plan each once (the
    # growth loop re-scans candidates, and must not re-consult the
    # planner per scan)
    step_plans = [plan_of(tables[i], attrs_list[i]) for i in range(n)]
    # the in-order baseline explain() reports against: untouched steps
    # reuse their step plan, only coalesced/DTE'd ones re-plan raw msgs
    in_order_costs = tuple(
        (step_plans[i] if not modified[i]
         else plan_of(list(steps[i].msgs), attrs_list[i])).cost
        for i in range(n))

    def try_merge(g: _Group, j: int) -> bool:
        """Attempt to fold canonical rank ``j`` into group ``g``; both
        the equal-attrs merge and the Valiant-aware rewrite are gated on
        the planned cost of the merged table strictly beating the best
        alternative schedule of the members — separate supersteps, or
        (when both commute and are overlappable) a split-phase overlap
        group, which the later overlap pass could otherwise form."""
        msgs_j, attrs_j = tables[j], attrs_list[j]
        if not g.msgs or not msgs_j:
            return False
        plan_j = step_plans[j]
        sep = _cost_of(g.plan, machine) + _cost_of(plan_j, machine)
        if g.plan.method in OVERLAPPABLE_METHODS \
                and plan_j.method in OVERLAPPABLE_METHODS \
                and _can_overlap(g.msgs, msgs_j):
            sep = min(sep, overlap_cost(
                [g.plan.cost, plan_j.cost]).predicted_seconds(machine))
        if not g.rewrite and attrs_j == g.attrs and \
                _independent(g.msgs, msgs_j, g.attrs.reduce_op):
            cand = g.msgs + msgs_j
            mp = merged_plan_or_none(cand, g.attrs)
            if mp is not None and _cost_of(mp, machine) < sep:
                g.msgs, g.plan = cand, mp
                return True
        # Valiant-aware rewrite: the merge gate refused (differing
        # attrs, or the merged plan priced higher).  For plain
        # conflict-free CRCW traffic whose separate schedules are
        # round-heavy (skewed/fragmented), price the merged fat
        # superstep routed through two-phase Valiant instead; a method
        # rewrite is only admissible when arbitration order cannot be
        # observed (conflict_free) and no member reads another's writes.
        if valiant_eligible(g.attrs) and valiant_eligible(attrs_j) \
                and g.plan.cost.rounds + plan_j.cost.rounds \
                >= VALIANT_REWRITE_MIN_ROUNDS \
                and _merge_reads_ok(g.msgs, msgs_j):
            cand = g.msgs + msgs_j
            if conflict_free(cand):
                vattrs = valiant_attrs(g.attrs, attrs_j)
                vp = merged_plan_or_none(cand, vattrs)
                if vp is not None and _cost_of(vp, machine) < sep:
                    g.msgs, g.attrs, g.plan = cand, vattrs, vp
                    g.rewrite = "valiant"
                    return True
        return False

    def maybe_valiant_upgrade(g: _Group) -> None:
        """A skewed/fragmented fat superstep on its own: rewrite its
        attrs to route it two-phase iff strictly cheaper."""
        if g.rewrite or not valiant_eligible(g.attrs) \
                or g.plan.cost.rounds < VALIANT_REWRITE_MIN_ROUNDS \
                or not conflict_free(g.msgs):
            return
        vp = merged_plan_or_none(g.msgs, valiant_attrs(g.attrs))
        if vp is not None and _cost_of(vp, machine) < \
                _cost_of(g.plan, machine):
            g.attrs, g.plan, g.rewrite = valiant_attrs(g.attrs), vp, \
                "valiant"

    # (3) superstep batching as DAG list scheduling: walk the
    # must-precede DAG over the rewritten tables; each emitted superstep
    # greedily absorbs ANY still-unscheduled step whose predecessors are
    # already placed — non-adjacent independent supersteps hoist over
    # intervening steps — with every fold cost-gated, and refused folds
    # offered to the Valiant-aware rewrite.
    groups: List[_Group] = []
    if search:
        preds = _conflict_dag(tables)
        scheduled: set = set()
        remaining = list(range(n))
        while remaining:
            first = next(k for k in remaining if preds[k] <= scheduled)
            g = _Group(msgs=tables[first], attrs=attrs_list[first],
                       label=labels[first], members=[first],
                       plan=step_plans[first])
            grew = True
            while grew:
                grew = False
                mset = set(g.members)
                for j in remaining:
                    if j in mset or not (preds[j] <= scheduled | mset):
                        continue
                    if try_merge(g, j):
                        # a hoist is non-adjacency in the RECORDED
                        # order (canonicalization may already have
                        # moved steps next to each other)
                        if order[j] != order[g.members[-1]] + 1:
                            n_hoisted += 1
                        g.members.append(j)
                        g.label = f"{g.label}+{labels[j]}"
                        mset.add(j)
                        grew = True
            maybe_valiant_upgrade(g)
            if g.rewrite:
                n_rewritten += 1
            groups.append(g)
            scheduled |= set(g.members)
            member_set = set(g.members)
            remaining = [k for k in remaining if k not in member_set]
    else:
        # the adjacent-pairs peephole (pre-search baseline)
        for i, (msgs, attrs, label) in enumerate(zip(tables, attrs_list,
                                                     labels)):
            if groups:
                g = groups[-1]
                if (g.msgs and msgs and attrs == g.attrs
                        and _independent(g.msgs, msgs, attrs.reduce_op)):
                    cand = g.msgs + msgs
                    mp = merged_plan_or_none(cand, attrs)
                    if mp is not None and _cost_of(mp, machine) < \
                            _cost_of(g.plan, machine) + \
                            _cost_of(step_plans[i], machine):
                        g.msgs, g.plan = cand, mp
                        g.label = f"{g.label}+{label}"
                        g.members.append(i)
                        continue
            groups.append(_Group(msgs=msgs, attrs=attrs, label=label,
                                 members=[i], plan=step_plans[i]))
    n_merged = len(tables) - len(groups)

    # (4) overlap grouping as DAG list scheduling: supersteps the merge
    # gate kept separate (differing attrs, or a merged plan the model
    # prices higher) are issued split-phase — all starts, then all
    # dones — priced max(h_i)*g + max(rounds_i)*l + (k-1)*l_overlap.
    # The search hoists any READY superstep (all predecessors emitted)
    # into the group, non-adjacent or not; a group only grows while the
    # overlapped time is predicted below the sequential sum.
    m = len(groups)
    ogroups: List[List[int]] = []
    if search:
        gpreds = _conflict_dag([g.msgs for g in groups])
        emitted: set = set()
        gremaining = list(range(m))
        while gremaining:
            i = next(k for k in gremaining if gpreds[k] <= emitted)
            grp = [i]
            if groups[i].plan.method in OVERLAPPABLE_METHODS:
                for j in gremaining:
                    if j == i or j in grp:
                        continue
                    if groups[j].plan.method not in OVERLAPPABLE_METHODS:
                        continue
                    # a member of grp is not yet emitted: j must not
                    # depend on one (its start would read stale state)
                    if not (gpreds[j] <= emitted):
                        continue
                    if not all(_can_overlap(groups[k].msgs,
                                            groups[j].msgs) for k in grp):
                        continue
                    costs = [groups[k].plan.cost for k in grp] \
                        + [groups[j].plan.cost]
                    if overlap_cost(costs).predicted_seconds(machine) < \
                            sum(c.predicted_seconds(machine)
                                for c in costs):
                        # recorded-order adjacency, as in the merge pass
                        if min(order[r] for r in groups[j].members) != \
                                max(order[r] for r in
                                    groups[grp[-1]].members) + 1:
                            n_hoisted += 1
                        grp.append(j)
            ogroups.append(grp)
            emitted |= set(grp)
            grp_set = set(grp)
            gremaining = [k for k in gremaining if k not in grp_set]
    else:
        for j in range(m):
            if ogroups and groups[j].plan.method in OVERLAPPABLE_METHODS:
                cur = ogroups[-1]
                members_ok = all(
                    groups[i].plan.method in OVERLAPPABLE_METHODS
                    and _can_overlap(groups[i].msgs, groups[j].msgs)
                    for i in cur)
                if members_ok:
                    seq = sum(groups[i].plan.cost.predicted_seconds(
                        machine) for i in cur) \
                        + groups[j].plan.cost.predicted_seconds(machine)
                    grouped = overlap_cost(
                        [groups[i].plan.cost for i in cur]
                        + [groups[j].plan.cost]).predicted_seconds(machine)
                    if grouped < seq:
                        cur.append(j)
                        continue
            ogroups.append([j])
    n_overlapped = len(groups) - len(ogroups)

    # emit in the scheduled order: the overlap pass's emission sequence
    # is the program's execution order; overlap_groups become ranges of
    # consecutive output positions
    perm = [i for grp in ogroups for i in grp]
    out_ogroups: List[Tuple[int, ...]] = []
    pos = 0
    for grp in ogroups:
        out_ogroups.append(tuple(range(pos, pos + len(grp))))
        pos += len(grp)

    _, _, canon_key = _slot_canon()
    # canonical indices must follow the (canonically ordered) trace's
    # first-occurrence order — what trace_slot_map of a replayed trace
    # reproduces — not the optimized tables' (an eliminated or hoisted
    # first occurrence would skew them)
    for st in steps:
        for msg in st.msgs:
            canon_key(msg.src_slot)
            canon_key(msg.dst_slot)

    opt_steps = []
    for gi in perm:
        g = groups[gi]
        table = tuple((msg.src, msg.dst, canon_key(msg.src_slot),
                       msg.src_off, canon_key(msg.dst_slot), msg.dst_off,
                       msg.size, msg.origin)
                      for msg in g.msgs)
        opt_steps.append(OptimizedStep(
            table=table, attrs=g.attrs, label=g.label,
            plan=g.plan, merged_from=tuple(g.members),
            unchanged=(len(g.members) == 1 and not modified[g.members[0]]
                       and not g.rewrite),
            rewrite=g.rewrite))
    return SuperstepProgram(
        p=p, steps=tuple(opt_steps), n_recorded=len(steps),
        n_coalesced=n_coalesced, n_eliminated=n_eliminated,
        n_merged=n_merged,
        overlap_groups=tuple(out_ogroups),
        n_overlapped=n_overlapped, n_rewritten=n_rewritten,
        n_hoisted=n_hoisted, in_order_costs=in_order_costs,
        canonical=search)


# ==========================================================================
# whole-program compilation
# ==========================================================================

@dataclasses.dataclass
class CompiledProgram:
    """An optimized program lowered into ONE jitted function.

    Step-by-step replay pays a Python dispatch (plan lookup, executor
    re-trace under the outer jit, per-superstep bookkeeping) per issue
    group; for small-h programs that overhead dominates the modelled
    cost.  Following the torch_xla ``fori_loop`` / pMR persistent-
    communication-object pattern, the whole schedule — every superstep
    *and* the canonical dataflow between them — is traced once against a
    :class:`repro.core.sync.ValueStore` over canonical slots and jitted;
    replays feed the actual slot values in and write the results back.

    Validity is anchored to the program signature: the canonical tables
    name slots by canonical index, the signature pins every index's
    (size, dtype, kind) descriptor and the scratch descriptor, so any
    trace that maps to this cache key can run through this function.
    The ledger is NOT produced inside the jitted body (cost records are
    static Python); callers append
    :meth:`SuperstepProgram.ledger_costs`, which is by construction
    identical to what step-by-step execution returns."""

    prog: SuperstepProgram
    slots: Tuple[Slot, ...]          # canonical slots, sid == index
    scratch: Optional[Slot]          # canonical scratch (valiant), or None
    fn: Callable = dataclasses.field(repr=False, default=None)
    n_calls: int = 0

    def __call__(self, myid, values, scratch_val=None):
        self.n_calls += 1
        if self.scratch is not None:
            return self.fn(myid, tuple(values), scratch_val)
        return self.fn(myid, tuple(values)), scratch_val


def compile_program(prog: SuperstepProgram, steps: Sequence[ProgramStep],
                    order: Sequence[int], p: int,
                    axes: Tuple[str, ...],
                    scratch: Optional[Slot] = None) -> CompiledProgram:
    """Lower ``prog`` into a :class:`CompiledProgram` for ``(p, axes)``.

    ``steps``/``order`` are any trace/canonical-order pair matching the
    program's signature — only their slot *descriptors* are consulted
    (to synthesize the canonical slot list), so the compiled function is
    reusable by every trace that hits the same cache entry."""
    import jax

    # fault seam: an armed plan may stand in for an XLA compilation
    # failure here; callers degrade to the dispatched schedule
    _fp.fire("compile", label=getattr(prog, "label", ""))

    actual = trace_slot_map(steps, order)
    slots = tuple(Slot(i, f"__prog_slot{i}", s.size, s.dtype, s.kind,
                       (s.size,))
                  for i, s in enumerate(actual))
    # valiant phase-1 bounces through the scratch slot; sid -1 cannot
    # collide with a canonical index
    need_scratch = any(st.plan.method == "valiant" for st in prog.steps)
    if need_scratch and scratch is None:
        raise LPFFatalError("program contains valiant supersteps but the "
                            "context has no scratch slot")
    cscratch = Slot(-1, "__prog_scratch", scratch.size, scratch.dtype,
                    "global", (scratch.size,)) if need_scratch else None

    entries = []
    for st in prog.steps:
        # rebuild from the canonical table unconditionally (an
        # ``unchanged`` step's table IS its staged messages modulo the
        # slot renaming, and the compiled body must speak canonical sids)
        msgs = [Msg(src, dst, slots[si], so, slots[di], do, sz,
                    origin=origin)
                for (src, dst, si, so, di, do, sz, origin) in st.table]
        entries.append((msgs, st.attrs, st.label, st.plan))
    groups = prog.groups()

    if need_scratch:
        def run(myid, vals, scratch_val):
            store = ValueStore({s.sid: v for s, v in zip(slots, vals)})
            store.set_value(cscratch, scratch_val)
            execute_schedule(entries, groups, store, p, axes, myid,
                             scratch=cscratch)
            return (tuple(store.value(s) for s in slots),
                    store.value(cscratch))
    else:
        def run(myid, vals):
            store = ValueStore({s.sid: v for s, v in zip(slots, vals)})
            execute_schedule(entries, groups, store, p, axes, myid)
            return tuple(store.value(s) for s in slots)

    return CompiledProgram(prog=prog, slots=slots, scratch=cscratch,
                           fn=jax.jit(run))


# ==========================================================================
# the program cache
# ==========================================================================

class ProgramCache:
    """LRU memo of :class:`SuperstepProgram` keyed by
    :func:`program_signature` — the program-level twin of
    :class:`repro.core.sync.PlanCache`.  A replayed trace skips the
    optimizer *and* the planner (every optimized step carries its plan).

    With a persistent store attached (:meth:`attach_store`, or
    ``LPFContext(persist_dir=...)`` / ``LPF_PROGRAM_CACHE_DIR``),
    entries additionally survive the process: certified programs are
    written back on insert and on eviction, and an in-memory miss
    consults the disk before paying the schedule search.  A loaded
    entry is **re-verified** against the actual recorded trace
    (``verify_program``) before it is served — corruption, version
    skew, or a stale schedule degrades to a cold miss (counted in
    ``stats.invalidated``), never an unverified execution."""

    #: bounded-backoff retry budget for one persistent-store operation
    #: (transient I/O only; corruption is never retried)
    DISK_RETRIES = 2
    DISK_BACKOFF = 0.01      # seconds, doubled per retry
    #: consecutive failed store *operations* after which the cache
    #: degrades to memory-only mode (detaches the store) — a dead disk
    #: must not tax every miss with a retry loop
    DISK_STRIKE_LIMIT = 3

    def __init__(self, maxsize: int = 256,
                 persist_dir: Optional[str] = None):
        self.maxsize = maxsize
        self._programs: "collections.OrderedDict[Hashable, SuperstepProgram]" \
            = collections.OrderedDict()
        #: program key -> {axes tuple: CompiledProgram}; a compiled
        #: artifact is only valid alongside its program entry, so
        #: eviction drops both (LRU coherence)
        self._compiled: Dict[Hashable, Dict[Tuple[str, ...],
                                            "CompiledProgram"]] = {}
        #: program key -> schedule-verifier certificate
        #: (:class:`repro.analysis.VerifierReport`); ``set_compiled``
        #: refuses keys without a passing one
        self._certs: Dict[Hashable, Any] = {}
        self.stats = CacheStats()
        self._store = None
        #: keys known to be on disk already (avoids rewriting an entry
        #: on every certify/evict of the same program)
        self._persisted: set = set()
        #: entry filenames that repeatedly fail decode/re-verification
        #: AND could not be removed (read-only cache dir): poisoned in
        #: memory so a corrupt-but-undeletable file costs ONE decode +
        #: verify, not one per miss
        self._poisoned: set = set()
        #: (key, axes) pairs whose whole-program compilation failed:
        #: replays go straight to the dispatched path instead of
        #: re-paying a doomed XLA compile every flush
        self._quarantined: Dict[Hashable, set] = {}
        #: keys exempt from LRU eviction (:meth:`pin`) — the serving
        #: path pins its hot decode-bucket programs so a burst of cold
        #: one-shot signatures can never evict the entries every
        #: admitted request depends on.  ``maxsize`` bounds the
        #: *unpinned* population; pins are never silently dropped.
        self._pinned: set = set()
        self._disk_strikes = 0
        #: why the cache went memory-only, or None while the store is
        #: attached (or was never attached)
        self.memory_only_reason: Optional[str] = None
        if persist_dir:
            self.attach_store(persist_dir)

    def __len__(self) -> int:
        return len(self._programs)

    @property
    def store(self):
        """The attached :class:`repro.core.persist.PersistentStore`,
        or ``None`` when the cache is memory-only."""
        return self._store

    def attach_store(self, directory: str):
        """Attach (or switch) the persistent store.  The directory is
        indexed immediately — the warm-load; entries deserialize and
        re-verify lazily, each on the first trace that maps to its
        signature (verification needs the recorded steps).

        Best-effort: an unusable directory (permissions, full disk)
        leaves the cache memory-only — a broken cache dir must never
        take down the context that merely mentioned it."""
        from .persist import PersistentStore
        if self._store is not None and \
                self._store.directory == str(directory):
            return self._store
        try:
            self._store = PersistentStore(directory)
        except OSError as e:
            self.stats.disk_errors += 1
            self._store = None
            self.memory_only_reason = f"attach failed: {e}"
            return None
        self._persisted = set()
        self._poisoned = set()
        self._disk_strikes = 0
        self.memory_only_reason = None
        return self._store

    # -- disk degradation ladder ----------------------------------------
    def _disk_op(self, fn):
        """Run one persistent-store operation with bounded-backoff
        retries.  Returns ``(ok, result)``; after the budget is spent
        the failure is counted (``stats.disk_errors``) and — past
        ``DISK_STRIKE_LIMIT`` consecutive failures — the store is
        detached (memory-only mode).  I/O failures cost the warm
        start, never the execution."""
        delay = self.DISK_BACKOFF
        for attempt in range(self.DISK_RETRIES + 1):
            try:
                out = fn()
            except OSError as e:
                if attempt == self.DISK_RETRIES:
                    self.stats.disk_errors += 1
                    self._disk_strikes += 1
                    if self._disk_strikes >= self.DISK_STRIKE_LIMIT:
                        self._store = None
                        self.memory_only_reason = \
                            f"{self._disk_strikes} consecutive I/O " \
                            f"failures, last: {e}"
                    return False, None
                time.sleep(delay)
                delay *= 2
            else:
                self._disk_strikes = 0
                return True, out
        return False, None     # pragma: no cover - loop always returns

    def clear(self) -> None:
        """Drop the in-memory state (programs, artifacts, certificates,
        counters).  On-disk entries are untouched — a cleared cache
        warm-starts from its store, which is the point of having one."""
        self._programs.clear()
        self._compiled.clear()
        self._certs.clear()
        self._persisted = set()
        self._poisoned = set()
        self._quarantined = {}
        self._pinned = set()
        self._disk_strikes = 0
        self.stats = CacheStats()

    def _write_back(self, key: Hashable, prog: "SuperstepProgram",
                    cert) -> None:
        """Best-effort persist of one certified entry (shared by
        certify-time write-back and eviction write-back): retried with
        bounded backoff on I/O failure, counted in
        ``stats.disk_errors``, degrading to memory-only mode past the
        strike limit — a cache must never take down the program it
        accelerates."""
        if self._store is None:
            return
        from .persist import PersistError
        store = self._store

        def op():
            try:
                return store.save(key, prog, cert)
            except PersistError:
                return None      # encoding refusal: final, not retried
        ok, path = self._disk_op(op)
        if ok and path is not None:
            self._persisted.add(key)
            fname = store.filename(key)
            # a fresh good entry supersedes any poison on its filename
            self._poisoned.discard(fname)

    def _maybe_persist(self, key: Hashable) -> None:
        """Write-back one entry if it is certified and not yet on disk.
        Persistence is strictly best-effort: an I/O or encoding failure
        costs the warm start, never the execution."""
        if self._store is None or key in self._persisted:
            return
        prog = self._programs.get(key)
        cert = self._certs.get(key)
        if prog is None or cert is None or not cert.ok:
            return
        self._write_back(key, prog, cert)

    def compiled(self, key: Hashable,
                 axes: Sequence[str]) -> Optional["CompiledProgram"]:
        """The compiled form of the cached program under ``key`` for an
        axes tuple, if one has been built (compilation is per-axes: the
        jitted body bakes in the collective axis names)."""
        return self._compiled.get(key, {}).get(tuple(axes))

    def set_compiled(self, key: Hashable, axes: Sequence[str],
                     cp: "CompiledProgram") -> None:
        if key not in self._programs:
            raise LPFFatalError(
                "set_compiled for a key with no cached program")
        cert = self._certs.get(key)
        if cert is None:
            raise LPFAnalysisError(
                "set_compiled for an uncertified program: call "
                "ProgramCache.certify(key, steps) first — compiled "
                "artifacts are only cached for verified schedules")
        if not cert.ok:
            raise LPFAnalysisError(
                "set_compiled for a program whose schedule failed "
                f"verification: {cert.summary()}")
        self._compiled.setdefault(key, {})[tuple(axes)] = cp

    def certify(self, key: Hashable, steps: Sequence[ProgramStep],
                prog: Optional[SuperstepProgram] = None,
                scratch: Optional[Slot] = None,
                order: Optional[Sequence[int]] = None):
        """Run the schedule verifier on the cached program under
        ``key`` against its recorded trace and memoize the resulting
        :class:`repro.analysis.VerifierReport`.  ``scratch``/``order``
        must match what :meth:`get_or_build_keyed` optimized with.
        Idempotent per key; :meth:`set_compiled` requires a passing
        certificate."""
        cert = self._certs.get(key)
        if cert is not None:
            return cert
        if prog is None:
            prog = self._programs.get(key)
        if prog is None:
            raise LPFFatalError("certify for a key with no cached program")
        from ..analysis.verifier import verify_program
        cert = verify_program(steps, prog, scratch=scratch, order=order)
        self._certs[key] = cert
        object.__setattr__(prog, "_certificate", cert)
        # write-back on insert: certification is the earliest point an
        # entry is both optimized and proven, so it is the persist point
        self._maybe_persist(key)
        return cert

    def certificate(self, key: Hashable):
        """The memoized certificate for ``key``, or ``None`` if
        :meth:`certify` has not run."""
        return self._certs.get(key)

    def get_or_build(self, steps: Sequence[ProgramStep], p: int,
                     machine: LPFMachine,
                     plan_cache: Optional[PlanCache] = None,
                     scratch: Optional[Slot] = None,
                     order: Optional[Sequence[int]] = None
                     ) -> SuperstepProgram:
        return self.get_or_build_keyed(steps, p, machine, plan_cache,
                                       scratch, order)[0]

    def get_or_build_keyed(self, steps: Sequence[ProgramStep], p: int,
                           machine: LPFMachine,
                           plan_cache: Optional[PlanCache] = None,
                           scratch: Optional[Slot] = None,
                           order: Optional[Sequence[int]] = None
                           ) -> Tuple[SuperstepProgram, Hashable]:
        """Like :meth:`get_or_build` but also returns the cache key, the
        handle :meth:`compiled`/:meth:`set_compiled` attach the jitted
        whole-program artifact to."""
        # the machine's (g, l) keys the cache too: the cost gates price
        # rewrites with them, so contexts over different link classes
        # must not share optimization decisions
        if order is None:
            order = canonical_order(steps)
        key = (program_signature(steps, p, scratch, order),
               machine.g, machine.l)
        prog = self._programs.get(key)
        if prog is not None:
            self.stats.hits += 1
            self._programs.move_to_end(key)
            return prog, key
        prog = self._load_persisted(key, steps, scratch, order)
        if prog is not None:
            return prog, key
        prog = optimize_program(steps, p, machine, plan_cache, scratch,
                                order=order)
        self.stats.misses += 1
        self._insert(key, prog)
        return prog, key

    def _load_persisted(self, key: Hashable,
                        steps: Sequence[ProgramStep],
                        scratch: Optional[Slot],
                        order: Sequence[int]
                        ) -> Optional[SuperstepProgram]:
        """The warm-start path: on an in-memory miss, try the attached
        store.  A loaded program is re-certified via ``verify_program``
        against the ACTUAL recorded trace before it is served — the
        persisted certificate is a record of what some process once
        proved, never a substitute for proving it here.  Any failure
        (integrity, version skew, key mismatch, failed re-verification)
        invalidates the entry and falls through to a cold build.

        Degradation: the poison set short-circuits entries that proved
        invalid but could not be removed (read-only cache dir), so a
        corrupt-but-undeletable file costs ONE decode+verify, not one
        per miss; a transient I/O *error* (as opposed to corruption) is
        retried with backoff and then degrades to a cold miss WITHOUT
        invalidating — the entry on disk may be perfectly fine."""
        if self._store is None:
            return None
        store = self._store
        fname = store.filename(key)
        if fname is not None and fname in self._poisoned:
            self.stats.disk_misses += 1
            return None

        def op():
            status_, entry_ = store.load(key)
            if status_ == "error":
                # surface the transient classification to _disk_op so
                # one ladder owns retries, counting, and detachment
                raise OSError("transient I/O failure reading "
                              f"persisted entry {fname}")
            return status_, entry_
        ok, result = self._disk_op(op)
        if not ok:
            self.stats.disk_misses += 1
            return None
        status, entry = result
        if status == "miss":
            self.stats.disk_misses += 1
            return None
        if status == "invalid":
            self._drop_invalid(key, fname)
            return None
        prog, _stored_cert = entry
        from ..analysis.verifier import verify_program
        try:
            cert = verify_program(steps, prog, scratch=scratch,
                                  order=order)
        except Exception:
            cert = None
        if cert is None or not cert.ok:
            self._drop_invalid(key, fname)
            return None
        self.stats.disk_hits += 1
        self._insert(key, prog)
        self._certs[key] = cert
        object.__setattr__(prog, "_certificate", cert)
        self._persisted.add(key)
        return prog

    def _drop_invalid(self, key: Hashable, fname: Optional[str]) -> None:
        """An entry proved bad (corruption or failed re-verification):
        count it, remove it from disk, and — when removal fails (a
        read-only cache dir) — poison its filename in memory so the
        decode+verify cost is paid once, not per miss."""
        self.stats.invalidated += 1
        if self._store is not None and not self._store.invalidate(key) \
                and fname is not None:
            self._poisoned.add(fname)

    # -- pinned entries (serving hot set) -------------------------------
    def pin(self, key: Hashable) -> None:
        """Exempt ``key`` from LRU eviction.  A pinned entry survives
        any burst of cold one-shot signatures — the serving loop pins
        its per-bucket decode programs because every admitted request
        is priced against them; losing one mid-load would turn a cache
        hit into a schedule search on the latency path.  Pinning a key
        with no cached program is a fatal error (there is nothing to
        protect)."""
        if key not in self._programs:
            raise LPFFatalError("pin for a key with no cached program")
        self._pinned.add(key)

    def unpin(self, key: Hashable) -> None:
        """Return ``key`` to normal LRU eviction (idempotent)."""
        self._pinned.discard(key)

    @property
    def pinned(self) -> frozenset:
        """The keys currently exempt from eviction."""
        return frozenset(self._pinned)

    def keys(self) -> Tuple[Hashable, ...]:
        """The cached program keys, LRU-oldest first."""
        return tuple(self._programs.keys())

    def flush(self) -> int:
        """Best-effort write-back of every certified in-memory entry
        not yet on disk (the graceful-drain hook: a stopping server
        flushes so the next process warm-starts with the hot decode
        set).  Returns the number of entries newly persisted.  No-op
        without an attached store."""
        if self._store is None:
            return 0
        before = len(self._persisted)
        for key in list(self._programs):
            self._maybe_persist(key)
        return len(self._persisted) - before

    # -- compile quarantine ---------------------------------------------
    def quarantine_compile(self, key: Hashable, axes: Sequence[str],
                           err: Optional[BaseException] = None) -> None:
        """Record that whole-program compilation of ``key`` for an axes
        tuple failed: replays fall back to the dispatched
        ``execute_schedule`` path (same certified program, identical
        ledger) instead of re-paying a doomed XLA compile every flush.
        Counted in ``stats.compile_fallbacks``."""
        self._quarantined.setdefault(key, set()).add(tuple(axes))
        self.stats.compile_fallbacks += 1

    def compile_quarantined(self, key: Hashable,
                            axes: Sequence[str]) -> bool:
        """Has compilation of ``key`` for this axes tuple been
        quarantined by a prior failure?"""
        return tuple(axes) in self._quarantined.get(key, ())

    def _insert(self, key: Hashable, prog: SuperstepProgram) -> None:
        self._programs[key] = prog
        # maxsize bounds the UNPINNED population: eviction picks the
        # least-recently-used unpinned entry, so a serving hot set
        # survives thousands of cold one-shot signatures streaming
        # through (the cache may transiently hold maxsize + pinned
        # entries — pins are a promise, not a hint)
        if len(self._programs) - len(self._pinned) <= self.maxsize:
            return
        evicted = next((k for k in self._programs
                        if k not in self._pinned), None)
        if evicted is None:      # pragma: no cover - all-pinned cache
            return
        eprog = self._programs.pop(evicted)
        cert = self._certs.pop(evicted, None)
        self._compiled.pop(evicted, None)
        self._quarantined.pop(evicted, None)
        self.stats.evictions += 1
        # write-back on evict: an entry leaving memory keeps its
        # disk copy (or gains one) so the next process — or the
        # next cold lookup here — warm-starts instead of re-searching
        if evicted not in self._persisted and cert is not None \
                and cert.ok:
            self._write_back(evicted, eprog, cert)


_GLOBAL_PROGRAM_CACHE = ProgramCache()


def global_program_cache() -> ProgramCache:
    """The process-wide program cache (shared across contexts/traces)."""
    return _GLOBAL_PROGRAM_CACHE


# ==========================================================================
# numpy reference interpreter (the differential-test oracle)
# ==========================================================================

_NP_REDUCE = {"sum": np.add, "max": np.maximum, "min": np.minimum}


def simulate_program(step_tables: Sequence[Tuple[Sequence[Msg],
                                                 SyncAttributes]],
                     values: Dict[int, np.ndarray]) -> Dict[int, np.ndarray]:
    """Execute supersteps on host arrays under the p >= 2 LPF semantics.

    ``values`` maps slot sid -> ``[p, slot.size]`` array (one row per
    process).  Each superstep: all reads observe the pre-superstep
    state; writes apply per slot-pair group in first-occurrence order,
    within a group in ascending ``(src, dst, dst_off)`` — exactly the
    arbitration :func:`repro.core.sync.plan_sync` encodes in its round
    structure.  ``reduce_op`` supersteps combine overlapping writes with
    first-write-replaces semantics.  Returns new arrays (inputs are not
    mutated).  Compression is not modelled (it is lossy by design)."""
    values = {sid: np.array(v) for sid, v in values.items()}
    for msgs, attrs in step_tables:
        if attrs.compress is not None:
            raise ValueError("simulate_program cannot model lossy "
                             "compressed supersteps")
        pre = {sid: v.copy() for sid, v in values.items()}
        reduce_fn = _NP_REDUCE[attrs.reduce_op] if attrs.reduce_op else None
        written: Dict[int, np.ndarray] = {}
        groups: "collections.OrderedDict[Tuple[int, int], List[Msg]]" = \
            collections.OrderedDict()
        for m in msgs:
            groups.setdefault((m.src_slot.sid, m.dst_slot.sid),
                              []).append(m)
        for group in groups.values():
            for m in sorted(group, key=lambda m_: (m_.src, m_.dst,
                                                   m_.dst_off)):
                chunk = pre[m.src_slot.sid][m.src,
                                            m.src_off:m.src_off + m.size]
                dst = values[m.dst_slot.sid]
                seg = (m.dst, slice(m.dst_off, m.dst_off + m.size))
                if reduce_fn is None:
                    dst[seg] = chunk
                else:
                    wr = written.setdefault(
                        m.dst_slot.sid,
                        np.zeros(dst.shape, bool))
                    dst[seg] = np.where(wr[seg],
                                        reduce_fn(dst[seg], chunk), chunk)
                    wr[seg] = True
    return values
