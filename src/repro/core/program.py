"""SuperstepProgram — record/replay whole LPF programs.

PR 1 made a single ``lpf_sync`` plan-once/execute-many.  The paper's
immortal-algorithm argument, however, is about whole *programs*: the
FFT's redistribute+reorder pair, PageRank's per-iteration h-relation, a
training step's per-layer gradient syncs.  Re-entering the planner
superstep by superstep ships many small h-relations where the BSP cost
model says fewer, fatter ones are cheaper — every extra superstep pays
another ``l``.  Following pMR's persistent communication objects, this
module lifts the plan/cache/execute architecture one level up:

* **record** — :meth:`repro.core.LPFContext.record` (or the
  ``ctx.program()`` context manager) turns ``ctx.sync`` into a deferred
  operation: each sync snapshots its ``(message table, attrs, label)``
  into a pending trace instead of executing.  Local compute acts as a
  *dataflow-precise* barrier: reading a slot executes exactly the
  pending supersteps in its dependency cone (:func:`dependency_cone` —
  the slot's writers, closed backwards under must-precede conflicts),
  leaving independent supersteps recorded, so interleaved compute keeps
  its sequential semantics without narrowing the batching/overlap
  window.
* **optimize** — :func:`optimize_program` rewrites one flushed trace:

  1. *coalescing* — same-``(src, dst, slot-pair)`` messages contiguous
     in both offsets merge into one fatter message (kept only when the
     plan of the rewritten table is not predicted slower — round
     padding can inflate wire bytes);
  2. *dead-transfer elimination* — a message whose destination range is
     completely overwritten by a later superstep before any read (and
     before the trace ends) is dropped, gated the same way (removing a
     message can demote a fused classification);
  3. *superstep batching* — adjacent compute-independent supersteps
     with equal attributes merge into one sync, cost-gated by the BSP
     model: merge only when ``h_merged*g + l < sum(h_i*g + l)`` (with
     ``h``/rounds taken from the planned schedules);
  4. *split-phase overlap* — adjacent independent supersteps the merge
     gate keeps separate (differing attrs, or a merged plan priced
     higher) are grouped for overlapped issue: all members' reads and
     collectives launch back-to-back, then all writes apply
     (:func:`repro.core.sync.execute_overlapped`).  A k-member group is
     priced ``max_i(h_i)g + max_i(rounds_i)l + (k-1)*l_overlap``
     (:func:`repro.core.cost.overlap_cost`) and admitted only below the
     sequential sum; members must commute, and valiant supersteps never
     overlap (phase-1 scratch writes land in the start half).

* **replay** — optimized traces are cached in a :class:`ProgramCache`
  keyed by the canonical program signature (slot ids renamed by first
  occurrence *across the whole trace*), so repeated invocations —
  a collective called per layer, an FFT called per batch — skip the
  optimizer and the planner entirely and go straight to
  :func:`repro.core.sync.execute_plan` with pre-planned supersteps.

Every optimized superstep carries its :class:`SuperstepPlan`, so the
ledger entry appended at execution is *by construction* the plan's
predicted :class:`SuperstepCost` — optimization never breaks the
compliance audit.

:func:`simulate_program` is a pure-numpy reference interpreter of the
p >= 2 superstep semantics (reads observe pre-superstep state; CRCW
writes arbitrate in ascending ``(src, dst, dst_off)`` order per
slot-pair group, groups in first-occurrence order; ``reduce_op``
supersteps combine with first-write-replaces semantics).  The
differential harness in ``tests/test_program_equivalence.py`` checks
optimized traces against it bit-for-bit.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from .attrs import SyncAttributes
from .cost import overlap_cost
from .errors import LPFFatalError
from .machine import LPFMachine
from .memslot import Slot
from .sync import (CacheStats, Msg, OVERLAPPABLE_METHODS, PlanCache,
                   SuperstepPlan, plan_sync)

__all__ = [
    "ProgramStep", "OptimizedStep", "SuperstepProgram", "ProgramCache",
    "global_program_cache", "program_signature", "optimize_program",
    "simulate_program", "dependency_cone",
]

#: canonical message: (src, dst, src_slot_idx, src_off, dst_slot_idx,
#: dst_off, size, origin) with slot indices assigned by first occurrence
#: across the whole trace
CanonMsg = Tuple[int, int, int, int, int, int, int, str]


@dataclasses.dataclass(frozen=True)
class ProgramStep:
    """One recorded ``sync``: the staged table + its attributes."""

    msgs: Tuple[Msg, ...]
    attrs: SyncAttributes
    label: str


@dataclasses.dataclass(frozen=True)
class OptimizedStep:
    """One superstep of the optimized trace, in canonical (slot-renamed)
    form plus its pre-computed plan.  ``merged_from`` names the recorded
    step indices this superstep executes; ``unchanged`` marks a step no
    rewrite touched, letting replay reuse the staged messages verbatim
    instead of rebuilding them from the canonical table."""

    table: Tuple[CanonMsg, ...]
    attrs: SyncAttributes
    label: str
    plan: SuperstepPlan
    merged_from: Tuple[int, ...]
    unchanged: bool = False


@dataclasses.dataclass(frozen=True)
class SuperstepProgram:
    """An optimized, replayable trace (the program-level IR)."""

    p: int
    steps: Tuple[OptimizedStep, ...]
    n_recorded: int          # supersteps in the raw trace
    n_coalesced: int         # messages removed by coalescing
    n_eliminated: int        # messages removed as dead transfers
    n_merged: int            # supersteps saved by batching
    #: partition of ``range(len(steps))`` into overlap groups, in step
    #: order: a group of k >= 2 adjacent compute-independent supersteps is
    #: issued split-phase (all starts, then all dones) and ledgered as ONE
    #: entry costing ``max_i(h_i)*g + max_i(rounds_i)*l + (k-1)*l_overlap``
    overlap_groups: Tuple[Tuple[int, ...], ...] = ()
    n_overlapped: int = 0    # supersteps hidden under another's wire time

    def groups(self) -> Tuple[Tuple[int, ...], ...]:
        """``overlap_groups``, defaulting to one singleton per step."""
        if self.overlap_groups:
            return self.overlap_groups
        return tuple((i,) for i in range(len(self.steps)))

    def predicted_seconds(self, machine: LPFMachine) -> float:
        """BSP time of the optimized schedule, overlap priced in."""
        total = 0.0
        for grp in self.groups():
            costs = [self.steps[i].plan.cost for i in grp]
            total += (costs[0] if len(costs) == 1
                      else overlap_cost(costs)).predicted_seconds(machine)
        return total

    def materialize(self, slot_map_or_steps,
                    labels: Optional[Sequence[str]] = None
                    ) -> List[Tuple[List[Msg], SyncAttributes, str,
                                    SuperstepPlan]]:
        """Rebind the canonical tables to actual slots.  Pass either the
        replaying trace's raw :class:`ProgramStep` list (untouched steps
        reuse their staged messages verbatim; rewritten ones rebuild
        from the canonical table via the trace's first-occurrence slot
        map) or a pre-computed slot list.  ``labels`` are the replaying
        trace's per-step labels, so a cached program replayed under new
        labels ledgers under those (merged supersteps join theirs with
        ``+``)."""
        raw_steps: Optional[Sequence[ProgramStep]] = None
        slot_map: Optional[List[Slot]] = None
        if slot_map_or_steps and isinstance(slot_map_or_steps[0],
                                            ProgramStep):
            raw_steps = slot_map_or_steps
        else:
            slot_map = list(slot_map_or_steps)
        out = []
        for st in self.steps:
            if raw_steps is not None and st.unchanged:
                msgs = list(raw_steps[st.merged_from[0]].msgs)
            else:
                if slot_map is None:
                    slot_map = trace_slot_map(raw_steps)
                msgs = [Msg(src, dst, slot_map[si], so, slot_map[di], do,
                            sz, origin=origin)
                        for (src, dst, si, so, di, do, sz, origin)
                        in st.table]
            label = st.label if labels is None else \
                "+".join(labels[i] for i in st.merged_from)
            out.append((msgs, st.attrs, label, st.plan))
        return out


# ==========================================================================
# canonicalization + signatures
# ==========================================================================

_DTYPE_STR: Dict[object, str] = {}


def _dtype_str(dtype) -> str:
    s = _DTYPE_STR.get(dtype)
    if s is None:
        s = _DTYPE_STR[dtype] = str(np.dtype(dtype))
    return s


def _slot_canon() -> Tuple[Dict[int, int], List[Tuple[int, str, str]],
                           Callable[[Slot], int]]:
    canon: Dict[int, int] = {}
    descrs: List[Tuple[int, str, str]] = []

    def key(slot: Slot) -> int:
        idx = canon.get(slot.sid)
        if idx is None:
            idx = canon[slot.sid] = len(canon)
            descrs.append((slot.size, _dtype_str(slot.dtype), slot.kind))
        return idx

    return canon, descrs, key


def trace_slot_map(steps: Sequence[ProgramStep]) -> List[Slot]:
    """Actual slots of a raw trace in first-occurrence order — the
    inverse of the canonical renaming."""
    seen: Dict[int, Slot] = {}
    for st in steps:
        for m in st.msgs:
            for slot in (m.src_slot, m.dst_slot):
                if slot.sid not in seen:
                    seen[slot.sid] = slot
    return list(seen.values())


def _attrs_key(attrs: SyncAttributes) -> Hashable:
    return (attrs.method, attrs.no_conflict, attrs.reduce_op,
            attrs.compress, attrs.stale, attrs.valiant_seed)


def program_signature(steps: Sequence[ProgramStep], p: int,
                      scratch: Optional[Slot] = None) -> Hashable:
    """Canonical key of a recorded trace: slot ids renamed by first
    occurrence across *all* supersteps (a slot reused by two supersteps
    must keep the same index — cross-superstep dataflow is part of the
    program), plus per-step attributes and message order."""
    _, descrs, key = _slot_canon()
    step_sigs = []
    for st in steps:
        table = tuple((m.src, m.dst, key(m.src_slot), m.src_off,
                       key(m.dst_slot), m.dst_off, m.size, m.origin)
                      for m in st.msgs)
        step_sigs.append((_attrs_key(st.attrs), table))
    scratch_sig = None if scratch is None else \
        (scratch.size, _dtype_str(scratch.dtype))
    return (p, scratch_sig, tuple(descrs), tuple(step_sigs))


# ==========================================================================
# the optimizer
# ==========================================================================

def _ranges_overlap(a_off: int, a_size: int, b_off: int, b_size: int) -> bool:
    return a_off < b_off + b_size and b_off < a_off + a_size


def _writes_overlap(a: Msg, b: Msg) -> bool:
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and _ranges_overlap(a.dst_off, a.size, b.dst_off, b.size))


def _reads_write(reader: Msg, writer: Msg) -> bool:
    """Does ``reader``'s source range observe ``writer``'s destination?"""
    return (reader.src == writer.dst
            and reader.src_slot.sid == writer.dst_slot.sid
            and _ranges_overlap(reader.src_off, reader.size,
                                writer.dst_off, writer.size))


def _coalesce_step(msgs: List[Msg], attrs: SyncAttributes
                   ) -> Tuple[List[Msg], int]:
    """Merge same-(src, dst, slot-pair, origin) messages contiguous in
    both offsets.  With CRCW semantics a merged write must not conflict
    with any *other* message of the step (merging would move it in the
    arbitration order); accumulating supersteps combine commutatively,
    so contiguity alone suffices."""
    if len(msgs) < 2:
        return msgs, 0
    groups: "collections.OrderedDict[Tuple, List[int]]" = \
        collections.OrderedDict()
    for i, m in enumerate(msgs):
        groups.setdefault((m.src, m.dst, m.src_slot.sid, m.dst_slot.sid,
                           m.origin), []).append(i)
    merged: Dict[int, Msg] = {}      # first-piece index -> merged msg
    dropped: set = set()
    for idxs in groups.values():
        if len(idxs) < 2:
            continue
        run = sorted(idxs, key=lambda i: msgs[i].src_off)
        k = 0
        while k < len(run):
            first = run[k]
            cur = msgs[first]
            pieces = [first]
            while k + 1 < len(run):
                nxt = msgs[run[k + 1]]
                if (cur.src_off + cur.size == nxt.src_off
                        and cur.dst_off + cur.size == nxt.dst_off):
                    cur = dataclasses.replace(cur, size=cur.size + nxt.size)
                    pieces.append(run[k + 1])
                    k += 1
                else:
                    break
            k += 1
            if len(pieces) == 1:
                continue
            if attrs.reduce_op is None:
                others = [m for j, m in enumerate(msgs)
                          if j not in pieces]
                if any(_writes_overlap(cur, o) for o in others):
                    continue   # merging would reorder a CRCW conflict
            merged[min(pieces)] = cur
            dropped.update(p_ for p_ in pieces if p_ != min(pieces))
    if not merged:
        return msgs, 0
    out = [merged.get(i, m) for i, m in enumerate(msgs) if i not in dropped]
    return out, len(dropped)


def _group_order(msgs: Sequence[Msg]) -> List[Tuple[int, int]]:
    """Slot-pair groups in first-occurrence order — the order the direct
    executor applies them in (cross-group CRCW arbitration)."""
    seen: List[Tuple[int, int]] = []
    for m in msgs:
        k = (m.src_slot.sid, m.dst_slot.sid)
        if k not in seen:
            seen.append(k)
    return seen


def _dead_msgs(tables: List[List[Msg]],
               attrs_list: List[SyncAttributes], i: int) -> List[int]:
    """Indices into ``tables[i]`` of messages whose destination range is
    completely overwritten by a single later message before any read
    (message sources are the only reads inside a trace; local compute
    flushes the trace, so a flushed trace has no interior compute reads;
    the trace end is a read of everything)."""
    dead = []
    for k, m in enumerate(tables[i]):
        for j in range(i + 1, len(tables)):
            if any(_reads_write(r, m) for r in tables[j]):
                break               # observed before any full overwrite
            if attrs_list[j].compress is not None:
                continue            # lossy wire: not a clean overwrite
            if any(w.dst == m.dst
                   and w.dst_slot.sid == m.dst_slot.sid
                   and w.dst_off <= m.dst_off
                   and w.dst_off + w.size >= m.dst_off + m.size
                   for w in tables[j]):
                dead.append(k)
                break
    return dead


def _msgs_conflict(ma: Msg, mb: Msg) -> bool:
    """Do two messages from different supersteps fail to commute?
    True when either reads the other's write (RAW/WAR) or their
    destination ranges overlap (WAW — ordering would elect the winner).
    The single source of truth for both the cone flush's must-precede
    relation and the overlap gate's commutation check."""
    return (_reads_write(mb, ma) or _reads_write(ma, mb)
            or _writes_overlap(ma, mb))


def _must_precede(a: ProgramStep, b: ProgramStep) -> bool:
    """Must ``a`` (staged before ``b``) still execute before ``b``?
    True when reordering them is observable: ``b`` reads ``a``'s writes
    (RAW), ``a`` reads ranges ``b`` writes (WAR — executing ``b`` first
    would leak its writes into ``a``'s reads), or their destination
    ranges overlap (WAW — arbitration order would flip)."""
    for ma in a.msgs:
        for mb in b.msgs:
            if _msgs_conflict(ma, mb):
                return True
    return False


def dependency_cone(steps: Sequence[ProgramStep], sid: int,
                    include_reads: bool = False) -> List[int]:
    """The dataflow-precise flush set: indices (sorted, ascending) of the
    pending supersteps a local read of slot ``sid`` depends on — the
    steps that write the slot, closed backwards under
    :func:`_must_precede` so that executing the cone now and the
    remaining steps later is indistinguishable from executing the whole
    trace in order.  With ``include_reads`` (a local *write* of the
    slot) steps that read the slot join the initial set too (they must
    observe the pre-write value)."""
    need: set = set()
    for i, st in enumerate(steps):
        for m in st.msgs:
            if m.dst_slot.sid == sid or (include_reads
                                         and m.src_slot.sid == sid):
                need.add(i)
                break
    # backward closure only: a deferred step *after* a cone step keeps
    # its original relative order when it flushes later, so only earlier
    # steps can be pulled in.  Worklist form: each step enters the
    # frontier once, so every (x, y) pair is tested at most once —
    # O(n^2) _must_precede calls per flush, not a fixpoint re-scan.
    frontier = sorted(need, reverse=True)
    while frontier:
        y = frontier.pop()
        for x in range(y):
            if x not in need and _must_precede(steps[x], steps[y]):
                need.add(x)
                frontier.append(x)
    return sorted(need)


def _independent(earlier: Sequence[Msg], later: Sequence[Msg],
                 reduce_op: Optional[str]) -> bool:
    """May ``later`` run in the same superstep as ``earlier``?  Requires
    that no later message reads an earlier write (merged reads observe
    pre-superstep state) and no destination ranges overlap across the
    two (merged CRCW arbitration could elect a different winner; merged
    accumulation would combine instead of overwrite).  For CRCW steps
    the concatenation must also preserve ``later``'s internal group
    order: a slot-pair group already present in ``earlier`` would hoist
    to its position, reordering ``later``'s own cross-group conflicts."""
    for m2 in later:
        for m1 in earlier:
            if _reads_write(m2, m1) or _writes_overlap(m1, m2):
                return False
    if reduce_op is None:
        later_groups = set(_group_order(later))
        merged_order = [g for g in _group_order(list(earlier) + list(later))
                        if g in later_groups]
        if merged_order != _group_order(later):
            return False
    return True


def _cost_of(plan: SuperstepPlan, machine: LPFMachine) -> float:
    return plan.cost.wire_bytes * machine.g + plan.cost.rounds * machine.l


def _can_overlap(earlier: Sequence[Msg], later: Sequence[Msg]) -> bool:
    """May ``later`` issue split-phase alongside ``earlier``?  The two
    supersteps must *commute*: no read of either may observe a write of
    the other (RAW in both directions — the split-phase lowering runs
    all reads before all writes, but commutation is what the reference
    interpreter validates and what keeps the members order-free), and no
    destination ranges may overlap (WAW — finish order would elect the
    winner).  Note this is weaker than :func:`_independent`: the tables
    are never concatenated, so each member keeps its own attributes,
    plan and internal CRCW arbitration order."""
    for m2 in later:
        for m1 in earlier:
            if _msgs_conflict(m1, m2):
                return False
    return True


def optimize_program(steps: Sequence[ProgramStep], p: int,
                     machine: LPFMachine,
                     plan_cache: Optional[PlanCache] = None,
                     scratch: Optional[Slot] = None) -> SuperstepProgram:
    """Rewrite one recorded trace: coalesce, eliminate dead transfers,
    batch adjacent independent supersteps (cost-gated), and plan every
    surviving superstep.  Pure trace-time Python — no JAX ops."""
    plan = (plan_cache.get_or_plan if plan_cache is not None
            else lambda m, p_, a, s=None: plan_sync(m, p_, a, s))

    def plan_of(msgs: List[Msg], attrs: SyncAttributes) -> SuperstepPlan:
        return plan(msgs, p, attrs, scratch)

    tables = [list(st.msgs) for st in steps]
    attrs_list = [st.attrs for st in steps]
    labels = [st.label for st in steps]
    modified = [False] * len(tables)

    # (1) coalesce within each superstep, gated on the planned cost
    n_coalesced = 0
    for i in range(len(tables)):
        cand, n = _coalesce_step(tables[i], attrs_list[i])
        if n == 0:
            continue
        if _cost_of(plan_of(cand, attrs_list[i]), machine) <= \
                _cost_of(plan_of(tables[i], attrs_list[i]), machine):
            tables[i] = cand
            modified[i] = True
            n_coalesced += n

    # (2) dead-transfer elimination across supersteps, gated per step —
    # removing a message can demote a fused classification (a total
    # exchange minus one message is coloured rounds), so a rewrite only
    # lands when the planned cost does not regress
    n_eliminated = 0
    for i in range(len(tables)):
        dead = _dead_msgs(tables, attrs_list, i)
        if not dead:
            continue
        # removing a group's first message can reorder the cross-group
        # CRCW application order; admit kills one by one, keeping the
        # surviving groups' relative order intact
        kill: List[int] = []
        for k in dead:
            trial = set(kill) | {k}
            cand = [m for idx, m in enumerate(tables[i])
                    if idx not in trial]
            surviving = {(m.src_slot.sid, m.dst_slot.sid) for m in cand}
            old_order = [g for g in _group_order(tables[i])
                         if g in surviving]
            if attrs_list[i].reduce_op is not None or \
                    _group_order(cand) == old_order:
                kill.append(k)
        if not kill:
            continue
        cand = [m for idx, m in enumerate(tables[i])
                if idx not in set(kill)]
        if _cost_of(plan_of(cand, attrs_list[i]), machine) <= \
                _cost_of(plan_of(tables[i], attrs_list[i]), machine):
            tables[i] = cand
            modified[i] = True
            n_eliminated += len(kill)

    # (3) batch adjacent independent supersteps when the model approves
    groups: List[Tuple[List[Msg], SyncAttributes, str, List[int]]] = []
    for i, (msgs, attrs, label) in enumerate(zip(tables, attrs_list,
                                                 labels)):
        if groups:
            cur_msgs, cur_attrs, cur_label, cur_src = groups[-1]
            if (cur_msgs and msgs and attrs == cur_attrs
                    and _independent(cur_msgs, msgs, attrs.reduce_op)):
                cand = cur_msgs + msgs
                try:
                    merged_plan = plan_of(cand, attrs)
                except LPFFatalError:
                    merged_plan = None      # e.g. bruck multigraph limits
                if merged_plan is not None and \
                        _cost_of(merged_plan, machine) < \
                        _cost_of(plan_of(cur_msgs, cur_attrs), machine) + \
                        _cost_of(plan_of(msgs, attrs), machine):
                    groups[-1] = (cand, cur_attrs,
                                  f"{cur_label}+{label}", cur_src + [i])
                    continue
        groups.append((msgs, attrs, label, [i]))
    n_merged = len(tables) - len(groups)

    # (4) overlap: adjacent independent supersteps the merge gate kept
    # separate (differing attrs, or a merged plan the model prices
    # higher) are issued split-phase instead — all starts, then all
    # dones — and priced max(h_i)*g + max(rounds_i)*l + (k-1)*l_overlap.
    # Cost-gated like every rewrite: a group only grows while the
    # overlapped time is predicted below the sequential sum.
    group_plans = [plan_of(msgs, attrs) for msgs, attrs, _, _ in groups]
    ogroups: List[List[int]] = []
    for j, (msgs, attrs, _, _) in enumerate(groups):
        if ogroups and group_plans[j].method in OVERLAPPABLE_METHODS:
            cur = ogroups[-1]
            members_ok = all(
                group_plans[i].method in OVERLAPPABLE_METHODS
                and _can_overlap(groups[i][0], msgs) for i in cur)
            if members_ok:
                seq = sum(group_plans[i].cost.predicted_seconds(machine)
                          for i in cur) \
                    + group_plans[j].cost.predicted_seconds(machine)
                grouped = overlap_cost(
                    [group_plans[i].cost for i in cur]
                    + [group_plans[j].cost]).predicted_seconds(machine)
                if grouped < seq:
                    cur.append(j)
                    continue
        ogroups.append([j])
    n_overlapped = len(groups) - len(ogroups)

    _, _, canon_key = _slot_canon()
    # canonical indices must follow the *raw* trace's first-occurrence
    # order (what trace_slot_map of a replayed trace reproduces), not the
    # optimized tables' — an eliminated first occurrence would skew them
    for st in steps:
        for m in st.msgs:
            canon_key(m.src_slot)
            canon_key(m.dst_slot)

    opt_steps = []
    for (msgs, attrs, label, src_idx), plan in zip(groups, group_plans):
        table = tuple((m.src, m.dst, canon_key(m.src_slot), m.src_off,
                       canon_key(m.dst_slot), m.dst_off, m.size, m.origin)
                      for m in msgs)
        opt_steps.append(OptimizedStep(
            table=table, attrs=attrs, label=label,
            plan=plan, merged_from=tuple(src_idx),
            unchanged=len(src_idx) == 1 and not modified[src_idx[0]]))
    return SuperstepProgram(
        p=p, steps=tuple(opt_steps), n_recorded=len(steps),
        n_coalesced=n_coalesced, n_eliminated=n_eliminated,
        n_merged=n_merged,
        overlap_groups=tuple(tuple(g) for g in ogroups),
        n_overlapped=n_overlapped)


# ==========================================================================
# the program cache
# ==========================================================================

class ProgramCache:
    """LRU memo of :class:`SuperstepProgram` keyed by
    :func:`program_signature` — the program-level twin of
    :class:`repro.core.sync.PlanCache`.  A replayed trace skips the
    optimizer *and* the planner (every optimized step carries its plan).
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._programs: "collections.OrderedDict[Hashable, SuperstepProgram]" \
            = collections.OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._programs)

    def clear(self) -> None:
        self._programs.clear()
        self.stats = CacheStats()

    def get_or_build(self, steps: Sequence[ProgramStep], p: int,
                     machine: LPFMachine,
                     plan_cache: Optional[PlanCache] = None,
                     scratch: Optional[Slot] = None) -> SuperstepProgram:
        # the machine's (g, l) keys the cache too: the cost gates price
        # rewrites with them, so contexts over different link classes
        # must not share optimization decisions
        key = (program_signature(steps, p, scratch), machine.g, machine.l)
        prog = self._programs.get(key)
        if prog is not None:
            self.stats.hits += 1
            self._programs.move_to_end(key)
            return prog
        prog = optimize_program(steps, p, machine, plan_cache, scratch)
        self.stats.misses += 1
        self._programs[key] = prog
        if len(self._programs) > self.maxsize:
            self._programs.popitem(last=False)
            self.stats.evictions += 1
        return prog


_GLOBAL_PROGRAM_CACHE = ProgramCache()


def global_program_cache() -> ProgramCache:
    """The process-wide program cache (shared across contexts/traces)."""
    return _GLOBAL_PROGRAM_CACHE


# ==========================================================================
# numpy reference interpreter (the differential-test oracle)
# ==========================================================================

_NP_REDUCE = {"sum": np.add, "max": np.maximum, "min": np.minimum}


def simulate_program(step_tables: Sequence[Tuple[Sequence[Msg],
                                                 SyncAttributes]],
                     values: Dict[int, np.ndarray]) -> Dict[int, np.ndarray]:
    """Execute supersteps on host arrays under the p >= 2 LPF semantics.

    ``values`` maps slot sid -> ``[p, slot.size]`` array (one row per
    process).  Each superstep: all reads observe the pre-superstep
    state; writes apply per slot-pair group in first-occurrence order,
    within a group in ascending ``(src, dst, dst_off)`` — exactly the
    arbitration :func:`repro.core.sync.plan_sync` encodes in its round
    structure.  ``reduce_op`` supersteps combine overlapping writes with
    first-write-replaces semantics.  Returns new arrays (inputs are not
    mutated).  Compression is not modelled (it is lossy by design)."""
    values = {sid: np.array(v) for sid, v in values.items()}
    for msgs, attrs in step_tables:
        if attrs.compress is not None:
            raise ValueError("simulate_program cannot model lossy "
                             "compressed supersteps")
        pre = {sid: v.copy() for sid, v in values.items()}
        reduce_fn = _NP_REDUCE[attrs.reduce_op] if attrs.reduce_op else None
        written: Dict[int, np.ndarray] = {}
        groups: "collections.OrderedDict[Tuple[int, int], List[Msg]]" = \
            collections.OrderedDict()
        for m in msgs:
            groups.setdefault((m.src_slot.sid, m.dst_slot.sid),
                              []).append(m)
        for group in groups.values():
            for m in sorted(group, key=lambda m_: (m_.src, m_.dst,
                                                   m_.dst_off)):
                chunk = pre[m.src_slot.sid][m.src,
                                            m.src_off:m.src_off + m.size]
                dst = values[m.dst_slot.sid]
                seg = (m.dst, slice(m.dst_off, m.dst_off + m.size))
                if reduce_fn is None:
                    dst[seg] = chunk
                else:
                    wr = written.setdefault(
                        m.dst_slot.sid,
                        np.zeros(dst.shape, bool))
                    dst[seg] = np.where(wr[seg],
                                        reduce_fn(dst[seg], chunk), chunk)
                    wr[seg] = True
    return values
