"""JAX version-compatibility shims.

The library targets every JAX from 0.4.35 (the oldest with
``jax.make_mesh``) through 0.5+/0.6+.  A handful of symbols moved or
changed signature across that range; every use of them in this repo MUST
go through this module so there is exactly one place that knows the
version story:

* ``shard_map`` — top-level ``jax.shard_map`` exists only on 0.6+; on
  0.4.x it lives in ``jax.experimental.shard_map`` and spells the
  replication check ``check_rep`` (new: ``check_vma``) and the partial
  manualness set ``auto`` (new: ``axis_names``, the complement).
* ``make_mesh`` — the ``axis_types`` kwarg (and ``jax.sharding.AxisType``
  itself) only exists on 0.5+; older meshes are implicitly "auto".
* ``tree_map`` & friends — ``jax.tree`` appeared in 0.4.25, before the
  oldest release this repo supports, so these aliases exist only as a
  convenience / insurance for even older jaxes; unlike ``shard_map``
  and the mesh helpers above, calling ``jax.tree.*`` directly elsewhere
  in the tree is fine.

Nothing here imports anything heavier than ``jax`` itself, and all the
probes are feature checks (``hasattr``), never version-string parses.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Set

import jax

__all__ = [
    "HAS_AXIS_TYPE", "axis_types_auto", "make_mesh", "set_mesh",
    "shard_map", "scan", "while_loop", "tree_map", "tree_flatten",
    "tree_unflatten", "tree_leaves", "tree_structure",
]

# -- axis types ------------------------------------------------------------

#: True when this JAX has ``jax.sharding.AxisType`` (0.5+).
HAS_AXIS_TYPE: bool = hasattr(jax.sharding, "AxisType")


def axis_types_auto(n: int):
    """``(AxisType.Auto,) * n`` on JAX 0.5+, else ``None`` (old meshes are
    implicitly auto; ``Mesh``/``make_mesh`` take no such argument)."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


# -- mesh construction -----------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with every axis explicitly ``Auto`` where the
    concept exists, and plain construction where it does not."""
    kwargs = {"devices": devices} if devices is not None else {}
    types = axis_types_auto(len(tuple(axis_names)))
    if types is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=types, **kwargs)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on 0.6+;
    older ``Mesh`` objects are themselves context managers."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# -- shard_map -------------------------------------------------------------

def shard_map(f: Callable, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = False,
              axis_names: Optional[Set[str]] = None) -> Callable:
    """Uniform ``shard_map`` over the old and new APIs.

    ``axis_names`` follows the NEW convention: the set of mesh axes the
    region is manual over (``None`` = all of them).  On 0.4.x this is
    translated to the old ``auto=`` complement set, and ``check_vma``
    becomes ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        # Partial-manual lowering is unreliable on 0.4.x XLA (PartitionId
        # is UNIMPLEMENTED under SPMD partitioning; sharding propagation
        # CHECK-fails on IsManualSubgroup).  When no in/out spec touches
        # an auto axis the region is semantically identical to a fully
        # manual one — every device along the auto axes holds replicated
        # data and runs the same program — so fall back to full manual.
        if auto and not _specs_touch_axes((in_specs, out_specs), auto):
            auto = frozenset()
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, **kwargs)


def _specs_touch_axes(specs, axes: frozenset) -> bool:
    """True if any PartitionSpec leaf in ``specs`` names one of ``axes``."""
    P = jax.sharding.PartitionSpec
    hit = False
    for leaf in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        if not isinstance(leaf, P):
            continue
        for entry in leaf:
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n in axes for n in names if n is not None):
                hit = True
    return hit


# -- structured control flow -----------------------------------------------
#
# ``lax.scan``/``lax.while_loop`` are stable across the supported range,
# but they are the symbols whole-program compilation (compiled
# SuperstepProgram replay, ``LPFContext.compile_loop``, the fused decode
# loop) hangs off — routed through here like every other symbol the
# version story could ever touch, so a future signature change has one
# place to land.

def scan(f, init, xs, length=None):
    """``lax.scan`` (body traced once; per-iteration work compiles into
    ONE XLA ``While`` op instead of a Python-dispatched call per step)."""
    import jax.lax
    return jax.lax.scan(f, init, xs, length=length)


def while_loop(cond_fun, body_fun, init_val):
    """``lax.while_loop`` — same single-trace contract as :func:`scan`."""
    import jax.lax
    return jax.lax.while_loop(cond_fun, body_fun, init_val)


# -- pytree helpers --------------------------------------------------------

if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
    tree_leaves = jax.tree.leaves
    tree_structure = jax.tree.structure
else:  # pragma: no cover - ancient JAX
    tree_map = jax.tree_util.tree_map
    tree_flatten = jax.tree_util.tree_flatten
    tree_unflatten = jax.tree_util.tree_unflatten
    tree_leaves = jax.tree_util.tree_leaves
    tree_structure = jax.tree_util.tree_structure
