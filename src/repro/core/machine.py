"""Machine models and ``lpf_probe`` — the paper's (p, g, l) introspection.

The paper requires ``lpf_probe`` so immortal algorithms can parametrise
themselves in (p, g, l).  Here ``probe`` returns an :class:`LPFMachine` per
mesh-axis group, derived from a hardware table (offline benchmark, paper
S4.1) — a Theta(1) table lookup, as the paper allows.  ``probe_online``
measures (g, l) on the current backend by timing total exchanges (paper
Table 3 methodology) and is used by ``benchmarks/hrelation.py``.

All bandwidths are bytes/second, latencies seconds, compute flop/second.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

__all__ = [
    "LinkModel",
    "HardwareModel",
    "LPFMachine",
    "TPU_V5E",
    "TPU_V5P",
    "CPU_HOST",
    "probe",
    "axis_kind_default",
]


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One interconnect class (ICI axis, DCN pod link, ...)."""

    bw: float        # per-chip injection bandwidth over this link class (B/s)
    latency: float   # per-superstep launch/sync latency (seconds)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Static description of one chip + its interconnects."""

    name: str
    peak_flops_bf16: float
    peak_flops_fp32: float
    hbm_bw: float                      # bytes/s
    hbm_bytes: float                   # capacity per chip
    vmem_bytes: float                  # on-chip vector memory
    links: Mapping[str, LinkModel]     # kind -> link model ("ici", "dcn", "host")

    def link(self, kind: str) -> LinkModel:
        if kind not in self.links:
            raise KeyError(f"{self.name} has no link class {kind!r}")
        return self.links[kind]


#: TPU v5e — the target platform for the production mesh (spec constants:
#: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).  DCN per-chip
#: bandwidth and latencies are engineering assumptions, recorded here so the
#: cost model is explicit about them.
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_fp32=98.5e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    vmem_bytes=128 * 2**20,
    links={
        "ici": LinkModel(bw=50e9, latency=1e-6),
        "dcn": LinkModel(bw=12.5e9, latency=50e-6),
    },
)

TPU_V5P = HardwareModel(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    peak_flops_fp32=229.5e12,
    hbm_bw=2765e9,
    hbm_bytes=95e9,
    vmem_bytes=128 * 2**20,
    links={
        "ici": LinkModel(bw=100e9, latency=1e-6),
        "dcn": LinkModel(bw=25e9, latency=50e-6),
    },
)

#: The CPU container this repo is *validated* on (not the deployment target).
CPU_HOST = HardwareModel(
    name="cpu_host",
    peak_flops_bf16=5e10,
    peak_flops_fp32=5e10,
    hbm_bw=2e10,
    hbm_bytes=32e9,
    vmem_bytes=32 * 2**20,
    links={
        "ici": LinkModel(bw=5e9, latency=5e-6),
        "dcn": LinkModel(bw=1e9, latency=1e-4),
    },
)


@dataclasses.dataclass(frozen=True)
class LPFMachine:
    """What ``lpf_probe`` returns: the BSP machine (p, g, l) + compute rate.

    ``g`` is seconds per *byte* of h-relation; ``l`` is seconds per
    superstep.  ``r`` is seconds per flop so that (g, l) can be normalised
    as in paper Table 3 (g x r-relative, l in word-times).
    """

    p: int
    g: float
    l: float
    r: float
    hardware: HardwareModel = TPU_V5E

    def t_comm(self, h_bytes: float, supersteps: int = 1) -> float:
        """BSP cost of communicating an h-relation: h*g + l per superstep."""
        return h_bytes * self.g + supersteps * self.l

    def normalised(self, word_bytes: int = 8) -> tuple[float, float]:
        """(g, l) in the paper's Table-3 units: g relative to memcpy speed r
        for one word, l in units of words."""
        g_norm = (self.g * word_bytes) / (self.r * word_bytes)
        l_norm = self.l / (self.g * word_bytes)
        return g_norm, l_norm


def axis_kind_default(axis_name: str) -> str:
    """Map a mesh axis name to an interconnect class."""
    return "dcn" if axis_name in ("pod", "dcn", "slice") else "ici"


def probe(
    axis_sizes: Mapping[str, int],
    hardware: HardwareModel = TPU_V5E,
    axis_kinds: Mapping[str, str] | None = None,
) -> LPFMachine:
    """``lpf_probe``: the BSP machine for a context spanning ``axis_sizes``.

    For a context over several axes the effective ``g`` is dominated by the
    slowest link class involved and the latency is the sum of the per-axis
    latencies (hierarchical supersteps execute per level).  Total-exchange
    bandwidth over a torus axis of size ``p`` scales the per-chip injection
    bandwidth by ``p/(p-1)`` locality loss, which we fold in as the paper's
    measured-g does.
    """
    if not axis_sizes:
        # Sequential LPF_ROOT context: communication is memcpy.
        return LPFMachine(p=1, g=1.0 / hardware.hbm_bw, l=0.0,
                          r=1.0 / hardware.peak_flops_fp32, hardware=hardware)
    axis_kinds = axis_kinds or {}
    p = 1
    worst_g = 0.0
    total_l = 0.0
    for name, size in axis_sizes.items():
        p *= int(size)
        if int(size) == 1:
            continue
        link = hardware.link(axis_kinds.get(name, axis_kind_default(name)))
        frac = (size - 1) / size  # fraction of traffic leaving the chip
        worst_g = max(worst_g, frac / link.bw)
        total_l += link.latency * max(1.0, math.log2(size))
    if worst_g == 0.0:
        worst_g = 1.0 / hardware.hbm_bw
    return LPFMachine(
        p=p,
        g=worst_g,
        l=total_l,
        r=1.0 / hardware.peak_flops_fp32,
        hardware=hardware,
    )
