"""Memory slots and registration — ``lpf_register_{local,global}``,
``lpf_deregister``, ``lpf_resize_memory_register``.

A slot names a per-process 1-D array (LPF registers raw memory areas; we
register arrays of a fixed dtype, with offsets/sizes counted in elements).
Multi-dimensional tensors are registered through ``flatten=True`` views.

The capacity contract is the paper's: the number of simultaneously
registered slots must not exceed the reserved register size, and staging
beyond queue capacity raises a *mitigable* error before any side effect.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Dict, List

import jax.numpy as jnp

from .errors import LPFCapacityError, LPFFatalError

__all__ = ["Slot", "SlotRegistry"]

# Registration epochs are process-global so a handle minted by any
# registry can never collide with a later registration that reuses its
# slot id — the stale handle is detectable by generation alone.
_GENERATION = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class Slot:
    """Handle to a registered memory area (``lpf_memslot_t``)."""

    sid: int
    name: str
    size: int            # elements
    dtype: Any
    kind: str            # "global" | "local"
    orig_shape: tuple    # for flatten-registered tensors
    gen: int = 0         # registration epoch; 0 = synthetic (tests, compile)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Slot<{self.name}#{self.sid} {self.kind} "
                f"{self.size}x{jnp.dtype(self.dtype).name}>")


class SlotRegistry:
    """Tracks registered slots + their current (traced) values."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity
        self._slots: Dict[int, Slot] = {}
        self._values: Dict[int, jnp.ndarray] = {}
        self._next_sid = 0
        self._free_sids: List[int] = []   # min-heap of deregistered ids

    # -- lpf_resize_memory_register -------------------------------------
    def resize(self, capacity: int) -> None:
        if capacity < len(self._slots):
            raise LPFCapacityError(
                f"cannot shrink register below {len(self._slots)} active slots",
                required=len(self._slots), capacity=capacity,
                kind="register")
        self.capacity = capacity

    # -- lpf_register_{local,global} -------------------------------------
    def register(self, name: str, value, kind: str, flatten: bool = True) -> Slot:
        if len(self._slots) >= self.capacity:
            raise LPFCapacityError(
                f"memory register full ({self.capacity}); call "
                f"resize_memory_register first",
                required=len(self._slots) + 1, capacity=self.capacity,
                kind="register")
        value = jnp.asarray(value)
        orig_shape = value.shape
        if flatten:
            value = value.reshape(-1)
        elif value.ndim != 1:
            raise LPFFatalError("slots are 1-D; pass flatten=True for tensors")
        if self._free_sids:
            sid = heapq.heappop(self._free_sids)
        else:
            sid = self._next_sid
            self._next_sid += 1
        slot = Slot(sid, name, int(value.shape[0]), value.dtype,
                    kind, tuple(orig_shape), next(_GENERATION))
        self._slots[slot.sid] = slot
        self._values[slot.sid] = value
        return slot

    # -- lpf_deregister ---------------------------------------------------
    def deregister(self, slot: Slot) -> None:
        self._check(slot)
        del self._slots[slot.sid]
        del self._values[slot.sid]
        heapq.heappush(self._free_sids, slot.sid)

    # -- value plumbing ----------------------------------------------------
    def _check(self, slot: Slot) -> None:
        if slot.sid not in self._slots:
            raise LPFFatalError(f"slot {slot} is not registered")
        live = self._slots[slot.sid]
        if live is not slot and live.gen != slot.gen:
            raise LPFFatalError(
                f"stale handle {slot}: slot id {slot.sid} was deregistered "
                f"and re-registered as {live}")

    def is_registered(self, slot: Slot) -> bool:
        """True iff *this exact handle* (id + generation) is live."""
        live = self._slots.get(slot.sid)
        return live is not None and (live is slot or live.gen == slot.gen)

    def value(self, slot: Slot) -> jnp.ndarray:
        self._check(slot)
        return self._values[slot.sid]

    def tensor(self, slot: Slot) -> jnp.ndarray:
        """Current value reshaped to the originally registered shape."""
        return self.value(slot).reshape(slot.orig_shape)

    def set_value(self, slot: Slot, value: jnp.ndarray) -> None:
        self._check(slot)
        if value.shape != (slot.size,) or value.dtype != slot.dtype:
            raise LPFFatalError(
                f"local write to {slot} with mismatched shape/dtype "
                f"{value.shape}/{value.dtype}")
        self._values[slot.sid] = value

    @property
    def n_active(self) -> int:
        return len(self._slots)
