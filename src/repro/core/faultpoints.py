"""Fault-injection seams — the core-side shim for
:mod:`repro.runtime.faults`.

Production code marks its failure seams by calling into this module;
:class:`repro.runtime.faults.FaultInjector` arms itself by installing
into :data:`_INJECTOR`.  The split keeps the dependency direction clean
(``repro.core`` never imports ``repro.runtime``) and keeps the unarmed
path free: every seam entry point is a single ``is None`` check, so
with no plan armed the executed bytecode is byte-identical to a build
without fault injection.

Seams (see ``runtime/faults.py`` for the plan grammar):

``persist_save``
    :meth:`repro.core.persist.PersistentStore.save` — injected
    ``OSError`` (full disk, read-only directory).
``persist_load``
    :meth:`repro.core.persist.PersistentStore._read` — injected
    ``OSError`` or a truncated / bit-flipped blob.
``compile``
    :func:`repro.core.program.compile_program` — injected XLA
    compilation failure (:class:`InjectedFault`).
``straggler``
    :meth:`repro.core.context.LPFContext._execute_steps` — wall-clock
    delay before the schedule issues (straggler simulation).
``capacity``
    :meth:`repro.core.context.LPFContext._stage` — injected capacity
    exhaustion (mitigable ``LPFCapacityError``), exercising the
    paper's resize-and-retry contract.
``serve_admit``
    :meth:`repro.runtime.server.LPFServer.submit` — injected
    infrastructure failure during request admission; the server must
    reject the request with a classified reason, never die.
``serve_decode``
    :meth:`repro.runtime.server.LPFServer.step` — injected decode
    failure before a batch issues; the server retries on the
    per-token fallback path (bucket quarantined) and, if that also
    fails, fails the batch's requests with a classified reason.
"""

from __future__ import annotations

from typing import Optional

__all__ = ["InjectedFault", "SEAMS", "armed", "fire", "corrupt", "delay"]

#: the closed set of seam names a plan may target
SEAMS = ("persist_save", "persist_load", "compile", "straggler",
         "capacity", "serve_admit", "serve_decode")


class InjectedFault(RuntimeError):
    """An infrastructure failure injected by an armed fault plan.

    Deliberately NOT an :class:`repro.core.errors.LPFError`: it stands
    in for the exception an external layer (XLA, the OS) would raise,
    so the degradation ladder's classification of foreign errors is
    exercised for real.  :func:`repro.core.errors.classify` files it as
    ``"transient"``."""


#: the armed injector (a ``repro.runtime.faults.FaultInjector``), or
#: ``None`` — the zero-fault fast path
_INJECTOR = None


def armed() -> bool:
    return _INJECTOR is not None


def fire(seam: str, **info) -> None:
    """Raise the armed plan's exception for ``seam``, if any is due.
    No-op (one pointer compare) when no plan is armed."""
    if _INJECTOR is not None:
        _INJECTOR.fire(seam, **info)


def corrupt(seam: str, blob: bytes) -> bytes:
    """Pass ``blob`` through the armed plan's corruption for ``seam``
    (truncation / bit-flip), or raise its injected I/O error.  Returns
    ``blob`` unchanged when no plan is armed."""
    if _INJECTOR is None:
        return blob
    return _INJECTOR.corrupt(seam, blob)


def delay(seam: str, **info) -> float:
    """Seconds of injected delay due at ``seam`` (0.0 when unarmed or
    not due).  The *caller* sleeps, so the seam stays trivially cheap
    on the zero-fault path."""
    if _INJECTOR is None:
        return 0.0
    return _INJECTOR.delay(seam, **info)


def _install(injector) -> Optional[object]:
    """Arm/disarm (``injector=None``) the process-wide injector;
    returns the previously armed one.  Called only by
    :mod:`repro.runtime.faults`."""
    global _INJECTOR
    prev, _INJECTOR = _INJECTOR, injector
    return prev
