"""LPF core — the paper's twelve primitives on JAX/XLA.

==========================  ==============================================
Paper primitive             This module
==========================  ==============================================
``lpf_exec``                :func:`repro.core.exec_`
``lpf_hook``                :func:`repro.core.hook`
``lpf_rehook``              :func:`repro.core.rehook`
``lpf_register_local``      :meth:`LPFContext.register_local`
``lpf_register_global``     :meth:`LPFContext.register_global`
``lpf_deregister``          :meth:`LPFContext.deregister`
``lpf_resize_memory_...``   :meth:`LPFContext.resize_memory_register`
``lpf_resize_message_...``  :meth:`LPFContext.resize_message_queue`
``lpf_put``                 :meth:`LPFContext.put`
``lpf_get``                 :meth:`LPFContext.get`
``lpf_sync``                :meth:`LPFContext.sync`
``lpf_probe``               :meth:`LPFContext.probe` / :func:`probe`
==========================  ==============================================
"""

from .attrs import CompressSpec, LPF_SYNC_DEFAULT, SyncAttributes
from .context import LPFContext, exec_, hook, rehook
from .cost import (CostLedger, FUSED_METHODS, OVERLAP_L_FRACTION,
                   SuperstepCost, overlap_cost, schedule_seconds)
from .errors import (LPF_ERR_FATAL, LPF_ERR_OUT_OF_MEMORY,
                     LPF_ERR_TRANSIENT, LPF_SUCCESS, LPFAnalysisError,
                     LPFCapacityError, LPFError, LPFFatalError,
                     LPFTransientError, classify)
from .faultpoints import InjectedFault
from .hlo_analysis import (CollectiveStats, RooflineTerms, parse_collectives,
                           roofline_terms)
from .machine import (CPU_HOST, TPU_V5E, TPU_V5P, HardwareModel, LinkModel,
                      LPFMachine, probe)
from .memslot import Slot, SlotRegistry
from .persist import PersistentStore, PersistError, steps_from_signature
from .program import (CompiledProgram, OptimizedStep, ProgramCache,
                      ProgramStep, SuperstepProgram, canonical_order,
                      compile_program, dependency_cone,
                      global_program_cache, optimize_program,
                      program_signature, simulate_program, trace_slot_map)
from .sync import (CacheStats, Msg, OVERLAPPABLE_METHODS, PlanCache,
                   RoundPlan, SuperstepPlan, ValueStore, begin_plan,
                   conflict_free, execute_overlapped, execute_plan,
                   find_conflict,
                   execute_schedule, global_plan_cache, plan_cost,
                   plan_sync, plan_signature)
from . import compat

__all__ = [
    "LPFContext", "exec_", "hook", "rehook",
    "SyncAttributes", "CompressSpec", "LPF_SYNC_DEFAULT",
    "CostLedger", "SuperstepCost", "FUSED_METHODS",
    "OVERLAP_L_FRACTION", "overlap_cost", "OVERLAPPABLE_METHODS",
    "schedule_seconds", "conflict_free", "find_conflict",
    "canonical_order",
    "begin_plan", "execute_overlapped", "dependency_cone",
    "LPFError", "LPFCapacityError", "LPFFatalError", "LPFAnalysisError",
    "LPFTransientError", "classify", "InjectedFault",
    "LPF_SUCCESS", "LPF_ERR_OUT_OF_MEMORY", "LPF_ERR_FATAL",
    "LPF_ERR_TRANSIENT",
    "HardwareModel", "LinkModel", "LPFMachine", "probe",
    "TPU_V5E", "TPU_V5P", "CPU_HOST",
    "Slot", "SlotRegistry", "Msg",
    "PlanCache", "CacheStats", "RoundPlan", "SuperstepPlan",
    "plan_sync", "plan_signature", "plan_cost", "execute_plan",
    "global_plan_cache", "compat",
    "ProgramStep", "OptimizedStep", "SuperstepProgram", "ProgramCache",
    "CompiledProgram", "compile_program", "trace_slot_map",
    "program_signature", "optimize_program", "global_program_cache",
    "simulate_program", "ValueStore", "execute_schedule",
    "PersistentStore", "PersistError", "steps_from_signature",
    "CollectiveStats", "RooflineTerms", "parse_collectives", "roofline_terms",
]
