"""Persistent, shareable program cache — pMR's persistent communication
objects taken literally.

Program signatures are canonical and process-independent (slots renamed
by first occurrence across the canonically ordered trace), so an
optimized :class:`repro.core.program.SuperstepProgram` — the searched
schedule, every superstep's :class:`repro.core.sync.SuperstepPlan`, and
the schedule verifier's certificate — is valid in *any* process that
records the same program.  This module serialises those cache entries
next to the XLA compilation cache so the "proven optimal once, valid
forever" wins survive restarts: a restarted or autoscaled worker pays
zero re-planning and zero schedule-search cost.

On-disk format (one file per entry, ``prog_<keyhash>.lpfc``)::

    {"magic": ..., "format": 1, "jax": ..., "payload_bytes": N,
     "payload_sha256": ...}\\n
    <N bytes of JSON payload: {"key", "program", "certificate"}>

The payload is a *structured* encoding (tagged tuples + a closed
registry of the IR dataclasses), not a pickle: nothing executable is
ever loaded from the cache directory.  Writes are atomic (temp file +
``os.replace``, the same discipline as ``checkpoint/store.py``), so a
crash mid-write never corrupts an entry.

Trust model — a loaded entry is *advisory*, never authoritative:

* the header is validated before the payload is parsed — a format or
  jax version skew degrades to a cold miss (``invalidated`` counter);
* the payload checksum catches truncation and bit-flips;
* the stored key must equal the requested key (hash-collision /
  renamed-file defence);
* and above all, :class:`repro.core.program.ProgramCache` re-runs
  ``verify_program`` on every loaded entry against the *actual*
  recorded trace before the program may execute or compile — a stale or
  adversarial entry can cost a re-optimization, never a wrong schedule.

:func:`steps_from_signature` reconstructs a synthetic recorded trace
from a persisted canonical signature, which is what lets
``python -m repro.analysis --cache-dir`` re-verify a cache offline,
with no recording process around.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from . import faultpoints as _fp
from .attrs import CompressSpec, SyncAttributes
from .cost import SuperstepCost
from .memslot import Slot
from .sync import Msg, RoundPlan, SuperstepPlan

__all__ = ["FORMAT_VERSION", "PersistError", "PersistentStore",
           "entry_filename", "steps_from_signature"]

#: bump on any change to the payload encoding or to the meaning of the
#: persisted IR; old entries then degrade to cold misses
FORMAT_VERSION = 1

MAGIC = "lpf-program-cache"

_SUFFIX = ".lpfc"


class PersistError(Exception):
    """An entry failed to encode/decode — callers degrade to a cold
    miss, they never propagate this to the execution path."""


def _jax_version() -> str:
    import jax
    return jax.__version__


# ==========================================================================
# the structured codec: tagged tuples + a closed dataclass registry
# ==========================================================================

def _codec_types():
    # program.py imports this module's consumers; resolve lazily to keep
    # the import graph acyclic
    from .program import OptimizedStep, SuperstepProgram
    from ..analysis.verifier import VerifierReport
    return {cls.__name__: cls for cls in (
        SyncAttributes, CompressSpec, SuperstepCost, SuperstepPlan,
        RoundPlan, OptimizedStep, SuperstepProgram, VerifierReport)}


def _encode(obj: Any) -> Any:
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (tuple, list)):
        return {"__t__": [_encode(x) for x in obj]}
    if dataclasses.is_dataclass(obj) and \
            type(obj).__name__ in _codec_types():
        fields = {}
        for f in dataclasses.fields(obj):
            if f.name == "diagnostics":
                # a persisted certificate is always a passing one (the
                # store refuses failed certs); Diagnostic carries live
                # Msg/Slot handles and has no business on disk
                fields[f.name] = {"__t__": []}
            else:
                fields[f.name] = _encode(getattr(obj, f.name))
        return {"__dc__": type(obj).__name__, "fields": fields}
    raise PersistError(f"cannot persist {type(obj).__name__}")


def _decode(doc: Any) -> Any:
    if doc is None or isinstance(doc, (bool, int, float, str)):
        return doc
    if isinstance(doc, dict) and "__t__" in doc and len(doc) == 1:
        return tuple(_decode(x) for x in doc["__t__"])
    if isinstance(doc, dict) and doc.keys() == {"__dc__", "fields"}:
        cls = _codec_types().get(doc["__dc__"])
        if cls is None:
            raise PersistError(f"unknown persisted type {doc['__dc__']!r}")
        kwargs = {f.name: _decode(doc["fields"][f.name])
                  for f in dataclasses.fields(cls)
                  if f.name in doc["fields"]}
        return cls(**kwargs)
    raise PersistError(f"malformed payload node {type(doc).__name__}")


def _key_text(obj: Any) -> str:
    """Deterministic textual form of a cache key (the canonical program
    signature plus the machine's (g, l)) — what the entry filename
    hashes.  Keys are nested tuples of primitives; the one structured
    leaf, :class:`CompressSpec`, is normalised explicitly."""
    if isinstance(obj, tuple):
        return "(" + ",".join(_key_text(x) for x in obj) + ")"
    if isinstance(obj, CompressSpec):
        return f"CompressSpec({obj.bits},{obj.stochastic})"
    if obj is None or isinstance(obj, (bool, int, str)):
        return repr(obj)
    if isinstance(obj, float):
        return repr(obj)
    raise PersistError(f"unsupported key element {type(obj).__name__}")


def entry_filename(key: Hashable) -> str:
    """Stable entry filename for a cache key: ``prog_<sha256/40>.lpfc``."""
    digest = hashlib.sha256(_key_text(key).encode()).hexdigest()[:40]
    return f"prog_{digest}{_SUFFIX}"


# ==========================================================================
# signature -> synthetic recorded trace (offline re-verification)
# ==========================================================================

def steps_from_signature(sig: Hashable):
    """Reconstruct ``(p, steps, scratch)`` from a canonical
    :func:`repro.core.program.program_signature`.

    The signature *is* the recorded program in canonical form — p, the
    scratch descriptor, every slot's (size, dtype, kind), and each
    step's attributes + message table over canonical slot indices — so a
    synthetic trace built from it is signature-identical to the original
    recording.  That is what lets the analysis CLI re-run the schedule
    verifier over a persisted cache with no recording process around."""
    from .program import ProgramStep
    p, scratch_sig, descrs, step_sigs = sig
    slots = [Slot(sid=i, name=f"c{i}", size=size, dtype=np.dtype(dt),
                  kind=kind, orig_shape=(size,))
             for i, (size, dt, kind) in enumerate(descrs)]
    scratch = None
    if scratch_sig is not None:
        size, dt = scratch_sig
        scratch = Slot(sid=len(slots), name="__scratch", size=size,
                       dtype=np.dtype(dt), kind="global",
                       orig_shape=(size,))
    steps = []
    for i, (akey, table) in enumerate(step_sigs):
        method, no_conflict, reduce_op, compress, stale, seed = akey
        attrs = SyncAttributes(method=method, no_conflict=no_conflict,
                               reduce_op=reduce_op, compress=compress,
                               stale=stale, valiant_seed=seed)
        msgs = tuple(Msg(src, dst, slots[si], soff, slots[di], doff, sz,
                         origin=origin)
                     for (src, dst, si, soff, di, doff, sz, origin)
                     in table)
        steps.append(ProgramStep(msgs, attrs, f"step[{i}]"))
    return int(p), steps, scratch


# ==========================================================================
# the store
# ==========================================================================

class PersistentStore:
    """One directory of ``prog_*.lpfc`` entries with atomic writes and
    classified loads (``hit`` / ``miss`` / ``invalid``)."""

    def __init__(self, directory: str):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)

    def _path(self, key: Hashable) -> str:
        return os.path.join(self.directory, entry_filename(key))

    def __len__(self) -> int:
        try:
            return len(self.filenames())
        except OSError:
            return 0

    def filenames(self) -> List[str]:
        """Sorted entry filenames currently on disk (the warm-load
        index: entries deserialize + re-verify lazily, on first use)."""
        return sorted(f for f in os.listdir(self.directory)
                      if f.startswith("prog_") and f.endswith(_SUFFIX))

    # ------------------------------------------------------------------
    def save(self, key: Hashable, prog, cert) -> str:
        """Atomically persist one verified entry; returns its path.
        Refuses certificates that are missing or failed — the disk only
        ever holds schedules that verified in some process (and will be
        re-verified in every process that loads them)."""
        if cert is None or not getattr(cert, "ok", False):
            raise PersistError("refusing to persist an unverified or "
                               "failed-verification program")
        _fp.fire("persist_save", directory=self.directory)
        payload = json.dumps({
            "key": _encode(key),
            "program": _encode(prog),
            "certificate": _encode(cert),
        }, separators=(",", ":")).encode()
        header = json.dumps({
            "magic": MAGIC,
            "format": FORMAT_VERSION,
            "jax": _jax_version(),
            "payload_bytes": len(payload),
            "payload_sha256": hashlib.sha256(payload).hexdigest(),
        }, separators=(",", ":")).encode()
        path = self._path(key)
        tmp = os.path.join(self.directory,
                           f".tmp_{os.path.basename(path)}.{os.getpid()}")
        try:
            with open(tmp, "wb") as fh:
                fh.write(header + b"\n" + payload)
            os.replace(tmp, path)
        except BaseException:
            # a failed write (full disk, read-only dir) must not strand
            # a temp file next to the live entries
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        return path

    # ------------------------------------------------------------------
    def _read(self, path: str, key: Optional[Hashable] = None
              ) -> Tuple[Hashable, Any, Any]:
        """Decode one entry file; raises :class:`PersistError` on any
        corruption, version skew, or (with ``key``) signature mismatch."""
        with open(path, "rb") as fh:
            blob = fh.read()
        # fault seam: an armed plan may raise OSError here (I/O error)
        # or hand back a truncated / bit-flipped blob, which the header
        # and checksum validation below must catch
        blob = _fp.corrupt("persist_load", blob)
        nl = blob.find(b"\n")
        if nl < 0:
            raise PersistError("truncated header")
        try:
            header = json.loads(blob[:nl])
        except ValueError as e:
            raise PersistError(f"malformed header: {e}")
        if not isinstance(header, dict) or header.get("magic") != MAGIC:
            raise PersistError("bad magic")
        if header.get("format") != FORMAT_VERSION:
            raise PersistError(
                f"format version skew: entry {header.get('format')!r}, "
                f"runtime {FORMAT_VERSION}")
        if header.get("jax") != _jax_version():
            raise PersistError(
                f"jax version skew: entry {header.get('jax')!r}, "
                f"runtime {_jax_version()!r}")
        payload = blob[nl + 1:]
        if len(payload) != header.get("payload_bytes"):
            raise PersistError(
                f"truncated payload: {len(payload)} bytes, header says "
                f"{header.get('payload_bytes')}")
        if hashlib.sha256(payload).hexdigest() != \
                header.get("payload_sha256"):
            raise PersistError("payload checksum mismatch")
        try:
            doc = json.loads(payload)
            stored_key = _decode(doc["key"])
            prog = _decode(doc["program"])
            cert = _decode(doc["certificate"])
        except (PersistError, KeyError, TypeError, ValueError) as e:
            raise PersistError(f"malformed payload: {e}")
        from .program import SuperstepProgram
        if not isinstance(prog, SuperstepProgram):
            raise PersistError("payload is not a SuperstepProgram entry")
        if entry_filename(stored_key) != os.path.basename(path):
            raise PersistError("entry filename does not match its key "
                               "(renamed or colliding entry)")
        if key is not None and stored_key != key:
            raise PersistError("signature mismatch: stored key differs "
                               "from the requested key")
        return stored_key, prog, cert

    def filename(self, key: Hashable) -> Optional[str]:
        """The entry filename ``key`` maps to, or ``None`` for a key
        that cannot be textualised (and so was never stored)."""
        try:
            return entry_filename(key)
        except PersistError:
            return None

    def load(self, key: Hashable) -> Tuple[str, Optional[Tuple[Any, Any]]]:
        """Classified lookup: ``("hit", (program, certificate))``,
        ``("miss", None)`` when no entry exists for the key,
        ``("invalid", None)`` when one exists but fails an integrity,
        version, or key check (the caller invalidates it and
        cold-builds), or ``("error", None)`` on a *transient* I/O
        failure — the entry itself may be fine, so the caller must NOT
        invalidate it; it retries or degrades to a cold miss."""
        try:
            path = self._path(key)
        except PersistError:
            return "miss", None     # unhashable-to-text key: never stored
        if not os.path.exists(path):
            return "miss", None
        try:
            _, prog, cert = self._read(path, key=key)
            return "hit", (prog, cert)
        except PersistError:
            return "invalid", None
        except OSError:
            return "error", None

    def invalidate(self, key: Hashable) -> bool:
        """Best-effort removal of a bad entry so it is not re-tried.
        Returns True iff the entry is gone afterwards — False (a
        read-only cache dir, say) tells the caller to poison the entry
        in memory instead, or it would re-pay decode + re-verify on
        every miss."""
        try:
            path = self._path(key)
        except PersistError:
            return True
        try:
            os.remove(path)
        except FileNotFoundError:
            return True
        except OSError:
            return not os.path.exists(path)
        return True

    def entries(self):
        """Iterate the whole store for offline analysis: yields
        ``(filename, error, key, program, certificate)`` — ``error`` is
        ``None`` for a well-formed entry, else the failure reason (and
        the remaining fields are ``None``)."""
        for fname in self.filenames():
            path = os.path.join(self.directory, fname)
            try:
                key, prog, cert = self._read(path)
                yield fname, None, key, prog, cert
            except (PersistError, OSError) as e:
                yield fname, str(e), None, None, None
