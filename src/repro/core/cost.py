"""BSP cost accounting — every superstep's h-relation, rounds and bytes.

Model compliance is only auditable if the layer itself can say what it
promised.  Each ``lpf_sync`` appends a :class:`SuperstepCost` record with
its h-relation (max over processes of bytes sent/received), the number of
collective rounds issued and the wire bytes actually scheduled (including
round padding and Bruck volume inflation).  The compliance checker then
verifies the *compiled HLO* matches the ledger, and the §Roofline report
feeds off the same records.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .machine import LPFMachine

__all__ = ["SuperstepCost", "CostLedger", "FUSED_METHODS",
           "OVERLAP_L_FRACTION", "overlap_cost", "schedule_seconds"]

#: methods that lower onto one native XLA collective (single round by
#: construction; their wire bytes equal the collective's schedule)
FUSED_METHODS = frozenset(
    {"fused", "fused_ag", "fused_rs", "fused_scatter", "fused_gather"})

#: residual latency of issuing one *additional* overlapped superstep as a
#: fraction of the full superstep latency ``l``.  Split-phase supersteps
#: share one barrier, but every extra member still pays its own launch /
#: progression overhead (pMR measures this as the cost of asynchronous
#: progression); 1/4 of ``l`` is the engineering assumption recorded here
#: so the overlap gate is explicit about it.
OVERLAP_L_FRACTION = 0.25


@dataclasses.dataclass(frozen=True)
class SuperstepCost:
    label: str
    h_bytes: int          # BSP h-relation of the *requested* pattern (bytes)
    wire_bytes: int       # bytes actually scheduled per process (max), incl. padding
    total_wire_bytes: int # sum over processes of bytes on the wire
    rounds: int           # collective launches issued
    n_msgs: int           # messages in the superstep
    method: str           # direct | bruck | valiant | fused* | overlap[k] | noop
    #: number of *additional* split-phase supersteps overlapped under this
    #: one (k - 1 for a k-member overlap group; 0 for a plain superstep).
    #: Each pays ``OVERLAP_L_FRACTION * l`` of issue latency on top of the
    #: shared barrier.
    overlap_extra: int = 0

    @property
    def is_fused(self) -> bool:
        return self.method in FUSED_METHODS

    def predicted_seconds(self, machine: LPFMachine) -> float:
        return (self.wire_bytes * machine.g + self.rounds * machine.l
                + self.overlap_extra * OVERLAP_L_FRACTION * machine.l)


def overlap_cost(costs: Sequence[SuperstepCost],
                 label: str = "") -> SuperstepCost:
    """The ledger record of ``k`` split-phase supersteps issued as one
    overlap group: their wire times hide under each other, so the
    BSP-time-equivalent wire is ``max_i(wire_i)`` (the paper's
    ``h_merged*g`` replaced by ``max(h_a, h_b)*g``), the shared barrier
    costs ``max_i(rounds_i) * l``, and each member past the first adds
    ``OVERLAP_L_FRACTION * l`` of issue latency (``l_overlap``).  Total
    wire bytes stay the sum — overlap hides time, not traffic."""
    costs = list(costs)
    if not costs:
        raise ValueError("overlap_cost of an empty group")
    if len(costs) == 1:
        return dataclasses.replace(costs[0], label=label)
    return SuperstepCost(
        label=label,
        h_bytes=max(c.h_bytes for c in costs),
        wire_bytes=max(c.wire_bytes for c in costs),
        total_wire_bytes=sum(c.total_wire_bytes for c in costs),
        rounds=max(c.rounds for c in costs),
        n_msgs=sum(c.n_msgs for c in costs),
        method=f"overlap[{'+'.join(c.method for c in costs)}]",
        overlap_extra=len(costs) - 1)


def schedule_seconds(cost_groups: Sequence[Sequence[SuperstepCost]],
                     machine: LPFMachine) -> float:
    """BSP time of a whole *schedule*: a sequence of issue groups, each a
    list of member superstep costs.  Singleton groups are priced as plain
    supersteps; multi-member groups as one :func:`overlap_cost` entry.
    This is the quantity the program optimizer's schedule search
    minimises, and the single comparison point for "schedule A vs
    schedule B" questions (searched vs peephole, optimized vs in-order):
    both sides priced by the same machine, overlap pricing included."""
    total = 0.0
    for costs in cost_groups:
        costs = list(costs)
        c = costs[0] if len(costs) == 1 else overlap_cost(costs)
        total += c.predicted_seconds(machine)
    return total


class CostLedger:
    """Per-context append-only log of superstep costs."""

    def __init__(self) -> None:
        self.records: List[SuperstepCost] = []

    def add(self, record: SuperstepCost) -> None:
        self.records.append(record)

    # -- aggregate views --------------------------------------------------
    @property
    def h_bytes(self) -> int:
        return sum(r.h_bytes for r in self.records)

    @property
    def wire_bytes(self) -> int:
        return sum(r.wire_bytes for r in self.records)

    @property
    def total_wire_bytes(self) -> int:
        return sum(r.total_wire_bytes for r in self.records)

    @property
    def rounds(self) -> int:
        return sum(r.rounds for r in self.records)

    @property
    def supersteps(self) -> int:
        return len(self.records)

    def predicted_seconds(self, machine: LPFMachine) -> float:
        return sum(r.predicted_seconds(machine) for r in self.records)

    def report(self, machine: Optional[LPFMachine] = None) -> str:
        lines = [f"{'label':<28}{'method':<14}{'h(B)':>12}{'wire(B)':>12}"
                 f"{'rounds':>8}{'msgs':>7}"
                 + (f"{'T_pred(us)':>12}" if machine else "")]
        for r in self.records:
            line = (f"{r.label:<28}{r.method:<14}{r.h_bytes:>12}"
                    f"{r.wire_bytes:>12}{r.rounds:>8}{r.n_msgs:>7}")
            if machine:
                line += f"{r.predicted_seconds(machine) * 1e6:>12.2f}"
            lines.append(line)
        total = (f"{'TOTAL':<28}{'':<14}{self.h_bytes:>12}"
                 f"{self.wire_bytes:>12}"
                 f"{self.rounds:>8}{sum(r.n_msgs for r in self.records):>7}")
        if machine:
            total += f"{self.predicted_seconds(machine) * 1e6:>12.2f}"
        lines.append(total)
        return "\n".join(lines)
