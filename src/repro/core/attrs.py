"""Sync/message attributes — the paper's extension point (S2.1, S6).

``lpf_sync`` accepts attributes that let an implementation relax
guarantees for better effective (g, l).  We realise the ones the paper
names as future work plus the ones the framework needs:

* ``method``    — h-relation execution algorithm: ``auto`` | ``direct``
                  (paper's direct all-to-all; m rounds of permutations) |
                  ``bruck`` (randomised-Bruck flavour: ceil(log2 p) rounds,
                  O(log p) x volume) | ``valiant`` (two-phase randomised
                  routing for skewed relations).
* ``no_conflict`` — caller asserts no overlapping writes: skips CRCW
                  arbitration ordering so rounds pack tighter (lower l).
* ``reduce_op``   — accumulating-put supersteps: overlapping destination
                  writes *combine* elementwise (``sum``/``max``/``min``)
                  instead of CRCW-arbitrating.  Elements covered by a
                  single message are written as usual; elements covered
                  by none keep their pre-superstep value.  Enables the
                  planner's fused reduce-scatter lowering
                  (``lax.psum_scatter``) for the canonical pattern.
* ``compress``  — quantise payloads (e.g. int8) before the wire: lower
                  effective g at a precision cost; used with error
                  feedback by the gradient-sync collectives.
* ``stale``     — tolerated staleness in supersteps; interpreted by the
                  runtime's local-SGD / stale-synchronous outer loop
                  (paper's future-work reference [16]), not by core sync.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

__all__ = ["CompressSpec", "SyncAttributes", "LPF_SYNC_DEFAULT", "LPF_MSG_DEFAULT"]


@dataclasses.dataclass(frozen=True)
class CompressSpec:
    """Payload quantisation spec (applies to floating slots only)."""

    bits: int = 8               # 8 -> int8 symmetric quantisation
    stochastic: bool = False    # stochastic rounding (needs a key per sync)

    @property
    def ratio(self) -> float:
        return self.bits / 32.0


@dataclasses.dataclass(frozen=True)
class SyncAttributes:
    method: Literal["auto", "direct", "bruck", "valiant"] = "auto"
    no_conflict: bool = False
    #: combine overlapping destination writes instead of arbitrating;
    #: one of "sum" | "max" | "min" (None = CRCW overwrite semantics)
    reduce_op: Optional[Literal["sum", "max", "min"]] = None
    compress: Optional[CompressSpec] = None
    stale: int = 0
    #: two-phase Valiant routing seed (static; randomness is configuration,
    #: not run-time state, so the schedule stays compile-time static).
    valiant_seed: int = 0x5DEECE66D

    def replace(self, **kw) -> "SyncAttributes":
        return dataclasses.replace(self, **kw)


LPF_SYNC_DEFAULT = SyncAttributes()
LPF_MSG_DEFAULT = object()  # placeholder for per-message attributes
