"""LPF error semantics mapped to the traced-JAX world.

The paper distinguishes *success*, *user-mitigable* errors (no side
effects; e.g. out-of-memory), and *fatal* errors.  In a traced SPMD
program the staging of communication happens at trace time, so capacity
violations (`lpf_resize_*` bounds) surface as mitigable Python exceptions
at trace time — before any communication is issued, hence side-effect
free, exactly as the paper requires.  Fatal errors (malformed h-relations
that can never execute) are :class:`LPFFatalError`.
"""

from __future__ import annotations

__all__ = [
    "LPF_SUCCESS",
    "LPF_ERR_OUT_OF_MEMORY",
    "LPF_ERR_FATAL",
    "LPFError",
    "LPFCapacityError",
    "LPFFatalError",
    "LPFAnalysisError",
]

LPF_SUCCESS = 0
LPF_ERR_OUT_OF_MEMORY = 1   # user-mitigable, guaranteed no side effects
LPF_ERR_FATAL = 2


class LPFError(Exception):
    """Base class for LPF errors."""

    code = LPF_ERR_FATAL


class LPFCapacityError(LPFError):
    """Mitigable error: a reserved capacity (message queue / memory
    register) would be exceeded.  Raised *before* any state change, so the
    caller may ``lpf_resize_*`` and retry — the paper's mitigable
    out-of-memory contract."""

    code = LPF_ERR_OUT_OF_MEMORY


class LPFFatalError(LPFError):
    """Non-mitigable error (malformed message, unregistered slot, ...)."""

    code = LPF_ERR_FATAL


class LPFAnalysisError(LPFError):
    """Raised by the static analyzer (``repro.analysis``) when sanitize
    mode finds an error-severity diagnostic, or when the schedule
    verifier refuses to certify an optimized program.  Like fatal
    errors, raised at trace time before any communication is issued."""

    code = LPF_ERR_FATAL
