"""LPF error semantics mapped to the traced-JAX world.

The paper distinguishes *success*, *user-mitigable* errors (no side
effects; e.g. out-of-memory), and *fatal* errors.  In a traced SPMD
program the staging of communication happens at trace time, so capacity
violations (`lpf_resize_*` bounds) surface as mitigable Python exceptions
at trace time — before any communication is issued, hence side-effect
free, exactly as the paper requires.  Fatal errors (malformed h-relations
that can never execute) are :class:`LPFFatalError`.

:func:`classify` extends the paper's two error classes with a third the
execution stack needs: *transient* infrastructure failures (disk I/O,
injected XLA/compile faults, timeouts) that did not corrupt LPF state
and may be retried — possibly from a checkpoint — by the recovery
supervisor (``repro.runtime.train_loop.StepSupervisor``).
"""

from __future__ import annotations

__all__ = [
    "LPF_SUCCESS",
    "LPF_ERR_OUT_OF_MEMORY",
    "LPF_ERR_FATAL",
    "LPF_ERR_TRANSIENT",
    "LPFError",
    "LPFCapacityError",
    "LPFFatalError",
    "LPFAnalysisError",
    "LPFTransientError",
    "classify",
]

LPF_SUCCESS = 0
LPF_ERR_OUT_OF_MEMORY = 1   # user-mitigable, guaranteed no side effects
LPF_ERR_FATAL = 2
LPF_ERR_TRANSIENT = 3       # infrastructure fault; retry/restore may succeed


class LPFError(Exception):
    """Base class for LPF errors."""

    code = LPF_ERR_FATAL


class LPFCapacityError(LPFError):
    """Mitigable error: a reserved capacity (message queue / memory
    register) would be exceeded.  Raised *before* any state change, so the
    caller may ``lpf_resize_*`` and retry — the paper's mitigable
    out-of-memory contract.

    ``required``/``capacity``/``kind`` let a handler size the retry
    instead of guessing: :meth:`repro.core.context.LPFContext.with_capacity`
    resizes the named resource to at least ``required`` and re-runs the
    caller's region."""

    code = LPF_ERR_OUT_OF_MEMORY

    def __init__(self, message: str, *, required: int = 0,
                 capacity: int = 0, kind: str = "queue"):
        super().__init__(message)
        self.required = int(required)
        self.capacity = int(capacity)
        self.kind = kind          # "queue" | "register"


class LPFFatalError(LPFError):
    """Non-mitigable error (malformed message, unregistered slot, ...)."""

    code = LPF_ERR_FATAL


class LPFTransientError(LPFError):
    """A classified infrastructure failure (I/O, compile, straggler
    escalation) surfaced *before* any communication was issued for the
    failing operation: LPF state is intact, so the supervisor may back
    off and retry — from the live state or from a checkpoint."""

    code = LPF_ERR_TRANSIENT


class LPFAnalysisError(LPFError):
    """Raised by the static analyzer (``repro.analysis``) when sanitize
    mode finds an error-severity diagnostic, or when the schedule
    verifier refuses to certify an optimized program.  Like fatal
    errors, raised at trace time before any communication is issued."""

    code = LPF_ERR_FATAL


def classify(err: BaseException) -> str:
    """File an exception into the supervisor's taxonomy:
    ``"mitigable"`` (resize-and-retry per the paper's contract),
    ``"transient"`` (infrastructure fault — retry, possibly from a
    checkpoint), or ``"fatal"`` (re-raise; retrying cannot help and
    might re-execute communication).

    Anything unrecognised is ``"fatal"``: an *unclassified* exception
    must never be silently retried — that is the chaos harness's core
    invariant."""
    from .faultpoints import InjectedFault
    if isinstance(err, LPFCapacityError):
        return "mitigable"
    if isinstance(err, LPFTransientError):
        return "transient"
    if isinstance(err, LPFError):
        return "fatal"
    if isinstance(err, (OSError, TimeoutError, InjectedFault)):
        return "transient"
    return "fatal"
