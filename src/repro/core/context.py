"""LPF contexts — ``lpf_exec``, ``lpf_hook``, ``lpf_rehook`` and the
twelve-primitive surface.

A *context* is a set of mesh axes inside an SPMD (``shard_map``) region.
``exec_`` launches an SPMD function on a mesh (the paper's process
spawning); ``hook`` runs an SPMD function *inside an existing traced
parallel program* — the interoperability mechanism that let the paper call
LPF algorithms from Spark lets us call them from any jit-compiled JAX
program, including a training step.  ``rehook`` re-scopes to a pristine
context, optionally over a sub-set of the axes (the paper's
library-encapsulation mechanism).

The context is imperative at trace time (mirroring the C API): ``put`` /
``get`` stage messages, ``sync`` compiles and executes the superstep, slot
values are read back with ``value`` / ``tensor``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from . import faultpoints as _fp
from .attrs import LPF_SYNC_DEFAULT, SyncAttributes
from .cost import CostLedger, SuperstepCost
from .errors import (LPFAnalysisError, LPFCapacityError, LPFError,
                     LPFFatalError)
from .machine import LPFMachine, HardwareModel, TPU_V5E, probe as _probe
from .memslot import Slot, SlotRegistry
from .program import (ProgramCache, ProgramStep, compile_program,
                      dependency_cone, global_program_cache,
                      trace_slot_map)
from .sync import (Msg, PlanCache, execute_plan, execute_schedule,
                   global_plan_cache)

__all__ = ["LPFContext", "exec_", "hook", "rehook", "LPF_ROOT_AXES"]

PidFn = Union[int, Sequence[int], Callable[[int], int]]
LPF_ROOT_AXES: Tuple[str, ...] = ()


def _per_pid(value: PidFn, p: int, name: str) -> List[int]:
    if callable(value):
        return [int(value(s)) for s in range(p)]
    if isinstance(value, (int, np.integer)):
        return [int(value)] * p
    out = [int(v) for v in value]
    if len(out) != p:
        raise LPFFatalError(f"{name} table must have length p={p}")
    return out


class _CacheStatsView(dict):
    """``ctx.cache_stats``: a dict of the memo layers' counter objects
    (``plan``/``program``) with a ``reset()`` that zeroes them in place —
    benchmarks and the replay tests measure hit/miss deltas without a
    process restart (the cache *contents* stay warm)."""

    def reset(self) -> None:
        for stats in self.values():
            stats.reset()


class LPFContext:
    """The LPF state of one SPMD region (paper: ``lpf_t``)."""

    def __init__(self, axes: Sequence[str] = LPF_ROOT_AXES, *,
                 hardware: HardwareModel = TPU_V5E,
                 plan_cache: Optional[PlanCache] = None,
                 program_cache: Optional[ProgramCache] = None,
                 persist_dir: Optional[str] = None,
                 sanitize: Optional[bool] = None,
                 _parent: Optional["LPFContext"] = None):
        self.axes: Tuple[str, ...] = tuple(axes)
        if self.axes:
            self.p: int = int(lax.psum(1, self.axes if len(self.axes) > 1
                                       else self.axes[0]))
            self.pid = lax.axis_index(self.axes if len(self.axes) > 1
                                      else self.axes[0])
        else:
            self.p = 1
            self.pid = jnp.zeros((), jnp.int32)
        self.hardware = hardware
        #: memoised superstep plans; shared process-wide by default so
        #: repeated h-relations plan once across contexts and traces.
        self.plan_cache = plan_cache if plan_cache is not None \
            else global_plan_cache()
        #: memoised optimized traces for the record/replay program layer
        self.program_cache = program_cache if program_cache is not None \
            else global_program_cache()
        #: persistent program cache (``persist_dir=`` or the
        #: ``LPF_PROGRAM_CACHE_DIR`` env var): certified optimized
        #: programs are written next to the XLA compilation cache and
        #: warm-loaded by any later context/process sharing the
        #: directory — a restarted worker pays zero re-planning and
        #: zero schedule-search cost.  Loaded entries are re-verified
        #: (``verify_program``) against the actual recorded trace
        #: before they may execute or compile.
        if persist_dir is None and _parent is None:
            persist_dir = os.environ.get("LPF_PROGRAM_CACHE_DIR") or None
        if persist_dir:
            self.program_cache.attach_store(persist_dir)
        self.registry = SlotRegistry(capacity=0)
        self.ledger = CostLedger()
        self._queue: List[Msg] = []
        self._queue_capacity = 0
        self._scratch: Optional[Slot] = None
        self._parent = _parent
        self._on_hold = False
        self._rec_depth = 0
        self._rec_labels: List[str] = []
        self._rec_pending: List[ProgramStep] = []
        self._rec_deferred_dereg: List[Slot] = []
        self._gate_machine: Optional[LPFMachine] = None
        #: lower optimized programs into single jitted XLA computations
        #: (:class:`repro.core.program.CompiledProgram`) instead of
        #: Python-dispatched superstep-by-superstep replay; the ledger is
        #: identical either way (``SuperstepProgram.ledger_costs``).  Set
        #: ``LPF_COMPILE_PROGRAMS=0`` to force the dispatched path.
        self.compile_programs: bool = \
            os.environ.get("LPF_COMPILE_PROGRAMS", "1") != "0"
        #: the most recently executed (optimized) program — inspect the
        #: searched schedule with ``ctx.last_program.explain(machine)``
        self.last_program = None
        #: sanitizer mode (``LPF_SANITIZE=1`` or ``sanitize=True``):
        #: every staged message is checked against live registrations,
        #: every flushed trace is linted (``repro.analysis.linter``) —
        #: error diagnostics raise :class:`LPFAnalysisError` before any
        #: communication is issued, warnings accumulate on
        #: :attr:`diagnostics`.  Sub-contexts (hook/compile_loop)
        #: inherit the parent's setting and diagnostics list.
        if sanitize is None:
            sanitize = _parent.sanitize if _parent is not None \
                else os.environ.get("LPF_SANITIZE", "0") != "0"
        self.sanitize: bool = bool(sanitize)
        self.diagnostics: List[Any] = [] if _parent is None \
            else _parent.diagnostics
        self._rec_registered: List[Slot] = []
        #: per-nesting-level start indices into ``_rec_pending`` — what
        #: lets :meth:`program` *discard* the supersteps recorded at an
        #: aborted level instead of flushing (= executing) a partial
        #: trace when an exception propagates out of the body.  That
        #: discard is what keeps a capacity error side-effect-free, the
        #: precondition of the paper's resize-and-retry contract
        #: (:meth:`with_capacity`).
        self._rec_marks: List[int] = []
        # the deterministic fault-injection hook (LPF_FAULT_PLAN=...):
        # arming is lazy and idempotent — no plan, no injector, and the
        # seams stay single-pointer-compare no-ops
        if _parent is None and os.environ.get("LPF_FAULT_PLAN") \
                and not _fp.armed():
            from ..runtime.faults import ensure_env_plan
            ensure_env_plan()

    # ------------------------------------------------------------------
    # capacity management: lpf_resize_message_queue / _memory_register
    # ------------------------------------------------------------------
    def resize_message_queue(self, n_msgs: int,
                             valiant_payload: int = 0,
                             payload_dtype=jnp.float32) -> None:
        """Reserve queue capacity (O(N) as per the paper).  When
        ``valiant_payload`` > 0 a scratch slot of that many elements is
        provisioned for two-phase routing."""
        if n_msgs < 0:
            raise LPFFatalError("negative queue capacity")
        self._queue_capacity = n_msgs
        if valiant_payload > 0 and self._rec_pending:
            # re-provisioning replaces the scratch slot recorded supersteps
            # may reference — execute them against the current one first
            self._flush_program()
        if valiant_payload > 0:
            # re-provisioning replaces the previous scratch slot; keeping
            # the stale registration would leak register capacity on every
            # resize call
            if self._scratch is not None:
                self.registry.deregister(self._scratch)
                self._scratch = None
            if self.registry.capacity < self.registry.n_active + 1:
                self.registry.resize(self.registry.n_active + 1)
            self._scratch = self.registry.register(
                "__lpf_valiant_scratch", jnp.zeros(valiant_payload,
                                                   payload_dtype), "global")

    def resize_memory_register(self, n_slots: int) -> None:
        reserve = 1 if self._scratch is not None else 0
        self.registry.resize(n_slots + reserve)

    def with_capacity(self, fn: Callable[["LPFContext"], Any], *,
                      max_attempts: int = 3, grow: float = 2.0) -> Any:
        """Run ``fn(ctx)`` under the paper's *mitigable-error* contract:
        an :class:`LPFCapacityError` is side-effect-free, so the caller
        may resize and retry.  This method implements that retry — the
        staged queue (and any supersteps recorded inside the attempt,
        via :meth:`program`'s abort path) is rolled back, the exhausted
        resource (``e.kind``: message queue or memory register) is grown
        to ``max(e.required, current * grow)``, and ``fn`` runs again,
        up to ``max_attempts`` times.  The final attempt's capacity
        error propagates — still mitigable, for a caller with a better
        resize policy."""
        if max_attempts < 1:
            raise LPFFatalError("with_capacity needs max_attempts >= 1")
        for attempt in range(max_attempts):
            queue_snap = list(self._queue)
            pend_snap = len(self._rec_pending)
            try:
                return fn(self)
            except LPFCapacityError as e:
                if attempt == max_attempts - 1:
                    raise
                # the contract says the failed attempt staged nothing;
                # enforce it — drop anything the attempt left behind
                self._queue = queue_snap
                del self._rec_pending[pend_snap:]
                if e.kind == "register":
                    cap = self.registry.capacity
                    self.registry.resize(
                        max(e.required, int(cap * grow) + 1))
                else:
                    cap = self._queue_capacity
                    self.resize_message_queue(
                        max(e.required, int(cap * grow) + 1))
        raise AssertionError("unreachable")  # pragma: no cover

    # ------------------------------------------------------------------
    # registration: lpf_register_{global,local}, lpf_deregister
    # ------------------------------------------------------------------
    def register_global(self, name: str, value, flatten: bool = True) -> Slot:
        slot = self.registry.register(name, value, "global", flatten)
        if self._rec_depth and self.sanitize:
            self._rec_registered.append(slot)
        return slot

    def register_local(self, name: str, value, flatten: bool = True) -> Slot:
        slot = self.registry.register(name, value, "local", flatten)
        if self._rec_depth and self.sanitize:
            self._rec_registered.append(slot)
        return slot

    def deregister(self, slot: Slot) -> None:
        self._rec_registered = [
            s for s in self._rec_registered
            if not (s.sid == slot.sid and s.gen == slot.gen)]
        if self._rec_depth and self._pending_refs(slot):
            # a recorded superstep still moves data through this slot;
            # deregistration takes effect when the trace flushes
            self._rec_deferred_dereg.append(slot)
            return
        self.registry.deregister(slot)

    # ------------------------------------------------------------------
    # staging: lpf_put / lpf_get
    # ------------------------------------------------------------------
    def _require_active(self) -> None:
        if self._on_hold:
            raise LPFFatalError(
                "context is on hold while a rehook sub-program runs; "
                "active contexts must be disjoint (paper S2.2)")

    def _stage(self, msgs: List[Msg]) -> None:
        self._require_active()
        # fault seam: an armed plan may simulate capacity exhaustion
        # here — same mitigable LPFCapacityError, same resize-and-retry
        # contract (:meth:`with_capacity`) as the real check below
        _fp.fire("capacity", staged=len(self._queue), new=len(msgs),
                 capacity=self._queue_capacity)
        if len(self._queue) + len(msgs) > self._queue_capacity:
            raise LPFCapacityError(
                f"message queue capacity {self._queue_capacity} exceeded "
                f"({len(self._queue)} staged + {len(msgs)} new); call "
                f"resize_message_queue first",
                required=len(self._queue) + len(msgs),
                capacity=self._queue_capacity, kind="queue")
        # extents/dtypes/kinds are checked the moment a transfer is
        # staged — an out-of-bounds put fails at the ``ctx.put`` call
        # site, not at the (possibly much later) sync or flush
        for m in msgs:
            m.validate(self.p)
        if self.sanitize:
            for m in msgs:
                for slot in (m.src_slot, m.dst_slot):
                    if not slot.gen:
                        continue   # synthetic handle, never registered
                    if not self.registry.is_registered(slot) or any(
                            d.sid == slot.sid and d.gen == slot.gen
                            for d in self._rec_deferred_dereg):
                        raise LPFAnalysisError(
                            f"LPF003: staged transfer uses deregistered "
                            f"slot {slot}")
        self._queue.extend(msgs)

    def put(self, src_slot: Slot, dst_slot: Slot, *, to: PidFn,
            src_off: PidFn = 0, dst_off: PidFn = 0,
            size: Optional[PidFn] = None,
            where: Optional[Callable[[int], bool]] = None) -> None:
        """Stage a put from every process ``s`` to process ``to(s)``.

        Offsets/sizes may be ints (uniform), tables, or functions of the
        *sending* pid — all static, as BSP supersteps declare their
        h-relation up front.  ``where`` statically masks which pids
        participate.  O(1) per message, no communication (paper Fig. 1).
        """
        if size is None:
            size = src_slot.size
        soff = _per_pid(src_off, self.p, "src_off")
        doff = _per_pid(dst_off, self.p, "dst_off")
        dsts = _per_pid(to, self.p, "to")
        sizes = _per_pid(size, self.p, "size")
        msgs = [Msg(s, dsts[s], src_slot, soff[s], dst_slot, doff[s],
                    sizes[s], origin="put")
                for s in range(self.p)
                if (where is None or where(s)) and sizes[s] > 0]
        self._stage(msgs)

    def get(self, src_slot: Slot, dst_slot: Slot, *, frm: PidFn,
            src_off: PidFn = 0, dst_off: PidFn = 0,
            size: Optional[PidFn] = None,
            where: Optional[Callable[[int], bool]] = None) -> None:
        """Stage a get: every process ``s`` reads from ``frm(s)``.

        Tables are indexed by the *destination* pid ``s`` (the caller);
        the message table is globally known so a get is a put issued from
        the remote side."""
        if size is None:
            size = src_slot.size
        soff = _per_pid(src_off, self.p, "src_off")
        doff = _per_pid(dst_off, self.p, "dst_off")
        srcs = _per_pid(frm, self.p, "frm")
        sizes = _per_pid(size, self.p, "size")
        msgs = [Msg(srcs[s], s, src_slot, soff[s], dst_slot, doff[s],
                    sizes[s], origin="get")
                for s in range(self.p)
                if (where is None or where(s)) and sizes[s] > 0]
        self._stage(msgs)

    def put_msgs(self, msgs: Sequence[Tuple[int, int, Slot, int, Slot,
                                            int, int]]) -> None:
        """Stage an explicit message table [(src, dst, src_slot, src_off,
        dst_slot, dst_off, size), ...] — the fully general h-relation."""
        self._stage([Msg(*m) for m in msgs])

    # ------------------------------------------------------------------
    # the fence: lpf_sync
    # ------------------------------------------------------------------
    def sync(self, attrs: SyncAttributes = LPF_SYNC_DEFAULT,
             label: str = "") -> Optional[SuperstepCost]:
        """Plan (memoised), lower, and account one superstep; returns its
        ledger entry so callers can thread costs through without reading
        the ledger back.

        While a program is being recorded (:meth:`record` /
        :meth:`program`) the superstep is *deferred*: its table is
        snapshotted into the pending trace and executed at the next
        flush — a local read/write of a touched slot executes exactly
        the slot's dependency cone (see :meth:`_flush_cone`);
        :meth:`end_record` executes whatever remains — after trace
        optimization (coalescing, dead-transfer elimination, batching,
        split-phase overlap).  In that case ``sync`` returns ``None``
        and the ledger entries appear at flush time."""
        self._require_active()
        if not label:
            prefix = next((l for l in reversed(self._rec_labels) if l), "")
            n = self.ledger.supersteps + len(self._rec_pending)
            label = f"{prefix}.superstep[{n}]" if prefix \
                else f"superstep[{n}]"
        if self._rec_depth:
            # messages were validated at stage time (see ``_stage``)
            self._rec_pending.append(
                ProgramStep(tuple(self._queue), attrs, label))
            self._queue = []
            return None
        if self.sanitize and self._queue:
            self._sanitize_lint(
                [ProgramStep(tuple(self._queue), attrs, label)])
        plan = self.plan_cache.get_or_plan(self._queue, self.p, attrs,
                                           self._scratch)
        cost = execute_plan(plan, self.registry, self._queue, self.p,
                            self.axes, self.pid, attrs, label,
                            scratch=self._scratch)
        self.ledger.add(cost)
        self._queue = []
        return cost

    # ------------------------------------------------------------------
    # program record/replay (see repro.core.program)
    # ------------------------------------------------------------------
    def record(self, label: str = "") -> None:
        """Start (or nest into) program recording: subsequent ``sync``
        calls defer into a trace that is optimized — coalesced,
        dead-transfer-eliminated, cost-gated superstep batching — and
        replayed through the program cache at flush time.  ``label``
        prefixes the default ledger labels of unlabelled syncs recorded
        at this level."""
        self._require_active()
        self._rec_depth += 1
        self._rec_labels.append(label)
        self._rec_marks.append(len(self._rec_pending))

    def end_record(self) -> None:
        """Leave one level of recording; the outermost level flushes any
        pending supersteps."""
        if self._rec_depth == 0:
            raise LPFFatalError("end_record without a matching record()")
        self._rec_depth -= 1
        self._rec_labels.pop()
        self._rec_marks.pop()
        if self._rec_depth == 0:
            self._flush_program()
            if self.sanitize and self._rec_registered:
                from ..analysis.linter import Diagnostic, WARNING
                for slot in self._rec_registered:
                    if self.registry.is_registered(slot):
                        self.diagnostics.append(Diagnostic(
                            "LPF003", WARNING, -1,
                            f"slot {slot} registered during the "
                            f"recording is still registered at "
                            f"end_record (leak?)"))
            self._rec_registered = []

    def abort_record(self) -> None:
        """Abandon one level of recording: the supersteps recorded at
        this level are *discarded*, not executed.  This is the
        exception path of :meth:`program` — flushing a partial trace
        when the body raised would issue communication the caller never
        completed, breaking the mitigable-error contract (a capacity
        error must be side-effect-free so :meth:`with_capacity` can
        resize and retry)."""
        if self._rec_depth == 0:
            raise LPFFatalError("abort_record without a matching record()")
        self._rec_depth -= 1
        self._rec_labels.pop()
        mark = self._rec_marks.pop()
        # steps recorded before the mark may have flushed already (a
        # dependency-cone read shrinks _rec_pending and rebases marks),
        # so the mark never exceeds the pending length
        del self._rec_pending[mark:]
        self._queue = []
        if self._rec_depth == 0:
            self._rec_registered = []

    @contextlib.contextmanager
    def program(self, label: str = ""):
        """``with ctx.program(): ...`` — record the body's supersteps as
        one :class:`repro.core.SuperstepProgram`; re-entrant (a recorded
        collective inside a recorded training step extends the outer
        trace).  If the body raises, the supersteps it recorded are
        discarded (:meth:`abort_record`) — never executed as a partial
        trace — and the exception propagates."""
        self.record(label)
        try:
            yield self
        except BaseException:
            self.abort_record()
            raise
        else:
            self.end_record()

    def _machine(self) -> LPFMachine:
        """The (g, l) machine the optimizer's cost gate prices with:
        the real per-axis probe, so a context spanning a DCN pod axis
        gates with DCN latencies, not the first axis's link class."""
        if self._gate_machine is None:
            axis_sizes = {a: int(lax.psum(1, a)) for a in self.axes}
            self._gate_machine = _probe(axis_sizes, self.hardware)
        return self._gate_machine

    def _pending_refs(self, slot: Slot, dst_only: bool = False) -> bool:
        """Does any pending recorded superstep reference ``slot``?"""
        for st in self._rec_pending:
            for m in st.msgs:
                if m.dst_slot.sid == slot.sid:
                    return True
                if not dst_only and m.src_slot.sid == slot.sid:
                    return True
        return False

    def _execute_steps(self, steps: List[ProgramStep]) -> None:
        """Optimize (or fetch the cached optimization of) one trace and
        execute it; the ledger gains one entry per *optimized* superstep
        — each exactly its plan's predicted cost — and one combined
        entry (``overlap_cost`` of the members' plans) per overlap
        group issued split-phase.  The searched schedule may *reorder*
        supersteps (non-adjacent hoists); ``materialize`` resolves the
        program's canonical ranks against this trace's own canonical
        order, so labels and staged-message reuse stay attached to the
        right recorded steps whatever order the scheduler emitted.

        With :attr:`compile_programs` (the default) the whole schedule
        runs as ONE jitted computation: slot values flow in, the
        compiled body issues every superstep, results write back through
        the registry's validating ``set_value``.  The dispatched path
        below it executes the same plans through the same
        ``execute_schedule`` loop, so the two ledgers are bit-for-bit
        identical — ``ledger_costs`` and ``execute_schedule`` both read
        the plans' predicted costs."""
        from .program import canonical_order
        order = canonical_order(steps)
        prog, key = self.program_cache.get_or_build_keyed(
            steps, self.p, self._machine(), plan_cache=self.plan_cache,
            scratch=self._scratch, order=order)
        self.last_program = prog
        # every schedule is certified (memoized per cache key) before it
        # may execute or be compiled; a program the verifier cannot
        # certify never reaches the wire
        cert = self.program_cache.certify(key, steps, prog,
                                          scratch=self._scratch,
                                          order=order)
        if not cert.ok:
            raise LPFAnalysisError(
                "schedule verification failed; refusing to execute:\n  "
                + "\n  ".join(str(d) for d in cert.diagnostics))
        if self.sanitize:
            self._sanitize_lint(steps, prog, order)
        # fault seam: an armed plan may delay this flush (a straggler);
        # pure wall-clock — numerics and ledger are untouched, which is
        # exactly what the StragglerMonitor is built to notice
        d = _fp.delay("straggler")
        if d > 0:
            time.sleep(d)
        labels = [st.label for st in steps]
        cp = None
        if self.compile_programs and \
                not self.program_cache.compile_quarantined(key, self.axes):
            cp = self.program_cache.compiled(key, self.axes)
            if cp is None:
                # graceful degradation: a *foreign* compilation failure
                # (XLA, OOM, injected) falls back to the dispatched
                # execute_schedule path below — the SAME certified
                # program, so numerics and ledger are bit-for-bit
                # identical — and quarantines this (key, axes) so
                # replays skip the doomed compile.  LPF errors are
                # contract violations, never degraded around.
                try:
                    cp = compile_program(prog, steps, order, self.p,
                                         self.axes,
                                         scratch=self._scratch)
                except LPFError:
                    raise
                except Exception as e:
                    self.program_cache.quarantine_compile(
                        key, self.axes, e)
                else:
                    self.program_cache.set_compiled(key, self.axes, cp)
        if cp is not None:
            slots = trace_slot_map(steps, order)
            vals = [self.registry.value(s) for s in slots]
            scratch_val = self.registry.value(self._scratch) \
                if cp.scratch is not None else None
            out_vals, out_scratch = cp(self.pid, vals, scratch_val)
            for s, v in zip(slots, out_vals):
                self.registry.set_value(s, v)
            if cp.scratch is not None:
                self.registry.set_value(self._scratch, out_scratch)
            costs = prog.ledger_costs(labels, order)
        else:
            entries = prog.materialize(steps, labels, order=order)
            costs = execute_schedule(entries, prog.groups(),
                                     self.registry, self.p, self.axes,
                                     self.pid, scratch=self._scratch)
        for cost in costs:
            self.ledger.add(cost)

    def _sanitize_lint(self, steps: List[ProgramStep],
                       prog=None, order=None) -> None:
        """Sanitizer hook: lint a trace about to execute.  Error
        diagnostics raise :class:`LPFAnalysisError` (before any
        communication); warnings accumulate on :attr:`diagnostics`."""
        from ..analysis.linter import ERROR, lint_program, lint_trace
        diags = list(lint_trace(steps, self.p, check_dead=False))
        if prog is not None:
            diags += lint_program(prog, steps, order=order)
        errors = [d for d in diags if d.severity == ERROR]
        if errors:
            raise LPFAnalysisError(
                "sanitize: " + "; ".join(str(d) for d in errors))
        self.diagnostics.extend(diags)

    def _drain_deferred_dereg(self) -> None:
        still: List[Slot] = []
        for slot in self._rec_deferred_dereg:
            if self._rec_pending and self._pending_refs(slot):
                still.append(slot)       # a deferred step still moves data
            else:
                self.registry.deregister(slot)
        self._rec_deferred_dereg = still

    def _flush_program(self) -> None:
        """Execute the whole pending trace (end of recording)."""
        if not self._rec_pending:
            return
        steps, self._rec_pending = self._rec_pending, []
        self._rec_marks = [0] * len(self._rec_marks)
        self._execute_steps(steps)
        self._drain_deferred_dereg()

    def _flush_cone(self, slot: Slot, include_reads: bool) -> None:
        """Dataflow-precise flush: execute only the pending supersteps a
        local read (or write, with ``include_reads``) of ``slot``
        depends on — its dependency cone, a topological slice over the
        trace's slot-dataflow graph.  Independent supersteps stay
        recorded across the compute barrier, keeping the
        batching/overlap window open for later syncs."""
        if not self._rec_pending:
            return
        cone = dependency_cone(self._rec_pending, slot.sid, include_reads)
        if not cone:
            return
        if len(cone) == len(self._rec_pending):
            self._flush_program()
            return
        cone_set = set(cone)
        steps = [st for i, st in enumerate(self._rec_pending)
                 if i in cone_set]
        self._rec_pending = [st for i, st in enumerate(self._rec_pending)
                             if i not in cone_set]
        # rebase the per-level abort marks: indices below a mark that
        # just flushed no longer occupy pending positions
        self._rec_marks = [m - sum(1 for i in cone_set if i < m)
                           for m in self._rec_marks]
        self._execute_steps(steps)
        self._drain_deferred_dereg()

    # ------------------------------------------------------------------
    # whole-loop compilation
    # ------------------------------------------------------------------
    def compile_loop(self, body: Callable[["LPFContext", Any], Any],
                     carry: Any, *, n_iters: Optional[int] = None,
                     cond: Optional[Callable[[Any], Any]] = None,
                     label: str = "loop",
                     collect: Optional[Callable[[Any], Any]] = None) -> Any:
        """Roll an iterated LPF program into ONE XLA loop.

        ``body(sub_ctx, carry) -> carry`` runs each iteration's compute
        and supersteps against a fresh sub-context whose trace records
        as one program (so the schedule search and the compiled-program
        path apply per iteration); the loop itself lowers through
        ``compat.scan`` (``n_iters``) or ``compat.while_loop``
        (``cond(carry) -> bool``), so N iterations issue as a single
        XLA ``While`` computation instead of N Python-dispatched calls —
        the torch_xla ``fori_loop`` pattern.  Exactly one of
        ``n_iters``/``cond`` must be given.

        The body traces ONCE: its per-iteration superstep costs are
        appended to this context's ledger once (the BSP model prices one
        iteration; multiply by the executed trip count for totals —
        which the trace cannot know for a ``cond`` loop).  With
        ``collect`` (scan only) each iteration's ``collect(carry)`` is
        stacked and ``(final_carry, stacked)`` is returned; otherwise
        just the final carry."""
        if (n_iters is None) == (cond is None):
            raise LPFFatalError(
                "compile_loop needs exactly one of n_iters= or cond=")
        if collect is not None and cond is not None:
            raise LPFFatalError(
                "collect= requires a counted loop (n_iters=): a "
                "while_loop's trip count is dynamic, so there is "
                "nothing static to stack into")
        self._require_active()
        ledgers: List[CostLedger] = []

        def one(c):
            sub = LPFContext(self.axes, hardware=self.hardware,
                             plan_cache=self.plan_cache,
                             program_cache=self.program_cache,
                             _parent=self)
            sub.compile_programs = self.compile_programs
            ledgers.append(sub.ledger)
            with sub.program(label):
                out = body(sub, c)
            return out

        if cond is not None:
            final, ys = compat.while_loop(cond, one, carry), None
        else:
            def step(c, _):
                out = one(c)
                return out, (None if collect is None else collect(out))

            final, ys = compat.scan(step, carry, None, length=n_iters)
        # guard against a double trace (e.g. dtype promotion in the
        # carry forcing a re-trace): ledger the first trace only
        if ledgers:
            for cost in ledgers[0].records:
                self.ledger.add(cost)
        return final if collect is None else (final, ys)

    @property
    def cache_stats(self) -> "_CacheStatsView":
        """Hit/miss/eviction counters of both memo layers; call
        ``.reset()`` on the returned view to zero the counters in place
        (the caches stay warm) for delta measurements."""
        return _CacheStatsView(plan=self.plan_cache.stats,
                               program=self.program_cache.stats)

    # ------------------------------------------------------------------
    # introspection: lpf_probe
    # ------------------------------------------------------------------
    def probe(self, axis_sizes: Optional[dict] = None) -> LPFMachine:
        if axis_sizes is None:
            if not self.axes:
                axis_sizes = {}
            else:
                axis_sizes = {a: int(lax.psum(1, a)) for a in self.axes}
        return _probe(axis_sizes, self.hardware)

    # ------------------------------------------------------------------
    # local access (between supersteps)
    # ------------------------------------------------------------------
    def value(self, slot: Slot) -> jnp.ndarray:
        # local compute is a barrier, but a *dataflow-precise* one: a
        # read executes only the pending supersteps in the slot's
        # dependency cone; independent supersteps stay recorded
        self._flush_cone(slot, include_reads=False)
        return self.registry.value(slot)

    def tensor(self, slot: Slot) -> jnp.ndarray:
        self._flush_cone(slot, include_reads=False)
        return self.registry.tensor(slot)

    def write(self, slot: Slot, value) -> None:
        """Local compute step writing a slot (allowed between supersteps)."""
        # recorded supersteps must observe the slot as it was when they
        # were staged; overwriting a slot flushes the cone of supersteps
        # that read *or* write it (WAR + WAW), and only that cone
        self._flush_cone(slot, include_reads=True)
        value = jnp.asarray(value).reshape(-1).astype(slot.dtype)
        self.registry.set_value(slot, value)

    # convenience mirrors of the C API's context queries
    @property
    def nprocs(self) -> int:
        return self.p


@dataclasses.dataclass
class _Args:
    """``lpf_args_t``: arbitrary input/output passing."""

    input: Any = None
    output: Any = None


def hook(axes: Sequence[str], spmd: Callable, args: Any = None, *,
         hardware: HardwareModel = TPU_V5E,
         plan_cache: Optional[PlanCache] = None,
         program_cache: Optional[ProgramCache] = None,
         parent: Optional[LPFContext] = None) -> Any:
    """``lpf_hook``: run an LPF SPMD function inside the *current* parallel
    environment (any traced program already under a mesh).  Returns the
    function's output.  O(1) setup — no processes are spawned.  The child
    context inherits the parent's plan/program caches (or explicit ones)
    so isolated caches stay isolated across hooked sub-programs."""
    if plan_cache is None and parent is not None:
        plan_cache = parent.plan_cache
    if program_cache is None and parent is not None:
        program_cache = parent.program_cache
    ctx = LPFContext(axes, hardware=hardware, plan_cache=plan_cache,
                     program_cache=program_cache, _parent=parent)
    return spmd(ctx, ctx.pid, ctx.p, args)


def rehook(ctx: LPFContext, spmd: Callable, args: Any = None, *,
           axes: Optional[Sequence[str]] = None) -> Any:
    """``lpf_rehook``: temporarily replace an active context with a
    pristine one (optionally over a sub-set of its axes) — the paper's
    sub-library encapsulation.  The parent context is on hold while the
    sub-program runs (active contexts are disjoint)."""
    sub_axes = tuple(axes) if axes is not None else ctx.axes
    for a in sub_axes:
        if a not in ctx.axes:
            raise LPFFatalError(f"rehook axis {a!r} not in parent context")
    ctx._on_hold = True
    try:
        return hook(sub_axes, spmd, args, hardware=ctx.hardware, parent=ctx)
    finally:
        ctx._on_hold = False


def exec_(mesh: jax.sharding.Mesh, spmd: Callable, args: Any = None, *,
          axes: Optional[Sequence[str]] = None,
          in_specs: Any = None, out_specs: Any = P(),
          hardware: HardwareModel = TPU_V5E,
          jit: bool = True,
          return_ledger: bool = False) -> Any:
    """``lpf_exec``: launch ``spmd(ctx, s, p, args)`` on ``mesh``.

    ``args`` are replicated by default (``in_specs``) and outputs are
    expected replicated (``out_specs=P()``), mirroring the C API's
    broadcast args; pass explicit specs for distributed I/O.  With
    ``return_ledger=True`` also returns the cost ledger recorded at trace
    time, for compliance checking."""
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    ledger_box: List[CostLedger] = []

    def wrapped(a):
        ctx = LPFContext(axes, hardware=hardware)
        ledger_box.append(ctx.ledger)
        return spmd(ctx, ctx.pid, ctx.p, a)

    if in_specs is None:
        in_specs = compat.tree_map(lambda _: P(), args)
    fn = compat.shard_map(wrapped, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check_vma=False)
    if jit:
        fn = jax.jit(fn)
    out = fn(args)
    if return_ledger:
        return out, (ledger_box[0] if ledger_box else CostLedger())
    return out
