"""The superstep compiler — ``lpf_sync``'s four phases on XLA.

The paper implements ``lpf_sync`` in four phases: (1) barrier + meta-data
exchange, (2) write-conflict resolution, (3) data exchange, (4) barrier.
On TPU/XLA the communication pattern of a BSP superstep is static at trace
time, so phases (1)-(2) run *in the compiler*: we analyse the staged
message table, resolve write conflicts by deterministic arbitration
(ascending source PID; the last writer — highest PID — wins, a refinement
of the paper's arbitrary-order CRCW), and lower phase (3) to a minimal
schedule of XLA collectives.  Phase (4) is implicit in XLA's dataflow.

Three execution methods mirror the paper's Table 1:

* ``direct``  — greedy edge-colouring of the message multigraph into
  partial permutations; one ``ppermute`` per round (m rounds for an
  m-relation), plus fast paths for uniform permutations (1 static-slice
  ``ppermute``) and canonical total exchanges (1 ``all_to_all``).
* ``bruck``   — the randomised-Bruck flavour: ceil(log2 p) rounds in
  *relative-destination coordinates* (statically indexable rows), paying
  O(log p) x volume for O(log p) latency.
* ``valiant`` — two-phase randomised routing for skewed h-relations:
  messages bounce via a seeded-hash intermediate, each phase a ``direct``
  sync of a near-balanced relation.

Every sync appends a :class:`SuperstepCost` to the context ledger so model
compliance can be audited against the compiled HLO.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .attrs import SyncAttributes
from .cost import SuperstepCost
from .errors import LPFFatalError
from .memslot import Slot, SlotRegistry

__all__ = ["Msg", "execute_sync", "plan_cost"]

AxisNames = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Msg:
    """One staged one-sided transfer (a ``lpf_put``; ``lpf_get`` is staged
    as a put from the remote side — the table is globally known)."""

    src: int
    dst: int
    src_slot: Slot
    src_off: int
    dst_slot: Slot
    dst_off: int
    size: int
    #: which call staged this: "put" (src is the caller's own memory, may
    #: be local-registered), "get" (dst is the caller's own), or "table"
    #: (fully general: both ends remotely referred -> both global)
    origin: str = "table"

    def validate(self, p: int) -> None:
        if not (0 <= self.src < p and 0 <= self.dst < p):
            raise LPFFatalError(f"pid out of range in {self}")
        if self.size < 0:
            raise LPFFatalError(f"negative size in {self}")
        if self.src_off < 0 or self.src_off + self.size > self.src_slot.size:
            raise LPFFatalError(f"source range OOB in {self}")
        if self.dst_off < 0 or self.dst_off + self.size > self.dst_slot.size:
            raise LPFFatalError(f"destination range OOB in {self}")
        if self.src_slot.dtype != self.dst_slot.dtype:
            raise LPFFatalError(f"dtype mismatch in {self}")
        if self.src != self.dst:
            # the remotely-referred side must be collectively registered
            # (paper S2.1); the caller's own side may be register_local
            need_global = {"put": (self.dst_slot,),
                           "get": (self.src_slot,),
                           "table": (self.src_slot, self.dst_slot)}
            for slot in need_global[self.origin]:
                if slot.kind != "global":
                    raise LPFFatalError(
                        f"remotely-referred slot {slot} must be "
                        f"register_global ({self.origin} in {self})")


# --------------------------------------------------------------------------
# Phase 1-2: trace-time planning
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Round:
    """One partial permutation: <=1 send and <=1 receive per process."""

    msgs: List[Msg]
    size: int = 0  # padded payload (elements), filled by finalise

    def finalise(self) -> None:
        self.size = max((m.size for m in self.msgs), default=0)


def _conflicts(a: Msg, b: Msg) -> bool:
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and a.dst_off < b.dst_off + b.size
            and b.dst_off < a.dst_off + a.size)


def _colour_rounds(msgs: Sequence[Msg], no_conflict: bool) -> List[Round]:
    """Greedy edge colouring preserving CRCW arbitration order.

    Messages are placed in ascending (src, dst, dst_off) order; a message
    that overlaps an earlier message's destination region must land in a
    strictly later round so that the higher-PID write is applied last.
    """
    order = sorted(msgs, key=lambda m: (m.src, m.dst, m.dst_off))
    rounds: List[Round] = []
    send_busy: List[set] = []
    recv_busy: List[set] = []
    placed: List[Tuple[Msg, int]] = []
    for m in order:
        floor = 0
        if not no_conflict:
            for prev, r in placed:
                if _conflicts(prev, m):
                    floor = max(floor, r + 1)
        r = floor
        while True:
            while r >= len(rounds):
                rounds.append(Round(msgs=[]))
                send_busy.append(set())
                recv_busy.append(set())
            if m.src not in send_busy[r] and m.dst not in recv_busy[r]:
                rounds[r].msgs.append(m)
                send_busy[r].add(m.src)
                recv_busy[r].add(m.dst)
                placed.append((m, r))
                break
            r += 1
    for rd in rounds:
        rd.finalise()
    return rounds


def _is_uniform_round(msgs: Sequence[Msg], p: int) -> bool:
    """True if all messages share offsets and size (static-slice fast path)."""
    if not msgs:
        return False
    m0 = msgs[0]
    return all(m.src_off == m0.src_off and m.dst_off == m0.dst_off
               and m.size == m0.size for m in msgs)


def _detect_total_exchange(msgs: Sequence[Msg], p: int
                           ) -> Optional[Tuple[Slot, Slot, int]]:
    """Detect the canonical total exchange: every (s, d) pair sends ``w``
    elements with src_off = d*w and dst_off = s*w -> one ``all_to_all``."""
    if len(msgs) != p * p or p == 1:
        return None
    m0 = msgs[0]
    w = m0.size
    if w == 0:
        return None
    seen = set()
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w or m.src_off != m.dst * w
                or m.dst_off != m.src * w or (m.src, m.dst) in seen):
            return None
        seen.add((m.src, m.dst))
    if m0.src_slot.size < p * w or m0.dst_slot.size < p * w:
        return None
    return (m0.src_slot, m0.dst_slot, w)


def _detect_allgather(msgs: Sequence[Msg], p: int
                      ) -> Optional[Tuple[Slot, Slot, int, np.ndarray]]:
    """Detect the canonical all-gather: every src sends the *same* ``w``
    elements (from a per-src constant offset) to every other process at
    dst_off = src*w -> one ``lax.all_gather``."""
    if p == 1 or len(msgs) not in (p * p, p * (p - 1)):
        return None
    m0 = msgs[0]
    w = m0.size
    if w == 0:
        return None
    seen = set()
    src_off = np.full(p, -1, np.int64)
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w
                or m.dst_off != m.src * w or (m.src, m.dst) in seen):
            return None
        if src_off[m.src] == -1:
            src_off[m.src] = m.src_off
        elif src_off[m.src] != m.src_off:
            return None
        seen.add((m.src, m.dst))
    if m0.src_slot.size < w or m0.dst_slot.size < p * w:
        return None
    if len(msgs) == p * (p - 1) and any(s == d for s, d in seen):
        return None
    src_off[src_off == -1] = 0
    return (m0.src_slot, m0.dst_slot, w, src_off)


def plan_cost(msgs: Sequence[Msg], p: int, attrs: SyncAttributes,
              label: str, method: str, rounds: int,
              wire_sent: Dict[int, int], wire_recv: Dict[int, int]) -> SuperstepCost:
    sent = np.zeros(p, dtype=np.int64)
    recv = np.zeros(p, dtype=np.int64)
    for m in msgs:
        if m.src != m.dst:
            nbytes = m.size * jnp.dtype(m.src_slot.dtype).itemsize
            sent[m.src] += nbytes
            recv[m.dst] += nbytes
    h_bytes = int(max(np.max(sent, initial=0), np.max(recv, initial=0)))
    wire = 0
    total = 0
    for pid in range(p):
        wire = max(wire, wire_sent.get(pid, 0), wire_recv.get(pid, 0))
        total += wire_sent.get(pid, 0)
    return SuperstepCost(label=label, h_bytes=h_bytes, wire_bytes=wire,
                         total_wire_bytes=total, rounds=rounds,
                         n_msgs=len(msgs), method=method)


# --------------------------------------------------------------------------
# Phase 3: data exchange primitives (traced)
# --------------------------------------------------------------------------

def _gather_payload(val: jnp.ndarray, offs: np.ndarray, size: int,
                    myid: jnp.ndarray, static_off: Optional[int]) -> jnp.ndarray:
    """Extract ``size`` elements starting at a per-PID offset."""
    if static_off is not None:
        return lax.dynamic_slice(val, (static_off,), (size,)) \
            if static_off + size <= val.shape[0] else \
            jnp.take(val, static_off + jnp.arange(size), mode="fill",
                     fill_value=0)
    off = jnp.asarray(offs)[myid]
    if int(np.max(offs)) + size <= val.shape[0]:
        return lax.dynamic_slice(val, (off,), (size,))
    idx = off + jnp.arange(size)
    return jnp.take(val, idx, mode="fill", fill_value=0)


def _scatter_payload(val: jnp.ndarray, payload: jnp.ndarray,
                     offs: np.ndarray, sizes: np.ndarray, mask: np.ndarray,
                     myid: jnp.ndarray) -> jnp.ndarray:
    """Blend ``payload`` into ``val`` at a per-PID offset with per-PID
    length; PIDs with ``mask == 0`` keep their data untouched."""
    size = payload.shape[0]
    off = jnp.asarray(offs)[myid]
    nrecv = jnp.asarray(sizes)[myid]
    active = jnp.asarray(mask)[myid]
    keep = (jnp.arange(size) < nrecv) & (active > 0)
    if int(np.max(offs)) + size <= val.shape[0]:
        cur = lax.dynamic_slice(val, (off,), (size,))
        new = jnp.where(keep, payload, cur)
        return lax.dynamic_update_slice(val, new, (off,))
    idx = off + jnp.arange(size)
    return val.at[idx].set(jnp.where(keep, payload, val.at[idx].get(
        mode="fill", fill_value=0)), mode="drop")


def _maybe_compress(payload: jnp.ndarray, attrs: SyncAttributes):
    """int8 symmetric quantisation of a float payload (lower effective g)."""
    spec = attrs.compress
    if spec is None or not jnp.issubdtype(payload.dtype, jnp.floating):
        return payload, None
    if spec.bits != 8:
        raise LPFFatalError(f"unsupported compression bits={spec.bits}")
    scale = jnp.max(jnp.abs(payload)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(payload / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _maybe_decompress(payload, scale, dtype):
    if scale is None:
        return payload
    return (payload.astype(jnp.float32) * scale).astype(dtype)


def _ppermute(x, axes: AxisNames, perm: List[Tuple[int, int]]):
    return lax.ppermute(x, axes if len(axes) > 1 else axes[0], perm)


# --------------------------------------------------------------------------
# Method: direct
# --------------------------------------------------------------------------

def _execute_direct(registry: SlotRegistry, msgs: List[Msg], p: int,
                    axes: AxisNames, myid, attrs: SyncAttributes,
                    wire_sent: Dict[int, int], wire_recv: Dict[int, int]
                    ) -> int:
    """Direct method: rounds of partial permutations.  Returns #rounds.

    Messages are grouped by (src_slot, dst_slot) pair — each round draws
    from one source slot and writes one destination slot — and all
    payloads are extracted from the *pre-sync* slot values before any
    write is applied (LPF reads observe the pre-superstep state)."""
    groups: Dict[Tuple[int, int], List[Msg]] = {}
    for m in msgs:
        groups.setdefault((m.src_slot.sid, m.dst_slot.sid), []).append(m)
    rounds: List[Round] = []
    for key in sorted(groups):
        rounds.extend(_colour_rounds(groups[key], attrs.no_conflict))

    # ---- extraction (reads observe pre-sync values) ----
    extracted: List[jnp.ndarray] = []
    scales: List[Optional[jnp.ndarray]] = []
    for rd in rounds:
        src_slot = rd.msgs[0].src_slot
        offs = np.zeros(p, dtype=np.int32)
        for m in rd.msgs:
            offs[m.src] = m.src_off
        static_off = rd.msgs[0].src_off if _is_uniform_round(rd.msgs, p) else None
        payload = _gather_payload(registry.value(src_slot), offs, rd.size,
                                  myid, static_off)
        payload, scale = _maybe_compress(payload, attrs)
        extracted.append(payload)
        scales.append(scale)

    # ---- exchange + ordered writes ----
    n_collectives = 0
    for rd, payload, scale in zip(rounds, extracted, scales):
        remote = [(m.src, m.dst) for m in rd.msgs if m.src != m.dst]
        dst_slot = rd.msgs[0].dst_slot
        itemsize = jnp.dtype(dst_slot.dtype).itemsize
        wire_elem = (rd.size // 4 + 1) if scale is not None else rd.size
        if remote:
            arrived = _ppermute(payload, axes, remote)
            if scale is not None:
                arrived_scale = _ppermute(scale, axes, remote)
            n_collectives += 1 if scale is None else 2
            for s, d in remote:
                wire_sent[s] = wire_sent.get(s, 0) + wire_elem * itemsize
                wire_recv[d] = wire_recv.get(d, 0) + wire_elem * itemsize
        else:
            arrived, arrived_scale = payload, scale
        # self-messages bypass the wire (a local memcpy, as in the paper's
        # shared-memory backend)
        selfs = [(m.src, m.dst) for m in rd.msgs if m.src == m.dst]
        if selfs and remote:
            self_mask = np.zeros(p, np.int8)
            for s, _ in selfs:
                self_mask[s] = 1
            pick = jnp.asarray(self_mask)[myid] > 0
            arrived = jnp.where(pick, payload, arrived)
            if scale is not None:
                arrived_scale = jnp.where(pick, scale, arrived_scale)
        arrived = _maybe_decompress(
            arrived, arrived_scale if scale is not None else None,
            dst_slot.dtype)

        offs = np.zeros(p, dtype=np.int32)
        sizes = np.zeros(p, dtype=np.int32)
        mask = np.zeros(p, dtype=np.int8)
        for m in rd.msgs:
            offs[m.dst] = m.dst_off
            sizes[m.dst] = m.size
            mask[m.dst] = 1
        registry.set_value(dst_slot, _scatter_payload(
            registry.value(dst_slot), arrived, offs, sizes, mask, myid))
    return max(n_collectives, 1)


# --------------------------------------------------------------------------
# Method: bruck (relative-destination coordinates; static row sets)
# --------------------------------------------------------------------------

def _execute_bruck(registry: SlotRegistry, msgs: List[Msg], p: int,
                   axes: AxisNames, myid, attrs: SyncAttributes,
                   wire_sent: Dict[int, int], wire_recv: Dict[int, int]
                   ) -> int:
    """Bruck-style log-latency exchange.

    Row ``r`` of the working matrix holds the payload this process
    currently carries whose *original* relative distance (dst - origin
    mod p) is ``r``.  All blocks of equal original distance move through
    identical hop sequences, so row sets per round are static.  Supports
    at most one message per (src, dst) pair; sizes padded to the max.
    """
    pairs = {}
    for m in msgs:
        key = (m.src, m.dst)
        if key in pairs:
            raise LPFFatalError("bruck method requires unique (src,dst) pairs; "
                                "use method='direct' for multigraphs")
        pairs[key] = m
    w = max(m.size for m in msgs)
    m0 = msgs[0]
    src_slot, dst_slot = m0.src_slot, m0.dst_slot
    for m in msgs:
        if m.src_slot.sid != src_slot.sid or m.dst_slot.sid != dst_slot.sid:
            raise LPFFatalError("bruck method requires a single slot pair")
    itemsize = jnp.dtype(src_slot.dtype).itemsize

    # tables[src, rel] -> offset/size/mask of the message src -> src+rel
    src_off = np.zeros((p, p), np.int32)
    dst_off = np.zeros((p, p), np.int32)
    sizes = np.zeros((p, p), np.int32)
    mask = np.zeros((p, p), np.int8)
    for (s, d), m in pairs.items():
        rel = (d - s) % p
        src_off[s, rel] = m.src_off
        dst_off[d, rel] = m.dst_off   # indexed by *receiver* pid
        sizes[s, rel] = m.size
        mask[s, rel] = 1
    val = registry.value(src_slot)
    my_off = jnp.asarray(src_off)[myid]                       # [p]
    idx = my_off[:, None] + jnp.arange(w)[None, :]            # [p, w]
    buf = jnp.take(val, idx.reshape(-1), mode="fill",
                   fill_value=0).reshape(p, w)
    nrounds = max(1, math.ceil(math.log2(p))) if p > 1 else 0

    n_collectives = 0
    for k in range(nrounds):
        step = 1 << k
        rows = [r for r in range(1, p) if r & step]
        if not rows:
            continue
        sub = buf[np.asarray(rows)]
        perm = [(i, (i + step) % p) for i in range(p)]
        sub = _ppermute(sub, axes, perm)
        buf = buf.at[np.asarray(rows)].set(sub)
        n_collectives += 1
        vol = len(rows) * w * itemsize
        for pid in range(p):
            wire_sent[pid] = wire_sent.get(pid, 0) + vol
            wire_recv[pid] = wire_recv.get(pid, 0) + vol

    # delivery: row r arrived from origin (me - r) % p; write at the
    # receiver-side offset table entries.
    out = registry.value(dst_slot)
    my_dst_off = jnp.asarray(dst_off)[myid]                   # [p]
    my_sizes = jnp.asarray(sizes)                             # [p(src), p(rel)]
    origin = (myid - jnp.arange(p)) % p
    my_len = my_sizes[origin, jnp.arange(p)]                  # [p]
    my_mask = jnp.asarray(mask)[origin, jnp.arange(p)]        # [p]
    # apply rows in ascending origin pid order for CRCW determinism
    order = np.arange(p)
    for r in order:
        keep = (jnp.arange(w) < my_len[r]) & (my_mask[r] > 0)
        tgt = my_dst_off[r] + jnp.arange(w)
        cur = out.at[tgt].get(mode="fill",
                              fill_value=0)
        out = out.at[tgt].set(jnp.where(keep, buf[r], cur), mode="drop")
    registry.set_value(dst_slot, out)
    return max(n_collectives, 1)


# --------------------------------------------------------------------------
# Method: valiant two-phase randomised routing
# --------------------------------------------------------------------------

def _valiant_split(msgs: List[Msg], p: int, seed: int, scratch: Slot
                   ) -> Tuple[List[Msg], List[Msg]]:
    """Split messages into two near-balanced phases via seeded hashing."""
    cursor = np.zeros(p, dtype=np.int64)
    phase1: List[Msg] = []
    phase2: List[Msg] = []
    for i, m in enumerate(sorted(msgs, key=lambda m: (m.src, m.dst, m.dst_off))):
        t = (m.src * 2654435761 + m.dst * 40503 + i * 97 + seed) % p
        off = int(cursor[t])
        if off + m.size > scratch.size:
            raise LPFFatalError(
                "valiant scratch overflow; resize_message_queue with a "
                "larger payload capacity")
        cursor[t] += m.size
        phase1.append(Msg(m.src, t, m.src_slot, m.src_off,
                          scratch, off, m.size))
        phase2.append(Msg(t, m.dst, scratch, off,
                          m.dst_slot, m.dst_off, m.size))
    return phase1, phase2


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def execute_sync(registry: SlotRegistry, queue: List[Msg], p: int,
                 axes: AxisNames, myid, attrs: SyncAttributes,
                 label: str, scratch: Optional[Slot] = None) -> SuperstepCost:
    """Run one superstep; mutates registry values; returns its cost record."""
    msgs = list(queue)
    for m in msgs:
        m.validate(p)
    wire_sent: Dict[int, int] = {}
    wire_recv: Dict[int, int] = {}

    if not msgs or p == 0:
        return plan_cost(msgs, max(p, 1), attrs, label, "noop", 0,
                         wire_sent, wire_recv)

    if p == 1:
        # LPF_ROOT / sequential context: puts degenerate to memcpys.
        for m in sorted(msgs, key=lambda m: (m.src, m.dst, m.dst_off)):
            src = registry.value(m.src_slot)
            dst = registry.value(m.dst_slot)
            chunk = lax.dynamic_slice(src, (m.src_off,), (m.size,))
            registry.set_value(m.dst_slot,
                               lax.dynamic_update_slice(dst, chunk,
                                                        (m.dst_off,)))
        return plan_cost(msgs, p, attrs, label, "noop", 0, wire_sent, wire_recv)

    method = attrs.method
    if method == "auto":
        fused = _detect_total_exchange(msgs, p)
        gathered = _detect_allgather(msgs, p)
        if fused is not None:
            method = "fused"
        elif gathered is not None:
            method = "fused_ag"
        else:
            # latency heuristic: many small messages per process -> bruck
            per_src: Dict[int, int] = {}
            for m in msgs:
                per_src[m.src] = per_src.get(m.src, 0) + 1
            max_deg = max(per_src.values())
            uniq = len({(m.src, m.dst) for m in msgs}) == len(msgs)
            one_pair = len({(m.src_slot.sid, m.dst_slot.sid) for m in msgs}) == 1
            sizes = [m.size for m in msgs]
            small = max(sizes) <= 4 * max(1, min(sizes))
            if uniq and one_pair and small and max_deg > 4 * math.ceil(
                    math.log2(p)):
                method = "bruck"
            else:
                method = "direct"

    if method == "fused_ag":
        src_slot, dst_slot, w, src_off = _detect_allgather(msgs, p)
        sval = registry.value(src_slot)
        if (src_off == src_off[0]).all():
            x = lax.dynamic_slice(sval, (int(src_off[0]),), (w,))
        else:
            x = _gather_payload(sval, src_off.astype(np.int32), w, myid, None)
        axis = axes if len(axes) > 1 else axes[0]
        x, scale = _maybe_compress(x, attrs)
        y = lax.all_gather(x, axis, tiled=True)
        if scale is not None:
            scales = lax.all_gather(scale, axis, tiled=False)  # [p]
            y = (y.reshape(p, w).astype(jnp.float32)
                 * scales[:, None]).reshape(p * w).astype(src_slot.dtype)
        dst = registry.value(dst_slot)
        if len(msgs) == p * (p - 1):
            # exclude-self variant: keep own chunk as-is
            own = lax.dynamic_slice(dst, (myid * w,), (w,))
            y = lax.dynamic_update_slice(y, own, (myid * w,))
        registry.set_value(dst_slot,
                           lax.dynamic_update_slice(dst, y, (0,)))
        itemsize = 1 if scale is not None else jnp.dtype(src_slot.dtype).itemsize
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return plan_cost(msgs, p, attrs, label, "fused_ag", 1,
                         wire_sent, wire_recv)

    if method == "fused":
        src_slot, dst_slot, w = _detect_total_exchange(msgs, p)
        x = registry.value(src_slot)[: p * w].reshape(p, w)
        axis = axes if len(axes) > 1 else axes[0]
        scale = None
        if attrs.compress is not None and jnp.issubdtype(
                x.dtype, jnp.floating):
            # per-destination-row scales travel alongside the payload
            scale = jnp.max(jnp.abs(x), axis=1) / 127.0 + 1e-30  # [p]
            x = jnp.clip(jnp.round(x / scale[:, None]),
                         -127, 127).astype(jnp.int8)
        y = lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
        if scale is not None:
            scales = lax.all_to_all(scale, axis, split_axis=0,
                                    concat_axis=0, tiled=False)  # [p]
            y = (y.astype(jnp.float32) * scales[:, None]).astype(
                src_slot.dtype)
        y = y.reshape(p * w)
        dst = registry.value(dst_slot)
        registry.set_value(dst_slot,
                           lax.dynamic_update_slice(dst, y, (0,)))
        itemsize = 1 if scale is not None else jnp.dtype(src_slot.dtype).itemsize
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return plan_cost(msgs, p, attrs, label, "fused", 1,
                         wire_sent, wire_recv)

    if method == "valiant":
        if scratch is None:
            raise LPFFatalError("valiant routing needs a scratch slot; the "
                                "context provisions one via "
                                "resize_message_queue(payload=...)")
        ph1, ph2 = _valiant_split(msgs, p, attrs.valiant_seed, scratch)
        sub = attrs.replace(method="direct")
        r1 = _execute_direct(registry, ph1, p, axes, myid, sub,
                             wire_sent, wire_recv)
        r2 = _execute_direct(registry, ph2, p, axes, myid, sub,
                             wire_sent, wire_recv)
        return plan_cost(msgs, p, attrs, label, "valiant", r1 + r2,
                         wire_sent, wire_recv)

    if method == "bruck":
        rounds = _execute_bruck(registry, msgs, p, axes, myid, attrs,
                                wire_sent, wire_recv)
        return plan_cost(msgs, p, attrs, label, "bruck", rounds,
                         wire_sent, wire_recv)

    rounds = _execute_direct(registry, msgs, p, axes, myid, attrs,
                             wire_sent, wire_recv)
    return plan_cost(msgs, p, attrs, label, "direct", rounds,
                     wire_sent, wire_recv)
