"""The superstep compiler — ``lpf_sync``'s four phases on XLA.

The paper implements ``lpf_sync`` in four phases: (1) barrier + meta-data
exchange, (2) write-conflict resolution, (3) data exchange, (4) barrier.
On TPU/XLA the communication pattern of a BSP superstep is static at trace
time, so phases (1)-(2) run *in the compiler*.  Following pMR and the
plan-once/execute-many design of FFTW-style communication layers, the
compiler is split into three stages:

* **plan** — :func:`plan_sync` analyses the staged message table, resolves
  write conflicts by deterministic arbitration (ascending source PID; the
  last writer — highest PID — wins, a refinement of the paper's
  arbitrary-order CRCW), classifies fast paths, edge-colours the message
  multigraph, and predicts the superstep's :class:`SuperstepCost`.  The
  result is a :class:`SuperstepPlan` — a pure-Python IR with **no JAX
  ops**, so planning is unit-testable in microseconds and reusable across
  traces.
* **cache** — :class:`PlanCache` memoises plans under a canonical
  signature of ``(p, attributes, message table)`` with slot ids renamed to
  first-occurrence indices, so the per-layer gradient syncs and per-stage
  FFT supersteps that repeat the same h-relation (through freshly
  registered slots) hit the cache instead of re-colouring.
* **execute** — :func:`execute_plan` lowers a :class:`SuperstepPlan` to a
  minimal schedule of XLA collectives and appends the (already predicted)
  cost to the ledger.  Phase (4) is implicit in XLA's dataflow.

Three execution methods mirror the paper's Table 1:

* ``direct``  — greedy edge-colouring of the message multigraph into
  partial permutations; one ``ppermute`` per round (m rounds for an
  m-relation), plus fast paths for uniform permutations (1 static-slice
  ``ppermute``) and canonical total exchanges (1 ``all_to_all``).
* ``bruck``   — the randomised-Bruck flavour: ceil(log2 p) rounds in
  *relative-destination coordinates* (statically indexable rows), paying
  O(log p) x volume for O(log p) latency.
* ``valiant`` — two-phase randomised routing for skewed h-relations:
  messages bounce via a seeded-hash intermediate, each phase a ``direct``
  sync of a near-balanced relation.

On top of these, ``auto`` planning recognises canonical patterns and
lowers each onto the single native collective XLA offers for it (pMR's
transport selection), instead of generic permutation rounds:

* ``fused``         — total exchange           -> 1 ``lax.all_to_all``
* ``fused_ag``      — all-gather               -> 1 ``lax.all_gather``
* ``fused_rs``      — reduce-scatter (needs ``attrs.reduce_op``)
                      -> 1 ``lax.psum_scatter`` (sum) or masked
                      ``all_to_all`` + local combine (max/min)
* ``fused_scatter`` — root scatter             -> 1 masked ``all_to_all``
* ``fused_gather``  — gather to root           -> 1 masked ``all_gather``

``attrs.reduce_op`` turns a superstep into an *accumulating-put*
superstep: overlapping destination writes combine elementwise
(sum/max/min) instead of CRCW-arbitrating, which is what makes the
reduce-scatter relation expressible as a message table at all.

Every sync appends a :class:`SuperstepCost` to the context ledger so model
compliance can be audited against the compiled HLO; the executed ledger
entry is by construction identical to the plan's prediction.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from .attrs import SyncAttributes
from .cost import SuperstepCost, overlap_cost
from .errors import LPFFatalError
from .memslot import Slot, SlotRegistry

__all__ = [
    "Msg", "RoundPlan", "SuperstepPlan", "PlanCache", "CacheStats",
    "plan_sync", "plan_signature", "begin_plan", "execute_plan",
    "execute_overlapped", "execute_schedule", "execute_sync", "plan_cost",
    "conflict_free", "find_conflict", "global_plan_cache",
    "OVERLAPPABLE_METHODS", "ValueStore",
]

AxisNames = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Msg:
    """One staged one-sided transfer (a ``lpf_put``; ``lpf_get`` is staged
    as a put from the remote side — the table is globally known)."""

    src: int
    dst: int
    src_slot: Slot
    src_off: int
    dst_slot: Slot
    dst_off: int
    size: int
    #: which call staged this: "put" (src is the caller's own memory, may
    #: be local-registered), "get" (dst is the caller's own), or "table"
    #: (fully general: both ends remotely referred -> both global)
    origin: str = "table"

    def validate(self, p: int) -> None:
        if not (0 <= self.src < p and 0 <= self.dst < p):
            raise LPFFatalError(f"pid out of range in {self}")
        if self.size < 0:
            raise LPFFatalError(f"negative size in {self}")
        if self.src_off < 0 or self.src_off + self.size > self.src_slot.size:
            raise LPFFatalError(f"source range OOB in {self}")
        if self.dst_off < 0 or self.dst_off + self.size > self.dst_slot.size:
            raise LPFFatalError(f"destination range OOB in {self}")
        if self.src_slot.dtype != self.dst_slot.dtype:
            raise LPFFatalError(f"dtype mismatch in {self}")
        if self.src != self.dst:
            # the remotely-referred side must be collectively registered
            # (paper S2.1); the caller's own side may be register_local
            need_global = {"put": (self.dst_slot,),
                           "get": (self.src_slot,),
                           "table": (self.src_slot, self.dst_slot)}
            for slot in need_global[self.origin]:
                if slot.kind != "global":
                    raise LPFFatalError(
                        f"remotely-referred slot {slot} must be "
                        f"register_global ({self.origin} in {self})")


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def _is_floating(dtype) -> bool:
    return np.issubdtype(np.dtype(dtype), np.floating)


#: elementwise combine functions for accumulating-put supersteps
_REDUCE_FNS = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


# ==========================================================================
# Stage 1: PLAN — pure Python, no JAX ops
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One partial permutation of the ``direct`` method.

    ``msg_idx`` indexes into the message list the plan was built from (the
    superstep queue, or a Valiant phase list); per-PID offset tables are
    rebuilt from those messages at lowering time — only the *decisions*
    (membership, order, padding, fast-path) are cached."""

    msg_idx: Tuple[int, ...]
    size: int                        # padded payload (elements)
    static_src_off: Optional[int]    # uniform-round fast path, else None


@dataclasses.dataclass(frozen=True)
class SuperstepPlan:
    """The planned superstep: everything ``lpf_sync`` decides at trace
    time, decoupled from slot identities and traced values.

    A plan built for one message table is valid for any table with the
    same :func:`plan_signature` — same ``p``, attributes, and per-message
    ``(src, dst, slot shape/dtype/kind pattern, offsets, size)`` with slot
    ids renamed by first occurrence."""

    #: noop | seq | direct | bruck | valiant | fused | fused_ag |
    #: fused_rs | fused_scatter | fused_gather
    method: str
    p: int
    n_msgs: int
    cost: SuperstepCost                                   # label == ""
    rounds: Tuple[RoundPlan, ...] = ()                    # direct
    seq_order: Tuple[int, ...] = ()                       # p == 1 memcpys
    fused_w: int = 0                                      # all fused methods
    ag_src_off: Tuple[int, ...] = ()                      # fused_ag, per pid
    ag_exclude_self: bool = False
    reduce_op: Optional[str] = None                       # accumulate mode
    rs_dst_off: Tuple[int, ...] = ()                      # fused_rs, per dst
    fused_root: int = -1                                  # scatter / gather
    sc_dst_off: Tuple[int, ...] = ()                      # fused_scatter
    sc_mask: Tuple[int, ...] = ()                         # fused_scatter
    g_src_off: Tuple[int, ...] = ()                       # fused_gather
    g_has_self: bool = False                              # fused_gather
    bruck_w: int = 0
    bruck_steps: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()  # (step, rows)
    valiant_order: Tuple[int, ...] = ()                   # sorted msg indices
    valiant_via: Tuple[int, ...] = ()                     # intermediate pid
    valiant_off: Tuple[int, ...] = ()                     # scratch offset
    valiant_phase1: Tuple[RoundPlan, ...] = ()
    valiant_phase2: Tuple[RoundPlan, ...] = ()

    def cost_with_label(self, label: str) -> SuperstepCost:
        return dataclasses.replace(self.cost, label=label)


def _conflicts(a: Msg, b: Msg) -> bool:
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and a.dst_off < b.dst_off + b.size
            and b.dst_off < a.dst_off + a.size)


def conflict_free(msgs: Sequence[Msg]) -> bool:
    """No two messages of the table write overlapping destination ranges.

    A conflict-free table's final state is independent of write
    arbitration order, which is the precondition for rewriting its
    execution *method*: ``direct`` arbitrates by ascending source pid
    while ``valiant`` phase 2 applies writes in intermediate-pid order,
    so the optimizer's Valiant-aware attr rewrite is only admissible on
    tables this predicate accepts (``reduce_op`` tables commute by
    construction but take no method rewrite — valiant cannot combine)."""
    return find_conflict(msgs) is None


def find_conflict(msgs: Sequence[Msg]) -> Optional[Tuple[Msg, Msg]]:
    """First pair of messages writing overlapping destination ranges,
    or ``None`` for a conflict-free table.  The witness pair is what the
    linter reports when a user-asserted ``no_conflict`` table races."""
    msgs = list(msgs)
    for i, a in enumerate(msgs):
        for b in msgs[i + 1:]:
            if _conflicts(a, b):
                return (a, b)
    return None


def _colour_rounds(idxs: Sequence[int], msgs: Sequence[Msg],
                   no_conflict: bool) -> List[List[int]]:
    """Greedy edge colouring preserving CRCW arbitration order.

    Messages are placed in ascending (src, dst, dst_off) order; a message
    that overlaps an earlier message's destination region must land in a
    strictly later round so that the higher-PID write is applied last.
    Returns rounds as lists of indices into ``msgs``.
    """
    order = sorted(idxs, key=lambda i: (msgs[i].src, msgs[i].dst,
                                        msgs[i].dst_off))
    rounds: List[List[int]] = []
    send_busy: List[set] = []
    recv_busy: List[set] = []
    placed: List[Tuple[int, int]] = []
    for i in order:
        m = msgs[i]
        floor = 0
        if not no_conflict:
            for prev, r in placed:
                if _conflicts(msgs[prev], m):
                    floor = max(floor, r + 1)
        r = floor
        while True:
            while r >= len(rounds):
                rounds.append([])
                send_busy.append(set())
                recv_busy.append(set())
            if m.src not in send_busy[r] and m.dst not in recv_busy[r]:
                rounds[r].append(i)
                send_busy[r].add(m.src)
                recv_busy[r].add(m.dst)
                placed.append((i, r))
                break
            r += 1
    return rounds


def _is_uniform(idxs: Sequence[int], msgs: Sequence[Msg]) -> bool:
    """True if all messages share offsets and size (static-slice fast path)."""
    m0 = msgs[idxs[0]]
    return all(msgs[i].src_off == m0.src_off and msgs[i].dst_off == m0.dst_off
               and msgs[i].size == m0.size for i in idxs)


def _detect_total_exchange(msgs: Sequence[Msg], p: int
                           ) -> Optional[Tuple[Slot, Slot, int]]:
    """Detect the canonical total exchange: every (s, d) pair sends ``w``
    elements with src_off = d*w and dst_off = s*w -> one ``all_to_all``."""
    if len(msgs) != p * p or p == 1:
        return None
    m0 = msgs[0]
    w = m0.size
    if w == 0:
        return None
    seen = set()
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w or m.src_off != m.dst * w
                or m.dst_off != m.src * w or (m.src, m.dst) in seen):
            return None
        seen.add((m.src, m.dst))
    if m0.src_slot.size < p * w or m0.dst_slot.size < p * w:
        return None
    return (m0.src_slot, m0.dst_slot, w)


def _detect_allgather(msgs: Sequence[Msg], p: int
                      ) -> Optional[Tuple[Slot, Slot, int, np.ndarray]]:
    """Detect the canonical all-gather: every src sends the *same* ``w``
    elements (from a per-src constant offset) to every other process at
    dst_off = src*w -> one ``lax.all_gather``."""
    if p == 1 or len(msgs) not in (p * p, p * (p - 1)):
        return None
    m0 = msgs[0]
    w = m0.size
    if w == 0:
        return None
    seen = set()
    src_off = np.full(p, -1, np.int64)
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w
                or m.dst_off != m.src * w or (m.src, m.dst) in seen):
            return None
        if src_off[m.src] == -1:
            src_off[m.src] = m.src_off
        elif src_off[m.src] != m.src_off:
            return None
        seen.add((m.src, m.dst))
    if m0.src_slot.size < w or m0.dst_slot.size < p * w:
        return None
    if len(msgs) == p * (p - 1) and any(s == d for s, d in seen):
        return None
    src_off[src_off == -1] = 0
    return (m0.src_slot, m0.dst_slot, w, src_off)


def _detect_reduce_scatter(msgs: Sequence[Msg], p: int,
                           attrs: SyncAttributes
                           ) -> Optional[Tuple[Slot, Slot, int, np.ndarray]]:
    """Detect the canonical reduce-scatter: every (s, d) pair sends ``w``
    elements with src_off = d*w to a per-destination constant offset,
    all p contributions combining under ``attrs.reduce_op`` -> one
    ``lax.psum_scatter`` (sum) or ``all_to_all`` + local combine."""
    if attrs.reduce_op is None or attrs.compress is not None:
        return None
    if p == 1 or len(msgs) != p * p:
        return None
    m0 = msgs[0]
    w = m0.size
    if w == 0:
        return None
    seen = set()
    dst_off = np.full(p, -1, np.int64)
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w or m.src_off != m.dst * w
                or (m.src, m.dst) in seen):
            return None
        if dst_off[m.dst] == -1:
            dst_off[m.dst] = m.dst_off
        elif dst_off[m.dst] != m.dst_off:
            return None
        seen.add((m.src, m.dst))
    if m0.src_slot.size < p * w:
        return None
    return (m0.src_slot, m0.dst_slot, w, dst_off)


def _detect_scatter(msgs: Sequence[Msg], p: int
                    ) -> Optional[Tuple[Slot, Slot, int, int,
                                        np.ndarray, np.ndarray]]:
    """Detect the canonical root scatter: one source sends chunk d
    (src_off = d*w) to every process d at a per-destination offset ->
    one masked ``all_to_all`` (1 round instead of p-1 ppermutes; equal
    h, so the fused schedule strictly dominates on latency)."""
    if p == 1 or len(msgs) not in (p, p - 1):
        return None
    m0 = msgs[0]
    root = m0.src
    w = m0.size
    if w == 0:
        return None
    seen_dst = set()
    dst_off = np.zeros(p, np.int64)
    mask = np.zeros(p, np.int8)
    for m in msgs:
        if (m.src != root or m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w or m.src_off != m.dst * w
                or m.dst in seen_dst):
            return None
        seen_dst.add(m.dst)
        dst_off[m.dst] = m.dst_off
        mask[m.dst] = 1
    if len(msgs) == p - 1 and root in seen_dst:
        return None   # the p-1 variant is exactly "everyone but root"
    if m0.src_slot.size < p * w:
        return None
    return (m0.src_slot, m0.dst_slot, w, root, dst_off, mask)


def _detect_gather(msgs: Sequence[Msg], p: int
                   ) -> Optional[Tuple[Slot, Slot, int, int,
                                       np.ndarray, bool]]:
    """Detect the canonical gather to root: every process sends ``w``
    elements (from a per-source constant offset) to one root at
    dst_off = src*w -> one masked ``lax.all_gather``."""
    if p == 1 or len(msgs) not in (p, p - 1):
        return None
    m0 = msgs[0]
    root = m0.dst
    w = m0.size
    if w == 0:
        return None
    seen_src = set()
    src_off = np.zeros(p, np.int64)
    for m in msgs:
        if (m.dst != root or m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid
                or m.size != w or m.dst_off != m.src * w
                or m.src in seen_src):
            return None
        seen_src.add(m.src)
        src_off[m.src] = m.src_off
    has_self = root in seen_src
    if len(msgs) == p - 1 and has_self:
        return None   # the p-1 variant is exactly "everyone but root"
    if m0.dst_slot.size < p * w or m0.src_slot.size < w:
        return None
    return (m0.src_slot, m0.dst_slot, w, root, src_off, has_self)


def plan_cost(msgs: Sequence[Msg], p: int, attrs: SyncAttributes,
              label: str, method: str, rounds: int,
              wire_sent: Dict[int, int], wire_recv: Dict[int, int]) -> SuperstepCost:
    sent = np.zeros(p, dtype=np.int64)
    recv = np.zeros(p, dtype=np.int64)
    for m in msgs:
        if m.src != m.dst:
            nbytes = m.size * _itemsize(m.src_slot.dtype)
            sent[m.src] += nbytes
            recv[m.dst] += nbytes
    h_bytes = int(max(np.max(sent, initial=0), np.max(recv, initial=0)))
    wire = 0
    total = 0
    for pid in range(p):
        wire = max(wire, wire_sent.get(pid, 0), wire_recv.get(pid, 0))
        total += wire_sent.get(pid, 0)
    return SuperstepCost(label=label, h_bytes=h_bytes, wire_bytes=wire,
                         total_wire_bytes=total, rounds=rounds,
                         n_msgs=len(msgs), method=method)


def _round_compressed(rd: RoundPlan, msgs: Sequence[Msg],
                      attrs: SyncAttributes) -> bool:
    """Whether int8 wire compression applies to this round's payload."""
    return (attrs.compress is not None
            and _is_floating(msgs[rd.msg_idx[0]].src_slot.dtype))


def _plan_direct(msgs: Sequence[Msg], attrs: SyncAttributes,
                 wire_sent: Dict[int, int], wire_recv: Dict[int, int]
                 ) -> Tuple[Tuple[RoundPlan, ...], int]:
    """Group by slot pair, colour each group, and account wire traffic.

    Groups are ordered by first occurrence in the message list (never by
    raw slot id) so that equivalent tables — same pattern through freshly
    registered slots — produce identical plans and can share one cache
    entry."""
    groups: "collections.OrderedDict[Tuple[int, int], List[int]]" = \
        collections.OrderedDict()
    for i, m in enumerate(msgs):
        groups.setdefault((m.src_slot.sid, m.dst_slot.sid), []).append(i)
    rounds: List[RoundPlan] = []
    # combining writes are order-free (sum/max/min commute), so reduce
    # supersteps pack rounds as tightly as a no-conflict assertion
    relaxed = attrs.no_conflict or attrs.reduce_op is not None
    for idxs in groups.values():
        for round_idxs in _colour_rounds(idxs, msgs, relaxed):
            size = max((msgs[i].size for i in round_idxs), default=0)
            static = msgs[round_idxs[0]].src_off \
                if round_idxs and _is_uniform(round_idxs, msgs) else None
            rounds.append(RoundPlan(tuple(round_idxs), size, static))

    n_collectives = 0
    for rd in rounds:
        remote = [(msgs[i].src, msgs[i].dst) for i in rd.msg_idx
                  if msgs[i].src != msgs[i].dst]
        if not remote:
            continue
        compressed = _round_compressed(rd, msgs, attrs)
        itemsize = _itemsize(msgs[rd.msg_idx[0]].dst_slot.dtype)
        wire_elem = (rd.size // 4 + 1) if compressed else rd.size
        n_collectives += 2 if compressed else 1
        for s, d in remote:
            wire_sent[s] = wire_sent.get(s, 0) + wire_elem * itemsize
            wire_recv[d] = wire_recv.get(d, 0) + wire_elem * itemsize
    return tuple(rounds), max(n_collectives, 1)


def _plan_bruck(msgs: Sequence[Msg], p: int, attrs: SyncAttributes,
                wire_sent: Dict[int, int], wire_recv: Dict[int, int]
                ) -> Tuple[int, Tuple[Tuple[int, Tuple[int, ...]], ...], int]:
    pairs = set()
    for m in msgs:
        key = (m.src, m.dst)
        if key in pairs:
            raise LPFFatalError("bruck method requires unique (src,dst) pairs; "
                                "use method='direct' for multigraphs")
        pairs.add(key)
    m0 = msgs[0]
    for m in msgs:
        if (m.src_slot.sid != m0.src_slot.sid
                or m.dst_slot.sid != m0.dst_slot.sid):
            raise LPFFatalError("bruck method requires a single slot pair")
    w = max(m.size for m in msgs)
    itemsize = _itemsize(m0.src_slot.dtype)
    nrounds = max(1, math.ceil(math.log2(p))) if p > 1 else 0
    steps: List[Tuple[int, Tuple[int, ...]]] = []
    n_collectives = 0
    for k in range(nrounds):
        step = 1 << k
        rows = tuple(r for r in range(1, p) if r & step)
        if not rows:
            continue
        steps.append((step, rows))
        n_collectives += 1
        vol = len(rows) * w * itemsize
        for pid in range(p):
            wire_sent[pid] = wire_sent.get(pid, 0) + vol
            wire_recv[pid] = wire_recv.get(pid, 0) + vol
    return w, tuple(steps), max(n_collectives, 1)


def _plan_valiant_split(msgs: Sequence[Msg], p: int, seed: int,
                        scratch: Slot
                        ) -> Tuple[List[int], List[int], List[int]]:
    """Assign each message a seeded-hash intermediate and scratch offset."""
    cursor = np.zeros(p, dtype=np.int64)
    order = sorted(range(len(msgs)),
                   key=lambda i: (msgs[i].src, msgs[i].dst, msgs[i].dst_off))
    via: List[int] = []
    offs: List[int] = []
    for rank, i in enumerate(order):
        m = msgs[i]
        t = (m.src * 2654435761 + m.dst * 40503 + rank * 97 + seed) % p
        off = int(cursor[t])
        if off + m.size > scratch.size:
            raise LPFFatalError(
                "valiant scratch overflow; resize_message_queue with a "
                "larger payload capacity")
        cursor[t] += m.size
        via.append(t)
        offs.append(off)
    return order, via, offs


def _valiant_phase_msgs(msgs: Sequence[Msg], order: Sequence[int],
                        via: Sequence[int], offs: Sequence[int],
                        scratch: Slot) -> Tuple[List[Msg], List[Msg]]:
    phase1 = [Msg(msgs[i].src, t, msgs[i].src_slot, msgs[i].src_off,
                  scratch, off, msgs[i].size)
              for i, t, off in zip(order, via, offs)]
    phase2 = [Msg(t, msgs[i].dst, scratch, off,
                  msgs[i].dst_slot, msgs[i].dst_off, msgs[i].size)
              for i, t, off in zip(order, via, offs)]
    return phase1, phase2


def plan_sync(msgs: Sequence[Msg], p: int, attrs: SyncAttributes,
              scratch: Optional[Slot] = None) -> SuperstepPlan:
    """Phases (1)-(2): validate, arbitrate, classify, colour, and cost one
    superstep.  Pure Python on static metadata — no JAX ops, no traced
    values — so it can run (and be property-tested) without any mesh."""
    msgs = list(msgs)
    for m in msgs:
        m.validate(p)
    if attrs.reduce_op is not None:
        if attrs.reduce_op not in _REDUCE_FNS:
            raise LPFFatalError(
                f"unknown reduce_op {attrs.reduce_op!r}; expected one of "
                f"{sorted(_REDUCE_FNS)}")
        if attrs.method in ("bruck", "valiant"):
            raise LPFFatalError(
                "reduce_op supersteps support method 'auto' or 'direct' "
                f"only, not {attrs.method!r}")
    wire_sent: Dict[int, int] = {}
    wire_recv: Dict[int, int] = {}

    if not msgs or p == 0:
        return SuperstepPlan(
            method="noop", p=max(p, 1), n_msgs=len(msgs),
            cost=plan_cost(msgs, max(p, 1), attrs, "", "noop", 0,
                           wire_sent, wire_recv))

    if p == 1:
        # LPF_ROOT / sequential context: puts degenerate to memcpys.
        order = tuple(sorted(range(len(msgs)),
                             key=lambda i: (msgs[i].src, msgs[i].dst,
                                            msgs[i].dst_off)))
        return SuperstepPlan(
            method="seq", p=p, n_msgs=len(msgs), seq_order=order,
            reduce_op=attrs.reduce_op,
            cost=plan_cost(msgs, p, attrs, "", "noop", 0,
                           wire_sent, wire_recv))

    method = attrs.method
    det_rs = det_te = det_ag = det_sc = det_ga = None
    if method == "auto":
        if (det_rs := _detect_reduce_scatter(msgs, p, attrs)) is not None:
            method = "fused_rs"
        elif (det_te := _detect_total_exchange(msgs, p)) is not None:
            method = "fused"
        elif (det_ag := _detect_allgather(msgs, p)) is not None:
            method = "fused_ag"
        elif attrs.compress is None and \
                (det_sc := _detect_scatter(msgs, p)) is not None:
            method = "fused_scatter"
        elif attrs.compress is None and \
                (det_ga := _detect_gather(msgs, p)) is not None:
            method = "fused_gather"
        elif attrs.reduce_op is not None:
            method = "direct"    # bruck cannot combine conflicting writes
        else:
            # latency heuristic: many small messages per process -> bruck
            per_src: Dict[int, int] = {}
            for m in msgs:
                per_src[m.src] = per_src.get(m.src, 0) + 1
            max_deg = max(per_src.values())
            uniq = len({(m.src, m.dst) for m in msgs}) == len(msgs)
            one_pair = len({(m.src_slot.sid, m.dst_slot.sid)
                            for m in msgs}) == 1
            sizes = [m.size for m in msgs]
            small = max(sizes) <= 4 * max(1, min(sizes))
            if uniq and one_pair and small and max_deg > 4 * math.ceil(
                    math.log2(p)):
                method = "bruck"
            else:
                method = "direct"

    if method == "fused_rs":
        src_slot, dst_slot, w, rs_off = det_rs
        itemsize = _itemsize(src_slot.dtype)
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return SuperstepPlan(
            method="fused_rs", p=p, n_msgs=len(msgs), fused_w=w,
            reduce_op=attrs.reduce_op,
            rs_dst_off=tuple(int(o) for o in rs_off),
            cost=plan_cost(msgs, p, attrs, "", "fused_rs", 1,
                           wire_sent, wire_recv))

    if method == "fused_scatter":
        src_slot, dst_slot, w, root, sc_off, sc_mask = det_sc
        itemsize = _itemsize(src_slot.dtype)
        # the all_to_all schedule moves (p-1)*w per process — same h as
        # the root's send volume, for a single l instead of p-1
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return SuperstepPlan(
            method="fused_scatter", p=p, n_msgs=len(msgs), fused_w=w,
            fused_root=root, reduce_op=attrs.reduce_op,
            sc_dst_off=tuple(int(o) for o in sc_off),
            sc_mask=tuple(int(m_) for m_ in sc_mask),
            cost=plan_cost(msgs, p, attrs, "", "fused_scatter", 1,
                           wire_sent, wire_recv))

    if method == "fused_gather":
        src_slot, dst_slot, w, root, g_off, g_self = det_ga
        itemsize = _itemsize(src_slot.dtype)
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return SuperstepPlan(
            method="fused_gather", p=p, n_msgs=len(msgs), fused_w=w,
            fused_root=root, reduce_op=attrs.reduce_op,
            g_src_off=tuple(int(o) for o in g_off), g_has_self=g_self,
            cost=plan_cost(msgs, p, attrs, "", "fused_gather", 1,
                           wire_sent, wire_recv))

    if method == "fused_ag":
        src_slot, dst_slot, w, src_off = det_ag
        compressed = attrs.compress is not None and _is_floating(
            src_slot.dtype)
        itemsize = 1 if compressed else _itemsize(src_slot.dtype)
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return SuperstepPlan(
            method="fused_ag", p=p, n_msgs=len(msgs), fused_w=w,
            ag_src_off=tuple(int(o) for o in src_off),
            ag_exclude_self=len(msgs) == p * (p - 1),
            cost=plan_cost(msgs, p, attrs, "", "fused_ag", 1,
                           wire_sent, wire_recv))

    if method == "fused":
        src_slot, dst_slot, w = det_te
        compressed = attrs.compress is not None and _is_floating(
            src_slot.dtype)
        itemsize = 1 if compressed else _itemsize(src_slot.dtype)
        for pid in range(p):
            wire_sent[pid] = (p - 1) * w * itemsize
            wire_recv[pid] = (p - 1) * w * itemsize
        return SuperstepPlan(
            method="fused", p=p, n_msgs=len(msgs), fused_w=w,
            cost=plan_cost(msgs, p, attrs, "", "fused", 1,
                           wire_sent, wire_recv))

    if method == "valiant":
        if scratch is None:
            raise LPFFatalError("valiant routing needs a scratch slot; the "
                                "context provisions one via "
                                "resize_message_queue(payload=...)")
        order, via, offs = _plan_valiant_split(msgs, p, attrs.valiant_seed,
                                               scratch)
        ph1, ph2 = _valiant_phase_msgs(msgs, order, via, offs, scratch)
        sub = attrs.replace(method="direct")
        rounds1, r1 = _plan_direct(ph1, sub, wire_sent, wire_recv)
        rounds2, r2 = _plan_direct(ph2, sub, wire_sent, wire_recv)
        return SuperstepPlan(
            method="valiant", p=p, n_msgs=len(msgs),
            valiant_order=tuple(order), valiant_via=tuple(via),
            valiant_off=tuple(offs),
            valiant_phase1=rounds1, valiant_phase2=rounds2,
            cost=plan_cost(msgs, p, attrs, "", "valiant", r1 + r2,
                           wire_sent, wire_recv))

    if method == "bruck":
        w, steps, rounds = _plan_bruck(msgs, p, attrs, wire_sent, wire_recv)
        return SuperstepPlan(
            method="bruck", p=p, n_msgs=len(msgs), bruck_w=w,
            bruck_steps=steps,
            cost=plan_cost(msgs, p, attrs, "", "bruck", rounds,
                           wire_sent, wire_recv))

    rounds_plan, rounds = _plan_direct(msgs, attrs, wire_sent, wire_recv)
    return SuperstepPlan(
        method="direct", p=p, n_msgs=len(msgs), rounds=rounds_plan,
        reduce_op=attrs.reduce_op,
        cost=plan_cost(msgs, p, attrs, "", "direct", rounds,
                       wire_sent, wire_recv))


# ==========================================================================
# Stage 2: CACHE — canonical signatures and memoised plans
# ==========================================================================

def plan_signature(msgs: Sequence[Msg], p: int, attrs: SyncAttributes,
                   scratch: Optional[Slot] = None) -> Hashable:
    """A hashable key identifying every input :func:`plan_sync` reads.

    Slot ids are renamed to first-occurrence indices and described by
    ``(size, dtype, kind)``, so the same h-relation staged through freshly
    registered slots (a collective called in a loop, a per-layer gradient
    sync) maps to the same key.  Message *order* is part of the key: CRCW
    arbitration is order-sensitive, so a permuted table is a different
    plan."""
    canon: Dict[int, int] = {}
    slots: List[Tuple[int, str, str]] = []

    def slot_key(slot: Slot) -> int:
        idx = canon.get(slot.sid)
        if idx is None:
            idx = canon[slot.sid] = len(canon)
            slots.append((slot.size, str(np.dtype(slot.dtype)), slot.kind))
        return idx

    table = tuple((m.src, m.dst, slot_key(m.src_slot), m.src_off,
                   slot_key(m.dst_slot), m.dst_off, m.size, m.origin)
                  for m in msgs)
    if attrs.method == "valiant":
        scratch_sig = (attrs.valiant_seed,
                       None if scratch is None
                       else (scratch.size, str(np.dtype(scratch.dtype))))
    else:
        scratch_sig = None
    return (p, attrs.method, attrs.no_conflict, attrs.reduce_op,
            attrs.compress, scratch_sig, tuple(slots), table)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    #: warm starts served from a persistent store (entry loaded from
    #: disk, re-verified, promoted to memory — no re-plan, no re-search)
    disk_hits: int = 0
    #: in-memory misses that also found no usable entry on disk (only
    #: counted while a persistent store is attached)
    disk_misses: int = 0
    #: on-disk entries rejected — corruption, version skew, signature
    #: mismatch, or failed re-verification — each degraded to a cold miss
    invalidated: int = 0
    #: persistent-store I/O failures (full disk, read-only dir, read
    #: errors) absorbed by the degradation ladder: each cost a retry
    #: loop and at worst the warm start, never the execution.  Past
    #: ``ProgramCache.DISK_STRIKE_LIMIT`` consecutive failures the
    #: cache detaches its store and runs memory-only.
    disk_errors: int = 0
    #: whole-program compilations that failed and fell back to the
    #: dispatched ``execute_schedule`` path (same certified program,
    #: ledger bit-for-bit); the failing signature is quarantined so
    #: replays skip the doomed compile
    compile_fallbacks: int = 0

    @property
    def plans(self) -> int:
        """Planning passes actually run (== misses)."""
        return self.misses

    def reset(self) -> None:
        """Zero the counters in place (the cache contents stay warm) —
        benchmarks and replay tests measure hit/miss deltas without a
        process restart or a cold cache."""
        self.hits = self.misses = self.evictions = 0
        self.disk_hits = self.disk_misses = self.invalidated = 0
        self.disk_errors = self.compile_fallbacks = 0


class PlanCache:
    """LRU memo of :class:`SuperstepPlan` keyed by :func:`plan_signature`.

    Planning is trace-time Python, so a 64-superstep FFT whose stages
    repeat a handful of distinct relations re-plans each relation once and
    replays the cached IR for the other supersteps."""

    def __init__(self, maxsize: int = 1024):
        self.maxsize = maxsize
        self._plans: "collections.OrderedDict[Hashable, SuperstepPlan]" = \
            collections.OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self) -> None:
        self._plans.clear()
        self.stats = CacheStats()

    def get_or_plan(self, msgs: Sequence[Msg], p: int,
                    attrs: SyncAttributes,
                    scratch: Optional[Slot] = None) -> SuperstepPlan:
        key = plan_signature(msgs, p, attrs, scratch)
        plan = self._plans.get(key)
        if plan is not None:
            self.stats.hits += 1
            self._plans.move_to_end(key)
            return plan
        plan = plan_sync(msgs, p, attrs, scratch)
        self.stats.misses += 1
        self._plans[key] = plan
        if len(self._plans) > self.maxsize:
            self._plans.popitem(last=False)
            self.stats.evictions += 1
        return plan


_GLOBAL_PLAN_CACHE = PlanCache()


def global_plan_cache() -> PlanCache:
    """The process-wide plan cache (shared across contexts and traces)."""
    return _GLOBAL_PLAN_CACHE


# ==========================================================================
# Stage 3: EXECUTE — lowering a plan to XLA collectives (traced)
# ==========================================================================

def _gather_payload(val: jnp.ndarray, offs: np.ndarray, size: int,
                    myid: jnp.ndarray, static_off: Optional[int]) -> jnp.ndarray:
    """Extract ``size`` elements starting at a per-PID offset."""
    if static_off is not None:
        return lax.dynamic_slice(val, (static_off,), (size,)) \
            if static_off + size <= val.shape[0] else \
            jnp.take(val, static_off + jnp.arange(size), mode="fill",
                     fill_value=0)
    off = jnp.asarray(offs)[myid]
    if int(np.max(offs)) + size <= val.shape[0]:
        return lax.dynamic_slice(val, (off,), (size,))
    idx = off + jnp.arange(size)
    return jnp.take(val, idx, mode="fill", fill_value=0)


def _scatter_payload(val: jnp.ndarray, payload: jnp.ndarray,
                     offs: np.ndarray, sizes: np.ndarray, mask: np.ndarray,
                     myid: jnp.ndarray) -> jnp.ndarray:
    """Blend ``payload`` into ``val`` at a per-PID offset with per-PID
    length; PIDs with ``mask == 0`` keep their data untouched."""
    size = payload.shape[0]
    off = jnp.asarray(offs)[myid]
    nrecv = jnp.asarray(sizes)[myid]
    active = jnp.asarray(mask)[myid]
    keep = (jnp.arange(size) < nrecv) & (active > 0)
    if int(np.max(offs)) + size <= val.shape[0]:
        cur = lax.dynamic_slice(val, (off,), (size,))
        new = jnp.where(keep, payload, cur)
        return lax.dynamic_update_slice(val, new, (off,))
    idx = off + jnp.arange(size)
    return val.at[idx].set(jnp.where(keep, payload, val.at[idx].get(
        mode="fill", fill_value=0)), mode="drop")


def _scatter_payload_acc(val: jnp.ndarray, written: jnp.ndarray,
                         payload: jnp.ndarray, offs: np.ndarray,
                         sizes: np.ndarray, mask: np.ndarray, myid,
                         op) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Accumulating delivery: masked elements combine via ``op`` with
    writes applied earlier in the same superstep (``written`` tracks
    them); the first write to an element replaces its old value."""
    size = payload.shape[0]
    off = jnp.asarray(offs)[myid]
    nrecv = jnp.asarray(sizes)[myid]
    active = jnp.asarray(mask)[myid]
    keep = (jnp.arange(size) < nrecv) & (active > 0)
    if int(np.max(offs)) + size <= val.shape[0]:
        cur = lax.dynamic_slice(val, (off,), (size,))
        wr = lax.dynamic_slice(written, (off,), (size,))
        new = jnp.where(keep, jnp.where(wr, op(cur, payload), payload), cur)
        val = lax.dynamic_update_slice(val, new, (off,))
        written = lax.dynamic_update_slice(written, wr | keep, (off,))
        return val, written
    idx = off + jnp.arange(size)
    cur = val.at[idx].get(mode="fill", fill_value=0)
    wr = written.at[idx].get(mode="fill", fill_value=False)
    new = jnp.where(keep, jnp.where(wr, op(cur, payload), payload), cur)
    val = val.at[idx].set(new, mode="drop")
    written = written.at[idx].set(wr | keep, mode="drop")
    return val, written


def _maybe_compress(payload: jnp.ndarray, attrs: SyncAttributes):
    """int8 symmetric quantisation of a float payload (lower effective g)."""
    spec = attrs.compress
    if spec is None or not jnp.issubdtype(payload.dtype, jnp.floating):
        return payload, None
    if spec.bits != 8:
        raise LPFFatalError(f"unsupported compression bits={spec.bits}")
    scale = jnp.max(jnp.abs(payload)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(payload / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _maybe_decompress(payload, scale, dtype):
    if scale is None:
        return payload
    return (payload.astype(jnp.float32) * scale).astype(dtype)


def _ppermute(x, axes: AxisNames, perm: List[Tuple[int, int]]):
    return lax.ppermute(x, axes if len(axes) > 1 else axes[0], perm)


def _direct_begin(registry: SlotRegistry, msgs: Sequence[Msg],
                  rounds: Sequence[RoundPlan], p: int, axes: AxisNames,
                  myid, attrs: SyncAttributes,
                  reduce_op: Optional[str] = None) -> Callable[[], None]:
    """Split-phase lowering of planned ``direct`` rounds: the *start*
    phase extracts every payload from the pre-sync slot values (LPF reads
    observe the pre-superstep state) and issues one ``ppermute`` per
    round; the returned *finish* closure applies the ordered deliveries.
    With ``reduce_op``, deliveries that overlap earlier deliveries of
    this superstep combine elementwise instead of overwriting."""
    reduce_fn = _REDUCE_FNS[reduce_op] if reduce_op is not None else None
    # ---- start: extraction (reads observe pre-sync values) ----
    extracted: List[jnp.ndarray] = []
    scales: List[Optional[jnp.ndarray]] = []
    for rd in rounds:
        src_slot = msgs[rd.msg_idx[0]].src_slot
        offs = np.zeros(p, dtype=np.int32)
        for i in rd.msg_idx:
            offs[msgs[i].src] = msgs[i].src_off
        payload = _gather_payload(registry.value(src_slot), offs, rd.size,
                                  myid, rd.static_src_off)
        payload, scale = _maybe_compress(payload, attrs)
        extracted.append(payload)
        scales.append(scale)

    # ---- start: the exchanges (no slot writes yet) ----
    deliveries: List[Tuple[Slot, jnp.ndarray, np.ndarray, np.ndarray,
                           np.ndarray]] = []
    for rd, payload, scale in zip(rounds, extracted, scales):
        rd_msgs = [msgs[i] for i in rd.msg_idx]
        remote = [(m.src, m.dst) for m in rd_msgs if m.src != m.dst]
        dst_slot = rd_msgs[0].dst_slot
        if remote:
            arrived = _ppermute(payload, axes, remote)
            if scale is not None:
                arrived_scale = _ppermute(scale, axes, remote)
        else:
            arrived, arrived_scale = payload, scale
        # self-messages bypass the wire (a local memcpy, as in the paper's
        # shared-memory backend)
        selfs = [(m.src, m.dst) for m in rd_msgs if m.src == m.dst]
        if selfs and remote:
            self_mask = np.zeros(p, np.int8)
            for s, _ in selfs:
                self_mask[s] = 1
            pick = jnp.asarray(self_mask)[myid] > 0
            arrived = jnp.where(pick, payload, arrived)
            if scale is not None:
                arrived_scale = jnp.where(pick, scale, arrived_scale)
        arrived = _maybe_decompress(
            arrived, arrived_scale if scale is not None else None,
            dst_slot.dtype)

        offs = np.zeros(p, dtype=np.int32)
        sizes = np.zeros(p, dtype=np.int32)
        mask = np.zeros(p, dtype=np.int8)
        for m in rd_msgs:
            offs[m.dst] = m.dst_off
            sizes[m.dst] = m.size
            mask[m.dst] = 1
        deliveries.append((dst_slot, arrived, offs, sizes, mask))

    def finish() -> None:
        written: Dict[int, jnp.ndarray] = {}   # dst sid -> delivered mask
        for dst_slot, arrived, offs, sizes, mask in deliveries:
            if reduce_fn is None:
                registry.set_value(dst_slot, _scatter_payload(
                    registry.value(dst_slot), arrived, offs, sizes, mask,
                    myid))
            else:
                wr = written.get(dst_slot.sid)
                if wr is None:
                    wr = jnp.zeros(dst_slot.size, jnp.bool_)
                val, wr = _scatter_payload_acc(
                    registry.value(dst_slot), wr, arrived, offs, sizes,
                    mask, myid, reduce_fn)
                written[dst_slot.sid] = wr
                registry.set_value(dst_slot, val)

    return finish


def _execute_direct(registry: SlotRegistry, msgs: Sequence[Msg],
                    rounds: Sequence[RoundPlan], p: int, axes: AxisNames,
                    myid, attrs: SyncAttributes,
                    reduce_op: Optional[str] = None) -> None:
    _direct_begin(registry, msgs, rounds, p, axes, myid, attrs,
                  reduce_op)()


def _bruck_begin(registry: SlotRegistry, msgs: Sequence[Msg],
                 plan: SuperstepPlan, p: int, axes: AxisNames,
                 myid) -> Callable[[], None]:
    """Split-phase lowering of planned Bruck rounds.

    Row ``r`` of the working matrix holds the payload this process
    currently carries whose *original* relative distance (dst - origin
    mod p) is ``r``.  All blocks of equal original distance move through
    identical hop sequences, so row sets per round are static.  The start
    phase runs the log-rounds exchange; the finish closure applies the
    deliveries."""
    w = plan.bruck_w
    m0 = msgs[0]
    src_slot, dst_slot = m0.src_slot, m0.dst_slot

    # tables[src, rel] -> offset/size/mask of the message src -> src+rel
    src_off = np.zeros((p, p), np.int32)
    dst_off = np.zeros((p, p), np.int32)
    sizes = np.zeros((p, p), np.int32)
    mask = np.zeros((p, p), np.int8)
    for m in msgs:
        rel = (m.dst - m.src) % p
        src_off[m.src, rel] = m.src_off
        dst_off[m.dst, rel] = m.dst_off   # indexed by *receiver* pid
        sizes[m.src, rel] = m.size
        mask[m.src, rel] = 1
    val = registry.value(src_slot)
    my_off = jnp.asarray(src_off)[myid]                       # [p]
    idx = my_off[:, None] + jnp.arange(w)[None, :]            # [p, w]
    buf = jnp.take(val, idx.reshape(-1), mode="fill",
                   fill_value=0).reshape(p, w)

    for step, rows in plan.bruck_steps:
        sub = buf[np.asarray(rows)]
        perm = [(i, (i + step) % p) for i in range(p)]
        sub = _ppermute(sub, axes, perm)
        buf = buf.at[np.asarray(rows)].set(sub)

    def finish() -> None:
        # delivery: row r arrived from origin (me - r) % p; write at the
        # receiver-side offset table entries.
        out = registry.value(dst_slot)
        my_dst_off = jnp.asarray(dst_off)[myid]               # [p]
        my_sizes = jnp.asarray(sizes)                         # [p(src), p(rel)]
        origin = (myid - jnp.arange(p)) % p
        my_len = my_sizes[origin, jnp.arange(p)]              # [p]
        my_mask = jnp.asarray(mask)[origin, jnp.arange(p)]    # [p]
        # apply rows in ascending origin pid order for CRCW determinism
        for r in range(p):
            keep = (jnp.arange(w) < my_len[r]) & (my_mask[r] > 0)
            tgt = my_dst_off[r] + jnp.arange(w)
            cur = out.at[tgt].get(mode="fill",
                                  fill_value=0)
            out2 = out.at[tgt].set(jnp.where(keep, buf[r], cur),
                                   mode="drop")
            out = out2
        registry.set_value(dst_slot, out)

    return finish


def begin_plan(plan: SuperstepPlan, registry: SlotRegistry,
               msgs: Sequence[Msg], p: int, axes: AxisNames, myid,
               attrs: SyncAttributes,
               scratch: Optional[Slot] = None) -> Callable[[], None]:
    """Phase (3), split-phase: issue the superstep's reads and collectives
    (the *start* half) and return a finish closure that applies its slot
    writes (the *done* half).

    The contract that makes overlap legal: the start half reads source
    payloads from the current slot values and launches the exchanges, but
    performs **no** slot writes; every destination-slot read and write
    happens inside the returned closure.  :func:`execute_overlapped` runs
    all starts of an overlap group before any finish, so every member
    observes the group-entry state — exactly the semantics of independent
    supersteps whose order cannot matter.  (``valiant`` is the exception:
    its phase-1 scratch writes land in the start half, which is why the
    optimizer never overlaps valiant supersteps.)"""
    if plan.method == "noop":
        return lambda: None

    if plan.method == "seq":
        reduce_fn = _REDUCE_FNS[plan.reduce_op] if plan.reduce_op else None
        # extract every payload before any write lands (LPF reads
        # observe the pre-superstep state, exactly as the direct path)
        pre = {m.src_slot.sid: registry.value(m.src_slot)
               for i in plan.seq_order for m in (msgs[i],)}
        chunks = [lax.dynamic_slice(pre[msgs[i].src_slot.sid],
                                    (msgs[i].src_off,), (msgs[i].size,))
                  for i in plan.seq_order]

        def finish_seq() -> None:
            written: Dict[int, np.ndarray] = {}   # static masks: p == 1
            for i, chunk in zip(plan.seq_order, chunks):
                m = msgs[i]
                dst = registry.value(m.dst_slot)
                piece = chunk
                if reduce_fn is not None:
                    wr = written.setdefault(m.dst_slot.sid,
                                            np.zeros(m.dst_slot.size, bool))
                    seg = wr[m.dst_off:m.dst_off + m.size].copy()
                    if seg.any():
                        cur = lax.dynamic_slice(dst, (m.dst_off,),
                                                (m.size,))
                        piece = jnp.where(jnp.asarray(seg),
                                          reduce_fn(cur, piece), piece)
                    wr[m.dst_off:m.dst_off + m.size] = True
                registry.set_value(m.dst_slot,
                                   lax.dynamic_update_slice(dst, piece,
                                                            (m.dst_off,)))

        return finish_seq

    if plan.method == "fused_rs":
        w = plan.fused_w
        m0 = msgs[0]
        src_slot, dst_slot = m0.src_slot, m0.dst_slot
        x = registry.value(src_slot)[: p * w].reshape(p, w)
        axis = axes if len(axes) > 1 else axes[0]
        if plan.reduce_op == "sum":
            y = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=False)
        else:
            # row s of the exchange holds process s's contribution to me
            contrib = lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                     tiled=False)
            y = (jnp.max if plan.reduce_op == "max" else jnp.min)(
                contrib, axis=0)
        off = jnp.asarray(np.asarray(plan.rs_dst_off, np.int32))[myid]

        def finish_rs() -> None:
            dst = registry.value(dst_slot)
            registry.set_value(dst_slot, lax.dynamic_update_slice(
                dst, y.astype(dst_slot.dtype), (off,)))

        return finish_rs

    if plan.method == "fused_scatter":
        w = plan.fused_w
        m0 = msgs[0]
        src_slot, dst_slot = m0.src_slot, m0.dst_slot
        x = registry.value(src_slot)[: p * w].reshape(p, w)
        axis = axes if len(axes) > 1 else axes[0]
        # row r of the result is what process r sent me; only the root's
        # row carries data — the rest is the masked schedule's padding
        y = lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                           tiled=False)
        chunk = y[plan.fused_root]
        off = jnp.asarray(np.asarray(plan.sc_dst_off, np.int32))[myid]
        active = jnp.asarray(np.asarray(plan.sc_mask, np.int8))[myid] > 0

        def finish_sc() -> None:
            dst = registry.value(dst_slot)
            cur = lax.dynamic_slice(dst, (off,), (w,))
            new = jnp.where(active, chunk.astype(dst_slot.dtype), cur)
            registry.set_value(dst_slot,
                               lax.dynamic_update_slice(dst, new, (off,)))

        return finish_sc

    if plan.method == "fused_gather":
        w = plan.fused_w
        m0 = msgs[0]
        src_slot, dst_slot = m0.src_slot, m0.dst_slot
        src_off = np.asarray(plan.g_src_off, np.int32)
        sval = registry.value(src_slot)
        if (src_off == src_off[0]).all():
            x = lax.dynamic_slice(sval, (int(src_off[0]),), (w,))
        else:
            x = _gather_payload(sval, src_off, w, myid, None)
        axis = axes if len(axes) > 1 else axes[0]
        y_gathered = lax.all_gather(x, axis, tiled=True)     # [p * w]

        def finish_ga() -> None:
            y = y_gathered
            dst = registry.value(dst_slot)
            if not plan.g_has_self:
                # root keeps its own chunk: no root -> root msg was staged
                own = lax.dynamic_slice(dst, (plan.fused_root * w,), (w,))
                y = lax.dynamic_update_slice(y, own, (plan.fused_root * w,))
            is_root = myid == plan.fused_root
            new = jnp.where(is_root, y.astype(dst_slot.dtype), dst[: p * w])
            registry.set_value(dst_slot,
                               lax.dynamic_update_slice(dst, new, (0,)))

        return finish_ga

    if plan.method == "fused_ag":
        w = plan.fused_w
        m0 = msgs[0]
        src_slot, dst_slot = m0.src_slot, m0.dst_slot
        src_off = np.asarray(plan.ag_src_off, np.int32)
        sval = registry.value(src_slot)
        if (src_off == src_off[0]).all():
            x = lax.dynamic_slice(sval, (int(src_off[0]),), (w,))
        else:
            x = _gather_payload(sval, src_off, w, myid, None)
        axis = axes if len(axes) > 1 else axes[0]
        x, scale = _maybe_compress(x, attrs)
        y_gathered = lax.all_gather(x, axis, tiled=True)
        if scale is not None:
            scales = lax.all_gather(scale, axis, tiled=False)  # [p]
            y_gathered = (y_gathered.reshape(p, w).astype(jnp.float32)
                          * scales[:, None]).reshape(p * w).astype(
                              src_slot.dtype)

        def finish_ag() -> None:
            y = y_gathered
            dst = registry.value(dst_slot)
            if plan.ag_exclude_self:
                # exclude-self variant: keep own chunk as-is
                own = lax.dynamic_slice(dst, (myid * w,), (w,))
                y = lax.dynamic_update_slice(y, own, (myid * w,))
            registry.set_value(dst_slot,
                               lax.dynamic_update_slice(dst, y, (0,)))

        return finish_ag

    if plan.method == "fused":
        w = plan.fused_w
        m0 = msgs[0]
        src_slot, dst_slot = m0.src_slot, m0.dst_slot
        x = registry.value(src_slot)[: p * w].reshape(p, w)
        axis = axes if len(axes) > 1 else axes[0]
        scale = None
        if attrs.compress is not None and jnp.issubdtype(
                x.dtype, jnp.floating):
            # per-destination-row scales travel alongside the payload
            scale = jnp.max(jnp.abs(x), axis=1) / 127.0 + 1e-30  # [p]
            x = jnp.clip(jnp.round(x / scale[:, None]),
                         -127, 127).astype(jnp.int8)
        y = lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
        if scale is not None:
            scales = lax.all_to_all(scale, axis, split_axis=0,
                                    concat_axis=0, tiled=False)  # [p]
            y = (y.astype(jnp.float32) * scales[:, None]).astype(
                src_slot.dtype)
        y = y.reshape(p * w)

        def finish_fused() -> None:
            dst = registry.value(dst_slot)
            registry.set_value(dst_slot,
                               lax.dynamic_update_slice(dst, y, (0,)))

        return finish_fused

    if plan.method == "valiant":
        if scratch is None:
            raise LPFFatalError("valiant plan lowered without a scratch slot")
        ph1, ph2 = _valiant_phase_msgs(msgs, plan.valiant_order,
                                       plan.valiant_via, plan.valiant_off,
                                       scratch)
        sub = attrs.replace(method="direct")
        # phase 2 reads the scratch slot phase 1 writes — an internal
        # barrier, so phase 1 completes inside the start half (the
        # optimizer's overlap gate excludes valiant for exactly this)
        _execute_direct(registry, ph1, plan.valiant_phase1, p, axes, myid,
                        sub)
        return _direct_begin(registry, ph2, plan.valiant_phase2, p, axes,
                             myid, sub)

    if plan.method == "bruck":
        return _bruck_begin(registry, msgs, plan, p, axes, myid)

    return _direct_begin(registry, msgs, plan.rounds, p, axes, myid, attrs,
                         reduce_op=plan.reduce_op)


#: methods the overlap rewrite may schedule split-phase: their start
#: half performs no slot writes (valiant's phase-1 scratch writes land in
#: start, so two overlapped valiant supersteps would race the scratch)
OVERLAPPABLE_METHODS = frozenset(
    {"noop", "seq", "direct", "bruck", "fused", "fused_ag", "fused_rs",
     "fused_scatter", "fused_gather"})


def execute_plan(plan: SuperstepPlan, registry: SlotRegistry,
                 msgs: Sequence[Msg], p: int, axes: AxisNames, myid,
                 attrs: SyncAttributes, label: str,
                 scratch: Optional[Slot] = None) -> SuperstepCost:
    """Phase (3): lower ``plan`` against the current slot values.

    ``msgs`` must be the table the plan was built from, or any table with
    the same :func:`plan_signature` (the cache guarantees this).  Mutates
    registry values; returns the superstep's ledger entry — identical to
    the plan's predicted cost, with the label attached."""
    begin_plan(plan, registry, msgs, p, axes, myid, attrs,
               scratch=scratch)()
    return plan.cost_with_label(label)


def execute_overlapped(items: Sequence[Tuple[SuperstepPlan, Sequence[Msg],
                                             SyncAttributes, str]],
                       registry: SlotRegistry, p: int, axes: AxisNames,
                       myid, scratch: Optional[Slot] = None
                       ) -> SuperstepCost:
    """Issue one overlap group of independent supersteps split-phase: all
    *start* halves first (every member reads the group-entry slot state
    and launches its collectives back-to-back — the double-buffered
    chain XLA's scheduler can pipeline), then all *done* halves in
    program order.  Returns the group's single ledger entry, by
    construction :func:`repro.core.cost.overlap_cost` of the members'
    planned costs."""
    finishes = [begin_plan(plan, registry, list(msgs), p, axes, myid,
                           attrs, scratch=scratch)
                for plan, msgs, attrs, _ in items]
    for finish in finishes:
        finish()
    return overlap_cost([plan.cost for plan, _, _, _ in items],
                        label="||".join(label for _, _, _, label in items))


class ValueStore:
    """The minimal slot-value surface the executors consume — a
    duck-type of :class:`repro.core.memslot.SlotRegistry` holding only
    ``sid -> value``.  Every ``begin_plan`` lowering touches a registry
    exclusively through ``value``/``set_value``, which is what lets a
    whole optimized program run against this store inside one jitted
    function (``repro.core.program.CompiledProgram``): values enter as
    jit arguments, flow through the schedule as tracers, and leave as
    jit outputs.  No registration or capacity checks — the real registry
    re-validates shapes/dtypes when the results are written back."""

    def __init__(self, values: Dict[int, jnp.ndarray]):
        self._values = dict(values)

    def value(self, slot: Slot) -> jnp.ndarray:
        return self._values[slot.sid]

    def set_value(self, slot: Slot, value: jnp.ndarray) -> None:
        self._values[slot.sid] = value


def execute_schedule(entries, groups, registry, p: int, axes: AxisNames,
                     myid, scratch: Optional[Slot] = None
                     ) -> List[SuperstepCost]:
    """Issue one optimized program's schedule: ``entries`` are the
    materialized ``(msgs, attrs, label, plan)`` supersteps and ``groups``
    the issue partition (singletons via :func:`execute_plan`, overlap
    groups via :func:`execute_overlapped`).  The single executor loop
    shared by step-by-step replay and the compiled whole-program path —
    both produce the returned ledger entries from the same plans, which
    is what makes the fused ledger bit-for-bit identical to the
    dispatched one.  ``registry`` may be a :class:`SlotRegistry` or a
    :class:`ValueStore`."""
    costs: List[SuperstepCost] = []
    for grp in groups:
        if len(grp) == 1:
            msgs, attrs, label, plan = entries[grp[0]]
            costs.append(execute_plan(plan, registry, msgs, p, axes, myid,
                                      attrs, label, scratch=scratch))
        else:
            costs.append(execute_overlapped(
                [(entries[i][3], entries[i][0], entries[i][1],
                  entries[i][2]) for i in grp],
                registry, p, axes, myid, scratch=scratch))
    return costs


# ==========================================================================
# entry point (plan + execute in one call)
# ==========================================================================

def execute_sync(registry: SlotRegistry, queue: Sequence[Msg], p: int,
                 axes: AxisNames, myid, attrs: SyncAttributes,
                 label: str, scratch: Optional[Slot] = None,
                 cache: Optional[PlanCache] = None) -> SuperstepCost:
    """Run one superstep; mutates registry values; returns its cost record.

    With ``cache`` the planning stage is memoised; pass ``None`` to force
    a fresh planning pass (the original single-stage behaviour)."""
    msgs = list(queue)
    if cache is not None:
        plan = cache.get_or_plan(msgs, p, attrs, scratch)
    else:
        plan = plan_sync(msgs, p, attrs, scratch)
    return execute_plan(plan, registry, msgs, p, axes, myid, attrs, label,
                        scratch=scratch)
