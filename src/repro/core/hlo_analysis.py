"""HLO/StableHLO introspection: collective bytes, op census, roofline terms.

This is the measurement half of model compliance.  The cost ledger says
what the LPF layer *promised*; this module reads what the compiler
*scheduled*.  It parses the compiled (post-SPMD-partitioning) HLO text and
sums operand bytes of every collective (`all-gather`, `all-reduce`,
`reduce-scatter`, `all-to-all`, `collective-permute`), giving:

* the compliance check (ledger wire bytes vs scheduled collective bytes),
* the §Roofline collective term (collective_bytes / (chips * link_bw)).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "CollectiveStats",
    "parse_collectives",
    "RooflineTerms",
    "roofline_terms",
    "DTYPE_BYTES",
]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

#: collective op name -> canonical kind
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# e.g. "f32[128,256]{1,0}" or "bf16[8]"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line: "  %name = TYPE[SHAPE] op-name(...)" — we match
# result type + op name.  `op-name.N` suffixes (all-reduce.42) included.
_INSTR_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"([a-z][a-z0-9-]*(?:-start|-done)?)\(")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, incl. tuple types '(f32[..], u32[..])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def report(self) -> str:
        lines = [f"{'collective':<22}{'count':>7}{'bytes':>16}"]
        for k in sorted(self.bytes_by_kind):
            lines.append(f"{k:<22}{self.count_by_kind[k]:>7}"
                         f"{self.bytes_by_kind[k]:>16,}")
        lines.append(f"{'TOTAL':<22}{self.total_count:>7}"
                     f"{self.total_bytes:>16,}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# loop-aware census: multiply while-body costs by their trip counts
# --------------------------------------------------------------------------

_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", re.M)
# the while operand's printed tuple type may itself contain parentheses
# (e.g. "while((s32[], f32[8,16]{1,0}) %tuple.3)"), so match non-greedily
# up to the condition/body attributes rather than to the first ")"
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """{name: block_text} for every computation in the module."""
    blocks = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            if cur_name:
                blocks[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), [line]
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name:
        blocks[cur_name] = "\n".join(cur_lines)
    return blocks


def _trip_count(cond_text: str) -> int:
    """Heuristic: scan-lowered loop conditions compare the induction var
    against an s32 constant — take the largest one (fallback 1)."""
    consts = [int(c) for c in _S32_CONST.findall(cond_text)]
    return max(consts) if consts else 1


#: ops with no HBM data movement of their own
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "after-all", "partition-id", "replica-id"}

# "%name = TYPE op(%a, %b, ...)" with the defined name captured
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"([a-z][a-z0-9-]*(?:-start|-done)?)\(([^)]*)")

#: fusion-aware HBM model: compute ops stream operands + result from HBM;
#: data-movement ops stream their result; everything elementwise is
#: assumed fused into its consumer (what XLA:TPU does).
_STREAM_IN_OUT = {"dot", "convolution"}
_STREAM_OUT = {"gather", "dynamic-slice",
               "copy", "transpose", "sort", "reduce", "reduce-window",
               "fft", "iota", "rng-bit-generator", "pad", "concatenate",
               "select-and-scatter", "broadcast"}
#: in-place updates: XLA aliases the output buffer, so real HBM traffic is
#: the UPDATE operand (operand 1), not the full result
_STREAM_UPDATE = {"dynamic-update-slice", "scatter"}


def _result_bytes(text: str) -> int:
    total = 0
    for m in _INSTR_RE.finditer(text):
        type_str, op = m.groups()
        base = op[:-6] if op.endswith("-start") else op
        if base in _FREE_OPS or op.endswith("-done"):
            continue
        total += _shape_bytes(type_str)
    return total


def _hbm_traffic(text: str) -> int:
    """Fusion-aware HBM traffic of one computation block.

    Matmuls/convs read their operands and write their result (weights +
    activations dominate real transformer traffic); gathers/scatters/
    slices/copies write their result; elementwise chains are assumed
    fused (free).  Collectives are excluded (they have their own term).
    """
    sizes: Dict[str, int] = {}
    entries = []
    for m in _DEF_RE.finditer(text):
        name, type_str, op, args = m.groups()
        b = _shape_bytes(type_str)
        sizes[name] = b
        entries.append((name, b, op, args))
    total = 0
    for name, b, op, args in entries:
        base = op[:-6] if op.endswith("-start") else op
        if base in _STREAM_IN_OUT:
            total += b
            for a in args.split(","):
                a = a.strip().lstrip("%")
                total += sizes.get(a, 0)
        elif base in _STREAM_UPDATE:
            ops = [a.strip().lstrip("%") for a in args.split(",")]
            if len(ops) > 1:
                total += sizes.get(ops[1], 0)   # the written slice
        elif base in _STREAM_OUT:
            total += b
    return total


def loop_aware_census(hlo_text: str):
    """(CollectiveStats, unfused_traffic_bytes) with while-loop
    trip-count multipliers.

    ``parse_collectives`` counts a scan body once; this walks the
    computation graph from ENTRY, multiplying each while body's costs by
    the trip count recovered from its condition — the exact wire volume
    of the scanned program, plus an unfused-result-bytes proxy for HBM
    traffic (x2 for the read side; an upper bound that XLA fusion
    tightens on the real target)."""
    blocks = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in blocks:
        st = parse_collectives(hlo_text)
        return st, float(_hbm_traffic(hlo_text))

    bytes_by_kind: Dict[str, float] = {}
    count_by_kind: Dict[str, float] = {}
    traffic = [0.0]
    visiting = set()

    def walk(name: str, mult: float):
        if name not in blocks or name in visiting:
            return
        visiting.add(name)
        text = blocks[name]
        st = parse_collectives(text)
        for k, b in st.bytes_by_kind.items():
            bytes_by_kind[k] = bytes_by_kind.get(k, 0) + b * mult
            count_by_kind[k] = count_by_kind.get(k, 0) \
                + st.count_by_kind[k] * mult
        traffic[0] += _hbm_traffic(text) * mult
        handled = set()
        for m in _WHILE_RE.finditer(text):
            cond, body = m.groups()
            trip = _trip_count(blocks.get(cond, ""))
            handled.add(body)
            handled.add(cond)
            walk(body, mult * trip)
        for m in _CALLS_RE.finditer(text):
            callee = m.group(1)
            if callee not in handled:
                walk(callee, mult)
        visiting.discard(name)

    walk(entry, 1.0)
    stats = CollectiveStats(
        {k: int(v) for k, v in bytes_by_kind.items()},
        {k: int(v) for k, v in count_by_kind.items()})
    return stats, float(traffic[0])


def parse_collectives_loop_aware(hlo_text: str) -> CollectiveStats:
    return loop_aware_census(hlo_text)[0]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in (compiled) HLO text.

    Result shape is used as the proxy for wire volume (for permute/
    gather/reduce the received bytes; for `-start` ops the async pair is
    counted once via the start op).  `-done` ops are skipped.
    """
    bytes_by_kind: Dict[str, int] = {}
    count_by_kind: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        type_str, op = m.groups()
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        kind = next((k for k in _COLLECTIVE_KINDS if base == k), None)
        if kind is None:
            continue
        b = _shape_bytes(type_str)
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


# --------------------------------------------------------------------------
# Roofline terms (§Roofline): three times in seconds + dominant bottleneck
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device FLOPs from cost_analysis
    hlo_bytes: float            # per-device HBM traffic from cost_analysis
    collective_bytes: float     # per-device collective bytes from HLO
    model_flops: float          # 6*N*D useful flops (global, per step)
    peak_flops: float           # per chip
    hbm_bw: float
    link_bw: float
    memory_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): how much compiled compute is
        useful work (catches remat / redundancy waste)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilisation at the bound: MODEL_FLOPS /
        (chips * peak * T_bound) — an MFU-style score from the dry-run."""
        denom = self.chips * self.peak_flops * self.t_bound
        return self.model_flops / denom if denom else 0.0

    def row(self) -> str:
        return (f"{self.arch:<24}{self.shape:<13}{self.mesh:<8}"
                f"{self.t_compute * 1e3:>10.2f}{self.t_memory * 1e3:>10.2f}"
                f"{self.t_collective * 1e3:>10.2f}  {self.bottleneck:<11}"
                f"{self.useful_flop_fraction:>7.1%}"
                f"{self.roofline_fraction:>9.2%}"
                f"{self.memory_per_device / 1e9:>9.1f}G")

    @staticmethod
    def header() -> str:
        return (f"{'arch':<24}{'shape':<13}{'mesh':<8}"
                f"{'Tcomp(ms)':>10}{'Tmem(ms)':>10}{'Tcoll(ms)':>10}  "
                f"{'bound':<11}{'useful':>7}{'roofline':>9}{'mem/dev':>10}")


def roofline_terms(arch: str, shape: str, mesh_name: str, chips: int,
                   cost_analysis: Optional[dict], hlo_text: str,
                   model_flops: float, peak_flops: float, hbm_bw: float,
                   link_bw: float, memory_per_device: float = 0.0
                   ) -> RooflineTerms:
    flops = float(cost_analysis.get("flops", 0.0)) if cost_analysis else 0.0
    in_bytes = sum(v for k, v in (cost_analysis or {}).items()
                   if k.startswith("bytes accessed"))
    colls = parse_collectives(hlo_text)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=float(in_bytes),
        collective_bytes=float(colls.total_bytes),
        model_flops=model_flops, peak_flops=peak_flops,
        hbm_bw=hbm_bw, link_bw=link_bw,
        memory_per_device=memory_per_device)
