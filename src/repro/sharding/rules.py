"""Parameter/activation partition rules: logical roles -> mesh axes.

The production mesh is ``(pod, data, model)``: ``pod`` is pure DP across
slices (DCN), ``data`` carries DP + ZeRO/FSDP parameter sharding, and
``model`` carries TP (attention heads / FFN hidden), EP (experts) and SP
(KV-cache sequence sharding for decode).  Rules are attached by
*parameter name*, so every architecture in the zoo shares one rule set;
meshes of any shape re-map without code changes (drop an axis and the
specs degrade gracefully — that is the elasticity story).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_spec", "param_specs", "batch_specs", "cache_specs",
           "DP_AXES", "MODEL_AXIS"]

DP_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"

#: name -> (spec for the *unstacked* param); a leading None is prepended
#: automatically for scan-stacked leaves inside block groups.
_BY_NAME = {
    # embeddings / head — the table shards on d_model over `data`
    # (replicated over model): the gather backward then produces
    # [V, D/|data|] partials instead of a full-table f32 partial per
    # device (5 GB for the 150k-vocab configs)
    "embed": P("model", "data"),
    "head": P("data", "model"),
    "pos_embed": P(None, None),
    "mtp_proj": P("data", "model"),
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    "bq": P("model"),
    "bk": P("model"),
    "bv": P("model"),
    # MLA
    "wq_a": P("data", None),
    "wq_b": P(None, "model"),
    "wkv_a": P("data", None),
    "wkv_b": P(None, "model"),
    # dense mlp
    "w_gate": P("data", "model"),
    "w_up": P("data", "model"),
    "w_down": P("model", "data"),
    # mamba: per-component projections (the fused in_proj of the
    # reference implementation has a non-divisible inner dim)
    "in_z": P("data", "model"),
    "in_x": P("data", "model"),
    "in_b": P("data", None),
    "in_c": P("data", None),
    "in_dt": P("data", None),
    "out_proj": P("model", "data"),
    "conv_w": P(None, None),
    "conv_b": P(None),
    # moe (4-D expert-stacked leaves are special-cased below); the
    # router is small and every shard routes all tokens -> replicated
    "router": P(None, None),
}

#: inside a "moe" subtree the expert dim leads
_MOE_EXPERT = {
    "w_gate": P("model", "data", None),
    "w_up": P("model", "data", None),
    "w_down": P("model", None, "data"),
}


def _path_names(path) -> list:
    return [str(getattr(k, "key", getattr(k, "name", k))) for k in path]


def param_spec(path, leaf, mesh_axes: Tuple[str, ...]) -> P:
    names = _path_names(path)
    name = names[-1]
    in_group = any(n.startswith("dec_") or n.startswith("enc_")
                   for n in names)
    in_moe = "moe" in names
    if in_moe and name in _MOE_EXPERT:
        spec = _MOE_EXPERT[name]
    elif name in _BY_NAME:
        spec = _BY_NAME[name]
    else:
        spec = P()  # norms, scalars, biases of norms: replicated
    # drop axes the mesh does not have
    parts = tuple(p if (p is None or p in mesh_axes) else None
                  for p in spec)
    # scan-stacked leaves carry a leading layer dim
    expected = leaf.ndim - (1 if in_group else 0)
    parts = parts[:expected] if len(parts) >= expected \
        else parts + (None,) * (expected - len(parts))
    if in_group:
        parts = (None,) + parts
    return P(*parts)


def param_specs(params, mesh: jax.sharding.Mesh, axes=None):
    """Pytree of PartitionSpecs congruent with ``params``.  ``axes``
    optionally restricts which mesh axes participate (axis-role
    remapping: e.g. axes=("data",) turns TP off for small models;
    axes=("model",) gives the TP-only serving layout)."""
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, axes), params)


def batch_specs(batch, mesh: jax.sharding.Mesh,
                dp_axes: Optional[Tuple[str, ...]] = None):
    """Inputs: batch dim over the dp axes, everything else replicated."""
    axes = tuple(a for a in (dp_axes or DP_AXES) if a in mesh.axis_names)
    return jax.tree.map(
        lambda leaf: P(axes, *([None] * (leaf.ndim - 1))), batch)


def cache_specs(caches, mesh: jax.sharding.Mesh, *,
                batch_axes: Tuple[str, ...], seq_axes: Tuple[str, ...]):
    """KV/state caches: [L, B, S, ...] -> batch over batch_axes, seq over
    seq_axes; mamba states [L, B, H, N, P] shard heads over model."""
    axes = set(mesh.axis_names)
    b_ax = tuple(a for a in batch_axes if a in axes) or None
    s_ax = tuple(a for a in seq_axes if a in axes) or None

    def _fits(dim: int, ax) -> bool:
        if ax is None:
            return False
        sizes = [mesh.shape[a] for a in (ax if isinstance(ax, tuple)
                                         else (ax,))]
        return dim % int(np.prod(sizes)) == 0

    model = MODEL_AXIS if "model" in axes else None

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v"):          # [L, B, S, Hkv, hd]
            return P(None, b_ax, s_ax if _fits(leaf.shape[2], s_ax)
                     else None, None, None)
        if name in ("ckv", "krope"):    # [L, B, S, C]
            return P(None, b_ax, s_ax if _fits(leaf.shape[2], s_ax)
                     else None, None)
        if name == "ssm":               # [L, B, H, N, P]: prefer heads,
            # else the state dim; else replicate (states are tiny)
            if _fits(leaf.shape[2], model):
                return P(None, b_ax, model, None, None)
            if _fits(leaf.shape[3], model):
                return P(None, b_ax, None, model, None)
            return P(None, b_ax, None, None, None)
        if name == "conv":              # [L, B, W, C]
            return P(None, b_ax, None,
                     model if _fits(leaf.shape[3], model) else None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, caches)
