"""Pure-jnp sequential-scan oracle for the SSD kernel (and the model's
reference/decode path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_ref", "ssd_step"]


def ssd_step(state, x_t, dt_t, a, b_t, c_t):
    """One recurrence step.  state [H, N, P]; x_t [H, P]; dt_t [H];
    a [H]; b_t/c_t [G, N].  Returns (state', y_t [H, P])."""
    H = x_t.shape[0]
    G = b_t.shape[0]
    hg = H // G
    bh = jnp.repeat(b_t, hg, axis=0)            # [H, N]
    ch = jnp.repeat(c_t, hg, axis=0)
    decay = jnp.exp(dt_t * a)                   # [H]
    upd = jnp.einsum("hn,hp->hnp", bh, x_t * dt_t[:, None])
    state = decay[:, None, None] * state + upd
    y = jnp.einsum("hn,hnp->hp", ch, state)
    return state, y


def ssd_ref(x, dt, a, b, c):
    """x [B,S,H,P], dt [B,S,H], a [H], b/c [B,S,G,N] ->
    (y [B,S,H,P], final_state [B,H,N,P])."""
    B, S, H, P = x.shape
    N = b.shape[-1]

    def scan_one(x_b, dt_b, b_b, c_b):
        def step(st, inp):
            xt, dtt, bt, ct = inp
            st, y = ssd_step(st, xt, dtt, a, bt, ct)
            return st, y
        st0 = jnp.zeros((H, N, P), jnp.float32)
        st, ys = jax.lax.scan(step, st0, (x_b.astype(jnp.float32),
                                          dt_b.astype(jnp.float32),
                                          b_b.astype(jnp.float32),
                                          c_b.astype(jnp.float32)))
        return ys, st

    ys, st = jax.vmap(scan_one)(x, dt, b, c)
    return ys.astype(x.dtype), st
