"""Public wrapper for the SSD scan: Pallas on TPU, interpret elsewhere;
reference VJP (the recurrence differentiates cleanly through the oracle
while the kernel serves the forward hot path)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref

__all__ = ["ssd"]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a, b, c, chunk, interpret):
    y, _ = _k.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=interpret)
    return y


def _ssd_fwd(x, dt, a, b, c, chunk, interpret):
    y, _ = _k.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=interpret)
    return y, (x, dt, a, b, c)


def _ssd_bwd(chunk, interpret, res, dy):
    x, dt, a, b, c = res
    _, vjp = jax.vjp(lambda *ops: _ref.ssd_ref(*ops)[0], x, dt, a, b, c)
    return vjp(dy)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
        c: jnp.ndarray, *, chunk: int = 128,
        interpret: Optional[bool] = None) -> jnp.ndarray:
    """SSD scan output y [B, S, H, P] (see kernel.ssd_scan)."""
    if interpret is None:
        interpret = _default_interpret()
    return _ssd(x, dt, a, b, c, chunk, interpret)
