"""Pallas kernel package."""
