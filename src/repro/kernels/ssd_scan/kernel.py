"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSD decomposition (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks of length L: within a chunk the recurrence is evaluated as a
dense (MXU-friendly) quadratic form; across chunks a [N, P] running state
is carried.  The chunk dimension is the grid's minor-most axis, so the
running state lives in VMEM scratch and flows sequentially — the same
accumulation idiom as the flash-attention kernels.

Per chunk (head h, all f32):
    dA   = dt * A_h                       [L]
    cum  = cumsum(dA)                     [L]
    Yin  = ((C B^T) o exp(cum_i - cum_j) o (i>=j) o dt_j) x     (intra)
    Yout = (C o exp(cum)_i) state_prev                          (inter)
    state = exp(cum_L) state_prev + (B o (exp(cum_L - cum) dt))^T x
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                s_scr, *, L: int, P: int, N: int):
    c_idx = pl.program_id(2)      # chunk (sequential)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [L, 1]  (lane-padded)
    a = a_ref[0, 0].astype(jnp.float32)          # [1, 1] scalar A_h
    bmat = b_ref[0, 0].astype(jnp.float32)       # [L, N]
    cmat = c_ref[0, 0].astype(jnp.float32)       # [L, N]

    dA = dt[:, 0] * a[0, 0]                      # [L]
    cum = jnp.cumsum(dA)                         # [L]

    # intra-chunk quadratic form
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    seg = cum[:, None] - cum[None, :]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    seg = jnp.where(causal, seg, -1e30)   # pre-exp clamp (no inf leakage)
    m = cb * jnp.exp(seg) * dt[:, 0][None, :]
    y = jax.lax.dot(m, x, preferred_element_type=jnp.float32)     # [L, P]

    # inter-chunk contribution from the running state  [N, P]
    state = s_scr[...]
    y += jax.lax.dot(cmat * jnp.exp(cum)[:, None], state,
                     preferred_element_type=jnp.float32)

    # state update
    decay_end = jnp.exp(cum[L - 1] - cum) * dt[:, 0]              # [L]
    s_new = (jnp.exp(cum[L - 1]) * state
             + jax.lax.dot_general(bmat * decay_end[:, None], x,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _final():
        state_ref[0, 0] = s_new.astype(state_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False):
    """Chunked SSD scan.

    x  [B, S, H, P]   inputs (already dt-free; dt applied inside)
    dt [B, S, H]      positive step sizes (softplus applied by caller)
    a  [H]            negative state decay scalars
    b  [B, S, G, N]   input projections  (G groups, H % G == 0)
    c  [B, S, G, N]   output projections
    Returns (y [B, S, H, P], final_state [B, H, N, P]).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    L = min(chunk, S)
    nc = pl.cdiv(S, L)
    hg = H // G

    # layout: [B, H, S, *] so (batch, head) are grid-major
    xt = jnp.swapaxes(x, 1, 2)                        # [B, H, S, P]
    dtt = jnp.swapaxes(dt, 1, 2)[..., None]           # [B, H, S, 1]
    bt = jnp.swapaxes(b, 1, 2)                        # [B, G, S, N]
    ct = jnp.swapaxes(c, 1, 2)
    a2 = a.reshape(H, 1, 1).astype(jnp.float32)       # [H, 1, 1]

    kernel = functools.partial(_ssd_kernel, L=L, P=P, N=N)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bb, h, cc: (bb, h, cc, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda bb, h, cc: (bb, h, cc, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda bb, h, cc: (0, h, 0, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda bb, h, cc, g=hg: (bb, h // g, cc, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda bb, h, cc, g=hg: (bb, h // g, cc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda bb, h, cc: (bb, h, cc, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bb, h, cc: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a2[None], bt, ct)
    return jnp.swapaxes(y, 1, 2), state
