"""Pallas kernel package."""
