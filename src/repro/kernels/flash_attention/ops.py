"""jit'd public wrapper for flash attention with a custom VJP.

``flash_attention`` dispatches to the Pallas TPU kernel (or its
``interpret=True`` execution on CPU) and differentiates through the
hand-written backward kernels.  On non-TPU backends ``interpret`` defaults
to True so the same call validates everywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _k

__all__ = ["flash_attention"]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, softcap, scale, block_q, block_k,
           interpret):
    o, _ = _k.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, window, softcap, scale, block_q, block_k,
               interpret):
    o, lse = _k.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, softcap, scale, block_q, block_k, interpret,
               res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _k.flash_attention_bwd(
        q, k, v, o, do, lse, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Flash attention: q [B,H,S,D], k/v [B,Hkv,S,D] -> [B,H,S,D]."""
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal, window, softcap, scale,
                  block_q, block_k, interpret)
