"""Flash attention Pallas TPU kernel (forward + backward).

TPU-native tiling: the grid's minor-most dimension iterates sequentially
on a core, so the online-softmax state (m, l, acc) lives in VMEM scratch
and accumulates across the key-block dimension.  Block shapes are
(8k, 128)-aligned for the MXU; all accumulation in f32.

Supports: causal masking, sliding-window (local) attention, logit
soft-capping (gemma-2), and GQA via a kv-head index map (no K/V
replication in HBM).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pick_block(requested: int, seq: int) -> int:
    """Largest power-of-two <= requested that divides seq (ragged
    sequences then never read OOB-padded blocks)."""
    b = min(requested, seq)
    while seq % b:
        b //= 2
    return max(b, 1)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                bq: int, bk: int, seq_len: int, causal: bool,
                window: Optional[int], softcap: Optional[float],
                scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # k block (minor-most: sequential)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nj - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...]
                         + jnp.log(jnp.maximum(l_scr[...], 1e-30)))


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: [B, H, S, D]; k, v: [B, Hkv, S, D] -> (o [B,H,S,D],
    lse [B,H,S,1] log-sum-exp residual for the backward)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    bq = _pick_block(block_q, S)
    bk = _pick_block(block_k, S)
    nq = S // bq
    nk = S // bk
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fwd_kernel, bq=bq, bk=bk, seq_len=S, causal=causal,
        window=window, softcap=softcap, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, S, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, D), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------
# backward (two passes: dK/dV over q-blocks, dQ over k-blocks)
# --------------------------------------------------------------------------

def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *,
                    bq: int, bk: int, seq_len: int, causal: bool,
                    window: Optional[int], softcap: Optional[float],
                    scale: float):
    j = pl.program_id(2)          # k block
    i = pl.program_id(3)          # q block (sequential accumulation)
    ni = pl.num_programs(3)

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)                  # [bq, d]
    lse = lse_ref[0, 0].astype(jnp.float32)                # [bq, 1]
    delta = delta_ref[0, 0].astype(jnp.float32)            # [bq, 1]

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)             # [bq, bk]

    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(i == ni - 1)
    def _final():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_scr, *,
                   bq: int, bk: int, seq_len: int, causal: bool,
                   window: Optional[int], softcap: Optional[float],
                   scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)

    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    dq_scr[...] += jax.lax.dot(ds, k,
                               preferred_element_type=jnp.float32) * scale

    @pl.when(j == nj - 1)
    def _final():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, o, do, lse, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Returns (dq, dk, dv).  ``lse`` is the forward log-sum-exp
    [B, H, S, 1] (recomputed by ops.py's custom_vjp residuals)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    bq = _pick_block(block_q, S)
    bk = _pick_block(block_k, S)
    nq = S // bq
    nk = S // bk
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1, keepdims=True)               # [B, H, S, 1]

    common = dict(bq=bq, bk=bk, seq_len=S, causal=causal, window=window,
                  softcap=softcap, scale=scale)
    # dK/dV computed per *query* head then group-summed outside.
    dkv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **common),
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, j, i, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, j, i, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, j, i: (b, h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk_h, dv_h = dkv
    dk = dk_h.reshape(B, Hkv, group, S, D).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, group, S, D).sum(axis=2).astype(v.dtype)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **common),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
