"""Pure-jnp oracle for flash attention (and the model's reference path)."""

from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q: [B, H, S, D]; k, v: [B, Hkv, S, D] -> [B, H, S, D].  f32 math."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
