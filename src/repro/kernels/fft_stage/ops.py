"""Complex-array wrapper for the local FFT kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _k

__all__ = ["fft", "ifft"]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _run(x: jnp.ndarray, inverse: bool, interpret: Optional[bool]):
    if interpret is None:
        interpret = _default_interpret()
    shape = x.shape
    n = shape[-1]
    xr = jnp.real(x).astype(jnp.float32).reshape(-1, n)
    xi = jnp.imag(x).astype(jnp.float32).reshape(-1, n)
    yr, yi = _k.fft_planes(xr, xi, inverse=inverse, interpret=interpret)
    return jax.lax.complex(yr, yi).reshape(shape).astype(
        jnp.complex64 if x.dtype != jnp.complex128 else x.dtype)


def fft(x: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """FFT along the last axis (power-of-two length)."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if x.ndim == 1:
        return _run(x[None], False, interpret)[0]
    return _run(x, False, interpret)


def ifft(x: jnp.ndarray, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if x.ndim == 1:
        return _run(x[None], True, interpret)[0]
    return _run(x, True, interpret)
