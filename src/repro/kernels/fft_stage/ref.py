"""Oracle for the local FFT kernel: jnp.fft."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fft_ref", "ifft_ref"]


def fft_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.fft.fft(jnp.asarray(x, jnp.complex64)).astype(jnp.complex64)


def ifft_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.fft.ifft(jnp.asarray(x, jnp.complex64)).astype(jnp.complex64)
