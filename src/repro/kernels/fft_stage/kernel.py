"""Process-local FFT Pallas kernel — the paper's FFT compute hot-spot.

Stockham autosort radix-2 FFT: no bit-reversal pass, every stage reads
and writes contiguous VMEM blocks.  Complex values travel as separate
re/im f32 planes (Mosaic has no complex dtype).  The whole local vector
(n/p <= 2^15 for the production FFT sizes) fits in VMEM, so one grid step
transforms a batch row; the batch dimension streams through the grid.

The butterfly loop is a *static* Python loop over log2(n) stages of
reshape/concat arithmetic — XLA/Mosaic sees a flat dataflow graph, all
operations lane-parallel over the row batch.

Stage invariant (bottom-up decimation in time): after ``s`` stages the
working array viewed as [n/L, L] holds, in row ``r``, the L-point DFT of
the stride-``n/L`` subsequence x[r::n/L].
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fft_body(re, im, n: int, inverse: bool):
    """Static Stockham stages on [rows, n] re/im planes."""
    rows = re.shape[0]
    sign = 1.0 if inverse else -1.0
    L = 1
    re = re.reshape(rows, n, 1)
    im = im.reshape(rows, n, 1)
    while L < n:
        d = n // (2 * L)
        re3 = re.reshape(rows, 2, d, L)
        im3 = im.reshape(rows, 2, d, L)
        ar, ai = re3[:, 0], im3[:, 0]            # [rows, d, L]
        br, bi = re3[:, 1], im3[:, 1]
        ang = sign * 2.0 * math.pi * jnp.arange(L, dtype=jnp.float32) \
            / (2.0 * L)
        wr, wi = jnp.cos(ang), jnp.sin(ang)       # [L]
        tbr = br * wr - bi * wi
        tbi = br * wi + bi * wr
        re = jnp.concatenate([ar + tbr, ar - tbr], axis=2)  # [rows, d, 2L]
        im = jnp.concatenate([ai + tbi, ai - tbi], axis=2)
        L *= 2
    return re.reshape(rows, n), im.reshape(rows, n)


def _fft_kernel(re_ref, im_ref, ore_ref, oim_ref, *, n: int, rows: int,
                inverse: bool):
    re = re_ref[...].astype(jnp.float32)
    im = im_ref[...].astype(jnp.float32)
    re, im = _fft_body(re, im, n, inverse)
    if inverse:
        re = re / n
        im = im / n
    ore_ref[...] = re.astype(ore_ref.dtype)
    oim_ref[...] = im.astype(oim_ref.dtype)


def fft_planes(re: jnp.ndarray, im: jnp.ndarray, *, inverse: bool = False,
               rows_per_block: int = 8, interpret: bool = False):
    """Batched FFT on separate planes: re/im [batch, n] -> (re, im)."""
    batch, n = re.shape
    assert n & (n - 1) == 0, f"radix-2 kernel needs power-of-two n, got {n}"
    rb = min(rows_per_block, batch)
    grid = (pl.cdiv(batch, rb),)
    kernel = functools.partial(_fft_kernel, n=n, rows=rb, inverse=inverse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rb, n), lambda i: (i, 0)),
                  pl.BlockSpec((rb, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rb, n), lambda i: (i, 0)),
                   pl.BlockSpec((rb, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((batch, n), jnp.float32),
                   jax.ShapeDtypeStruct((batch, n), jnp.float32)],
        interpret=interpret,
    )(re, im)
