"""Pallas kernel package."""
