"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` — no state to lose on
restart beyond the step counter, which rides in the checkpoint.  The
token stream has learnable structure (a noisy affine next-token rule over
a zipf-ish marginal) so training loss demonstrably decreases in the
end-to-end example.  Modality stubs synthesise patch/frame embeddings
with the same determinism.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["DataConfig", "SyntheticStream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.75      # P(next token follows the affine rule)


class SyntheticStream:
    """Checkpointable iterator: state == step (int)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.a = 6364136223846793005 % cfg.vocab or 1
        self.c = 1442695040888963407 % cfg.vocab

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) ^ step)
        B, S = cfg.global_batch, cfg.seq_len
        # zipf-ish marginal for the random branches
        ranks = np.arange(1, cfg.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        rand_draws = rng.choice(cfg.vocab, size=(B, S), p=probs)
        follow = rng.random((B, S)) < cfg.structure
        for t in range(1, S):
            nxt = (toks[:, t - 1] * self.a + self.c) % cfg.vocab
            toks[:, t] = np.where(follow[:, t], nxt, rand_draws[:, t])
        labels = np.concatenate(
            [toks[:, 1:], np.full((B, 1), -1, np.int64)], axis=1)
        out = {"tokens": toks.astype(np.int32),
               "labels": labels.astype(np.int32)}
        mc = self.model_cfg
        if mc is not None and mc.modality == "vision":
            out["embeds"] = rng.standard_normal(
                (B, mc.stub_prefix, mc.d_model)).astype(np.float32)
        if mc is not None and mc.modality == "audio" and mc.encoder_groups:
            out["frames"] = rng.standard_normal(
                (B, S, mc.d_model)).astype(np.float32)
        return out

    # -- checkpointable iterator protocol --------------------------------
    def state(self, step: int) -> dict:
        return {"step": int(step), "seed": self.cfg.seed}

    @staticmethod
    def resume(state: dict) -> int:
        return int(state["step"])
