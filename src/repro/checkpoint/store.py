"""Checkpointing: atomic, async-capable, *elastic* (mesh-shape-agnostic).

Layout:  <dir>/step_<n>/
           manifest.json        tree structure, shapes, dtypes, meta
           leaf_<i>.npy         one array per pytree leaf

Writes go to a temp dir and are renamed into place (atomic publish), so a
crash mid-save never corrupts the latest checkpoint; ``latest_step`` only
sees published steps.  ``AsyncCheckpointer`` runs the device->host fetch
synchronously (cheap) and the serialisation on a worker thread,
overlapping I/O with the next training steps — the save barrier moves off
the step path.

Elasticity: leaves are stored unsharded; ``restore`` re-shards onto ANY
mesh via ``jax.device_put`` with the target NamedSharding — restoring a
16x16 run onto 2x16x16 (or onto one CPU device in tests) is the same code
path.  At >100B scale the same manifest format would point at per-shard
files; the single-file-per-leaf layout is the container-scale instance of
that design.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _sweep_stale_tmp(directory: str, keep: Optional[str] = None) -> None:
    """Remove crash-abandoned ``.tmp_step_*`` staging dirs.  A temp dir
    only exists while a save is in flight (it is renamed into place on
    publish), so any found here — other than ``keep``, the one the
    caller is about to write — was orphaned by a crash and would
    otherwise accumulate forever (``_gc`` only matches ``step_*``)."""
    if not os.path.isdir(directory):
        return
    for d in os.listdir(directory):
        if d.startswith(".tmp_step_") and d != keep:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def save(directory: str, step: int, tree: Any, meta: Optional[dict] = None
         ) -> str:
    """Synchronous atomic save.  Returns the published path."""
    flat, treedef = _tree_paths(tree)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    _sweep_stale_tmp(directory, keep=os.path.basename(tmp))
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "leaves": [],
        "meta": meta or {},
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore onto the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional congruent pytree of
    ``jax.sharding.Sharding`` — pass the *target* mesh's shardings to
    restore elastically onto a different topology."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _tree_paths(like)
    if manifest["n_leaves"] != len(flat_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target "
            f"structure has {len(flat_like)} — config mismatch")
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_like))
    out = []
    for i, (ref, sh) in enumerate(zip(flat_like, flat_sh)):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlaps serialisation with training; keeps the last K steps."""

    def __init__(self, directory: str, keep: int = 3):
        if keep < 0:
            raise ValueError(f"keep must be >= 0, got {keep}")
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        # fetch on the caller thread (device ordering), write on a worker
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                 tree)

        def work():
            try:
                save(self.directory, step, host_tree, meta)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Any, shardings: Any = None):
        """Restore the newest *published* checkpoint: returns
        ``(step, tree)``, or ``(None, None)`` when the directory holds
        no published step.  Waits for any in-flight save first, so the
        recovery path (``train_loop``'s step supervisor) never races
        its own publisher."""
        self.wait()
        last = latest_step(self.directory)
        if last is None:
            return None, None
        return last, restore(self.directory, last, like,
                             shardings=shardings)

    def _gc(self):
        _sweep_stale_tmp(self.directory)
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        # NOT steps[:-self.keep]: with keep=0 that is the empty slice
        # (nothing would ever be deleted) instead of "keep none"; the
        # max() guard keeps the bound non-negative when fewer than
        # ``keep`` checkpoints exist (a negative bound would slice from
        # the end and delete the oldest ones)
        for s in steps[:max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
