"""Shared model machinery: norms, RoPE, initialisers, dtype policy."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["DtypePolicy", "rms_norm", "layer_norm", "apply_rope",
           "rope_freqs", "dense_init", "sinusoidal_positions"]


@dataclasses.dataclass(frozen=True)
class DtypePolicy:
    param: Any = jnp.float32
    compute: Any = jnp.bfloat16
    accum: Any = jnp.float32

    def cast_in(self, x):
        return x.astype(self.compute)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in f32 (gemma uses (1 + w) scaling: ``plus_one``)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] (int).  Half-split convention."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs    # [B, S, d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq_len, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32,
               scale: float = 1.0) -> jnp.ndarray:
    """Truncated-normal fan-in init (LeCun-ish), stable across dtypes."""
    fan_in = shape[in_axis] if shape else 1
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)
