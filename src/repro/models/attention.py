"""Attention implementations for train/prefill and distributed decode.

Three paths:

* ``blocked_attention`` — pure-jnp online-softmax over query chunks
  (flash-pattern memory: never materialises the full [S, S] score
  matrix).  The default train/prefill path; it lowers on any backend and
  GSPMD partitions it cleanly (batch -> data, heads -> model).
* ``repro.kernels.flash_attention`` — the Pallas TPU kernel; selected
  with ``impl='flash'`` on TPU runtimes.
* ``decode_attention`` — single-token decode against a *sequence-sharded*
  KV cache: each model-axis shard computes a partial softmax over its
  chunk of the cache; partials merge with the numerically-stable
  (m, l, o) combine — a textbook LPF superstep (one small all-reduce),
  executed via shard_map over the model axis.  Replicating a 32k cache
  over TP=16 would cost 17 GB/device; sharding costs 67 MB.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat

__all__ = ["blocked_attention", "decode_attention", "attention"]


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      scale: Optional[float] = None,
                      q_chunk: int = 512) -> jnp.ndarray:
    """q [B, S, H, D]; k/v [B, S, Hkv, D] -> [B, S, H, D].

    Scans over query chunks; scores per step are [B, H, qc, S] — O(S)
    memory in the sequence length, not O(S^2)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qc = min(q_chunk, S)
    nq = S // qc if S % qc == 0 else -(-S // qc)
    pad = nq * qc - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # GQA without materialising repeated K/V: fold head groups.  Operands
    # stay in the input dtype (bf16); only the score accumulator and the
    # softmax run in f32 — the flash-kernel precision contract, and the
    # difference between ~4 GB and ~40 GB of live attention intermediates
    # on the 8k-wide configs.
    q4 = q.reshape(B, nq, qc, Hkv, group, D)

    def step(carry, inp):
        i, qch = inp                                  # qch [B, qc, Hkv, g, D]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qch, k,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = i * qc + jnp.arange(qc)
        k_pos = jnp.arange(S)
        mask = jnp.ones((qc, S), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        p = (p / jnp.maximum(l, 1e-30)).astype(v.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v,
                       preferred_element_type=jnp.float32)
        return carry, o.astype(v.dtype)

    _, outs = lax.scan(step, 0,
                       (jnp.arange(nq), jnp.moveaxis(q4, 1, 0)))
    Dv = v.shape[-1]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * qc, H, Dv)
    if pad:
        out = out[:, :S]
    return out.astype(q.dtype)


def _partial_softmax(q, k, v, scale, softcap, valid=None):
    """Partial attention stats over a cache chunk.
    q [B, H, D]; k/v [B, Sc, Hkv, D] -> (m, l, o) with o unnormalised.
    ``valid`` [Sc] bool masks cache slots not yet written."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if valid is not None:
        s = jnp.where(valid[None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)                  # [B,Hkv,g,1]
    p = jnp.exp(s - m)
    if valid is not None:
        p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return m, l, o


def merge_partials(m1, l1, o1, m2, l2, o2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, a1 * l1 + a2 * l2, a1 * o1 + a2 * o2


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, k_new: jnp.ndarray,
                     v_new: jnp.ndarray, *, mesh,
                     seq_axes: Tuple[str, ...] = ("model",),
                     batch_axes: Tuple[str, ...] = ("data",),
                     softcap: Optional[float] = None,
                     window: Optional[int] = None,
                     scale: Optional[float] = None,
                     pos=None) -> jnp.ndarray:
    """One-token decode against a seq-sharded cache with distributed merge.

    q [B, H, D]; {k,v}_cache [B, S, Hkv, D] sharded (batch->batch_axes,
    S->seq_axes); {k,v}_new [B, 1, Hkv, D].  Returns [B, H, D].

    Sliding-window caches are assumed pre-rolled (the cache holds the
    last ``window`` positions), so all cache entries participate.
    """
    B, H, D = q.shape
    Hkv = k_cache.shape[2]
    scale_v = scale if scale is not None else 1.0 / math.sqrt(D)
    batch_axes = tuple(batch_axes) or None
    seq_axes = tuple(seq_axes)

    def body(qb, kc, vc, kn, vn):
        valid = None
        if pos is not None:
            # global slot index of my cache chunk across the seq shards
            Sc = kc.shape[1]
            shard = lax.axis_index(seq_axes if len(seq_axes) > 1
                                   else seq_axes[0])
            k_pos = shard * Sc + jnp.arange(Sc)
            valid = k_pos < pos
        m, l, o = _partial_softmax(qb, kc, vc, scale_v, softcap, valid)
        # merge across the sequence shards
        mg = lax.pmax(m, seq_axes)
        corr = jnp.exp(m - mg)
        l = lax.psum(l * corr, seq_axes)
        o = lax.psum(o * corr, seq_axes)
        # fold in the new token (replicated over seq shards)
        m2, l2, o2 = _partial_softmax(qb, kn, vn, scale_v, softcap)
        mf, lf, of = merge_partials(mg, l, o, m2, l2, o2)
        out = of / jnp.maximum(lf, 1e-30)
        return out.reshape(qb.shape[0], H, D).astype(qb.dtype)

    bspec = P(batch_axes)
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None),
                  P(batch_axes, seq_axes, None, None),
                  P(batch_axes, seq_axes, None, None),
                  P(batch_axes, None, None, None),
                  P(batch_axes, None, None, None)),
        out_specs=P(batch_axes, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new)


def attention(q, k, v, *, impl: str = "blocked", causal=True, window=None,
              softcap=None, scale=None, q_chunk: int = 512):
    """Dispatch train/prefill attention by implementation name."""
    if impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention
        # kernel layout is [B, H, S, D]
        o = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                            jnp.swapaxes(v, 1, 2), causal=causal,
                            window=window, softcap=softcap, scale=scale)
        return jnp.swapaxes(o, 1, 2)
    if impl == "reference":
        from repro.kernels.flash_attention.ref import attention_ref
        o = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=causal,
                          window=window, softcap=softcap, scale=scale)
        return jnp.swapaxes(o, 1, 2)
    return blocked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, q_chunk=q_chunk)
