"""Transformer/Mamba block implementations: init, train apply, decode step.

All blocks are pre-norm residual; gemma-2's ``post_norms`` adds the
sandwich norms.  Attention supports GQA, qk-norm, QKV bias, RoPE,
sliding windows, soft-capping and MLA (compressed-KV) — each feature
driven by the :class:`ModelConfig`.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .attention import attention, decode_attention
from .common import apply_rope, dense_init, layer_norm, rms_norm
from .config import BlockCfg, ModelConfig
from .mamba import (mamba_apply, mamba_decode_step, mamba_init_cache,
                    mamba_params)
from .moe import moe_apply, moe_params

__all__ = ["block_params", "block_apply", "block_decode",
           "block_init_cache", "Runtime"]


class Runtime:
    """Execution context handed down from the launcher: mesh + axis roles.

    ``dp_axes``: batch-sharding axes (also the MoE token axes).
    ``seq_axes``: KV-cache sequence-sharding axes for decode (defaults to
    the model axis; long-context cells widen it to (data, model))."""

    def __init__(self, mesh=None, dp_axes: Tuple[str, ...] = (),
                 model_axis: Optional[str] = None,
                 seq_axes: Optional[Tuple[str, ...]] = None,
                 sp: bool = False, decode_pos=None):
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.model_axis = model_axis
        self.sp = sp
        self.seq_axes = tuple(seq_axes) if seq_axes is not None \
            else ((model_axis,) if model_axis else ())
        self.decode_pos = decode_pos  # traced write position for caches

    @property
    def distributed(self) -> bool:
        return self.mesh is not None and self.model_axis is not None


def _norm(x, p, kind: str, plus_one: bool = False):
    if kind == "layer":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], plus_one=plus_one)


def _norm_params(d: int, kind: str):
    if kind == "layer":
        return {"w": jnp.ones((d,), jnp.float32),
                "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.zeros((d,), jnp.float32)}  # rms stored as (1+w) style


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def _attn_params(key, cfg: ModelConfig, dtype) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _mla_params(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    return {
        "wq_a": dense_init(ks[0], (cfg.d_model, m.q_lora), dtype=dtype),
        "q_norm": jnp.ones((m.q_lora,), jnp.float32),
        "wq_b": dense_init(ks[1], (m.q_lora, h * (m.dh_nope + m.dh_rope)),
                           dtype=dtype),
        "wkv_a": dense_init(ks[2], (cfg.d_model, m.kv_lora + m.dh_rope),
                            dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora,), jnp.float32),
        "wkv_b": dense_init(ks[3], (m.kv_lora, h * (m.dh_nope + m.dh_v)),
                            dtype=dtype),
        "wo": dense_init(ks[4], (h * m.dh_v, cfg.d_model), dtype=dtype),
    }


def _mlp_params(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (cfg.d_model, cfg.d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (cfg.d_ff, cfg.d_model), dtype=dtype),
    }


def block_params(key, bcfg: BlockCfg, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if bcfg.mixer == "attn":
        p["attn"] = _attn_params(ks[0], cfg, dtype)
        p["ln1"] = _norm_params(cfg.d_model, cfg.norm)
    elif bcfg.mixer == "mla":
        p["attn"] = _mla_params(ks[0], cfg, dtype)
        p["ln1"] = _norm_params(cfg.d_model, cfg.norm)
    elif bcfg.mixer == "mamba":
        p["mamba"] = mamba_params(ks[0], cfg.mamba, dtype)
        p["ln1"] = _norm_params(cfg.d_model, cfg.norm)
    if cfg.post_norms and bcfg.mixer != "none":
        p["post_ln1"] = _norm_params(cfg.d_model, cfg.norm)
    if bcfg.cross_attn:
        p["xattn"] = _attn_params(ks[1], cfg, dtype)
        p["ln_x"] = _norm_params(cfg.d_model, cfg.norm)
    if bcfg.ffn == "dense":
        p["mlp"] = _mlp_params(ks[2], cfg, dtype)
        p["ln2"] = _norm_params(cfg.d_model, cfg.norm)
    elif bcfg.ffn == "moe":
        p["moe"] = moe_params(ks[2], cfg.moe, dtype)
        p["ln2"] = _norm_params(cfg.d_model, cfg.norm)
        if cfg.shared_expert:
            # the shared expert is expert-sized (cfg.moe.d_ff), not d_ff
            kk = jax.random.split(ks[3], 3)
            p["shared_mlp"] = {
                "w_gate": dense_init(kk[0], (cfg.d_model, cfg.moe.d_ff),
                                     dtype=dtype),
                "w_up": dense_init(kk[1], (cfg.d_model, cfg.moe.d_ff),
                                   dtype=dtype),
                "w_down": dense_init(kk[2], (cfg.moe.d_ff, cfg.d_model),
                                     dtype=dtype),
            }
    if cfg.post_norms and bcfg.ffn != "none":
        p["post_ln2"] = _norm_params(cfg.d_model, cfg.norm)
    return p


# --------------------------------------------------------------------------
# apply (train / prefill)
# --------------------------------------------------------------------------

def _mlp(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def _attn_fwd(p, h, cfg: ModelConfig, bcfg: BlockCfg, positions,
              kv_override=None):
    B, S, D = h.shape
    hd = cfg.hd
    q = h @ p["wq"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    if kv_override is None:
        src = h
    else:
        src = kv_override              # cross attention reads encoder states
    Skv = src.shape[1]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = k.reshape(B, Skv, cfg.n_kv, hd)
    v = v.reshape(B, Skv, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_embed == "rope" and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, impl=cfg.attn_impl,
                  causal=bcfg.causal and kv_override is None,
                  window=bcfg.window, softcap=cfg.attn_softcap,
                  q_chunk=cfg.q_chunk)
    return o.reshape(B, S, cfg.n_heads * hd) @ p["wo"], (k, v)


def _mla_fwd(p, h, cfg: ModelConfig, bcfg: BlockCfg, positions):
    """MLA training/prefill path (decompressed K/V)."""
    m = cfg.mla
    B, S, D = h.shape
    H = cfg.n_heads
    q = rms_norm(h @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, m.dh_nope + m.dh_rope)
    q_nope, q_rope = q[..., :m.dh_nope], q[..., m.dh_nope:]
    kv = h @ p["wkv_a"]
    c_kv = rms_norm(kv[..., :m.kv_lora], p["kv_norm"])
    k_rope = kv[..., m.kv_lora:].reshape(B, S, 1, m.dh_rope)
    kvb = c_kv @ p["wkv_b"]
    kvb = kvb.reshape(B, S, H, m.dh_nope + m.dh_v)
    k_nope, v = kvb[..., :m.dh_nope], kvb[..., m.dh_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.dh_rope))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.dh_nope + m.dh_rope)
    o = attention(qf, k, v, impl=cfg.attn_impl, causal=bcfg.causal,
                  window=bcfg.window, softcap=cfg.attn_softcap,
                  scale=scale, q_chunk=cfg.q_chunk)
    out = o.reshape(B, S, H * m.dh_v) @ p["wo"]
    return out, (c_kv, k_rope.reshape(B, S, m.dh_rope))


def _ffn_fwd(p, h, cfg: ModelConfig, bcfg: BlockCfg, rt: Runtime):
    if bcfg.ffn == "dense":
        return _mlp(p["mlp"], h)
    out = moe_apply(p["moe"], h, cfg.moe, mesh=rt.mesh,
                    model_axis=rt.model_axis or "model",
                    dp_axes=rt.dp_axes) if rt.distributed else \
        _moe_single(p["moe"], h, cfg.moe)
    if cfg.shared_expert:
        out = out + _mlp(p["shared_mlp"], h)
    return out


def _moe_single(p, x, mcfg) -> jnp.ndarray:
    """Single-device MoE fallback (smoke tests without a mesh)."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ p["router"]
    E = p["w_gate"].shape[0]
    if E > mcfg.n_experts:
        logits = jnp.where(jnp.arange(E)[None] >= mcfg.n_experts, -1e30,
                           logits)
    gate_vals, gate_idx = lax.top_k(logits, min(mcfg.top_k, E))
    gates = jax.nn.softmax(gate_vals, axis=-1)
    out = jnp.zeros((T, D), jnp.float32)
    cap = max(1, min(T, max(8, int(mcfg.capacity_factor * mcfg.top_k * T / E))))
    for e in range(E):
        w_tok = jnp.sum(jnp.where(gate_idx == e, gates, 0.0), axis=1)
        sel_w, sel_idx = lax.top_k(w_tok, cap)
        x_e = jnp.take(xt, sel_idx, axis=0)
        y = (jax.nn.silu(x_e @ p["w_gate"][e]) * (x_e @ p["w_up"][e])) \
            @ p["w_down"][e]
        out = out.at[sel_idx].add(y.astype(jnp.float32) * sel_w[:, None])
    return out.reshape(B, S, D).astype(x.dtype)


def block_apply(p: dict, x: jnp.ndarray, bcfg: BlockCfg, cfg: ModelConfig,
                rt: Runtime, positions, enc_out=None) -> jnp.ndarray:
    plus_one = cfg.norm == "rms"
    if bcfg.mixer in ("attn", "mla"):
        h = _norm(x, p["ln1"], cfg.norm, plus_one)
        if bcfg.mixer == "attn":
            o, _ = _attn_fwd(p["attn"], h, cfg, bcfg, positions)
        else:
            o, _ = _mla_fwd(p["attn"], h, cfg, bcfg, positions)
        if cfg.post_norms:
            o = _norm(o, p["post_ln1"], cfg.norm, plus_one)
        x = x + o
    elif bcfg.mixer == "mamba":
        h = _norm(x, p["ln1"], cfg.norm, plus_one)
        x = x + mamba_apply(p["mamba"], h, cfg.mamba)
    if bcfg.cross_attn:
        h = _norm(x, p["ln_x"], cfg.norm, plus_one)
        o, _ = _attn_fwd(p["xattn"], h, cfg, bcfg, positions,
                         kv_override=enc_out)
        x = x + o
    if bcfg.ffn != "none":
        h = _norm(x, p["ln2"], cfg.norm, plus_one)
        o = _ffn_fwd(p, h, cfg, bcfg, rt)
        if cfg.post_norms:
            o = _norm(o, p["post_ln2"], cfg.norm, plus_one)
        x = x + o
    return x


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def block_init_cache(bcfg: BlockCfg, cfg: ModelConfig, batch: int,
                     cache_len: int, dtype) -> dict:
    c: Dict[str, Any] = {}
    if bcfg.mixer == "attn":
        S = min(bcfg.window, cache_len) if bcfg.window else cache_len
        c["k"] = jnp.zeros((batch, S, cfg.n_kv, cfg.hd), dtype)
        c["v"] = jnp.zeros((batch, S, cfg.n_kv, cfg.hd), dtype)
    elif bcfg.mixer == "mla":
        m = cfg.mla
        c["ckv"] = jnp.zeros((batch, cache_len, m.kv_lora), dtype)
        c["krope"] = jnp.zeros((batch, cache_len, m.dh_rope), dtype)
    elif bcfg.mixer == "mamba":
        c.update(mamba_init_cache(batch, cfg.mamba, dtype))
    return c


def _attn_decode(p, h, cache, cfg: ModelConfig, bcfg: BlockCfg, rt: Runtime,
                 pos):
    B, D = h.shape
    hd = cfg.hd
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, cfg.n_heads, hd)
    k = k.reshape(B, 1, cfg.n_kv, hd)
    v = v.reshape(B, 1, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.pos_embed == "rope":
        posb = jnp.broadcast_to(pos, (B, 1))
        q = apply_rope(q[:, None], posb, cfg.rope_theta)[:, 0]
        k = apply_rope(k, posb, cfg.rope_theta)
    if rt.distributed:
        o = decode_attention(q, cache["k"], cache["v"], k, v, mesh=rt.mesh,
                             seq_axes=rt.seq_axes,
                             batch_axes=rt.dp_axes,
                             softcap=cfg.attn_softcap, window=bcfg.window,
                             pos=pos)
    else:
        from .attention import _partial_softmax, merge_partials
        scale = 1.0 / math.sqrt(hd)
        valid = jnp.arange(cache["k"].shape[1]) < pos
        m1, l1, o1 = _partial_softmax(q, cache["k"], cache["v"],
                                      scale, cfg.attn_softcap, valid)
        m2, l2, o2 = _partial_softmax(q, k, v, scale,
                                      cfg.attn_softcap)
        m, l, o = merge_partials(m1, l1, o1, m2, l2, o2)
        o = (o / jnp.maximum(l, 1e-30)).reshape(B, cfg.n_heads, hd)
        o = o.astype(h.dtype)
    out = o.reshape(B, cfg.n_heads * hd) @ p["wo"]
    # rolling write: replace slot (pos % cache_len)
    slot = (pos % cache["k"].shape[1]).astype(jnp.int32)
    newc = {
        "k": lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0)),
        "v": lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0)),
    }
    return out, newc


def _mla_decode(p, h, cache, cfg: ModelConfig, rt: Runtime, pos):
    """Absorbed MLA decode on the compressed cache (c_kv + shared k_rope)."""
    m = cfg.mla
    B, D = h.shape
    H = cfg.n_heads
    q = rms_norm(h @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, H, m.dh_nope + m.dh_rope)
    q_nope, q_rope = q[..., :m.dh_nope], q[..., m.dh_nope:]
    posb = jnp.broadcast_to(pos, (B, 1))
    q_rope = apply_rope(q_rope[:, None], posb, cfg.rope_theta)[:, 0]

    kv = h @ p["wkv_a"]
    c_new = rms_norm(kv[..., :m.kv_lora], p["kv_norm"])          # [B, 512]
    kr_new = apply_rope(kv[..., m.kv_lora:][:, None, None, :], posb,
                        cfg.rope_theta)[:, 0, 0]                  # [B, 64]

    wkv_b = p["wkv_b"].reshape(m.kv_lora, H, m.dh_nope + m.dh_v)
    w_uk = wkv_b[..., :m.dh_nope]        # [kv_lora, H, dh_nope]
    w_uv = wkv_b[..., m.dh_nope:]        # [kv_lora, H, dh_v]
    # absorb W_uk into the query: q_abs [B, H, kv_lora]
    q_abs = jnp.einsum("bhd,chd->bhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.dh_nope + m.dh_rope)

    ckv, krope = cache["ckv"], cache["krope"]                     # [B,S,512]
    s = (jnp.einsum("bhc,bsc->bhs", q_abs, ckv.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32))) * scale
    s = jnp.where((jnp.arange(ckv.shape[1]) < pos)[None, None, :], s,
                  -1e30)
    s_new = (jnp.einsum("bhc,bc->bh", q_abs, c_new.astype(jnp.float32))
             + jnp.einsum("bhr,br->bh", q_rope.astype(jnp.float32),
                          kr_new.astype(jnp.float32))) * scale
    mmax = jnp.maximum(jnp.max(s, axis=-1), s_new)               # [B, H]
    pcache = jnp.exp(s - mmax[..., None])
    pnew = jnp.exp(s_new - mmax)
    denom = jnp.sum(pcache, axis=-1) + pnew
    ctx_c = (jnp.einsum("bhs,bsc->bhc", pcache, ckv.astype(jnp.float32))
             + pnew[..., None] * c_new.astype(jnp.float32)[:, None, :]) \
        / denom[..., None]                                        # [B,H,512]
    o = jnp.einsum("bhc,chd->bhd", ctx_c, w_uv.astype(jnp.float32))
    out = o.reshape(B, H * m.dh_v).astype(h.dtype) @ p["wo"]
    slot = (pos % ckv.shape[1]).astype(jnp.int32)
    newc = {
        "ckv": lax.dynamic_update_slice(ckv, c_new[:, None].astype(ckv.dtype),
                                        (0, slot, 0)),
        "krope": lax.dynamic_update_slice(
            krope, kr_new[:, None].astype(krope.dtype), (0, slot, 0)),
    }
    return out, newc


def block_decode(p: dict, x: jnp.ndarray, cache: dict, bcfg: BlockCfg,
                 cfg: ModelConfig, rt: Runtime, pos, enc_out=None
                 ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode.  x [B, D]."""
    plus_one = cfg.norm == "rms"
    newc = dict(cache)
    if bcfg.mixer in ("attn", "mla"):
        h = _norm(x, p["ln1"], cfg.norm, plus_one)
        if bcfg.mixer == "attn":
            o, upd = _attn_decode(p["attn"], h, cache, cfg, bcfg, rt, pos)
        else:
            o, upd = _mla_decode(p["attn"], h, cache, cfg, rt, pos)
        newc.update(upd)
        if cfg.post_norms:
            o = _norm(o, p["post_ln1"], cfg.norm, plus_one)
        x = x + o
    elif bcfg.mixer == "mamba":
        h = _norm(x, p["ln1"], cfg.norm, plus_one)
        o, upd = mamba_decode_step(p["mamba"], h, cache, cfg.mamba)
        newc.update(upd)
        x = x + o
    if bcfg.cross_attn:
        h = _norm(x, p["ln_x"], cfg.norm, plus_one)
        o, _ = _attn_fwd(p["xattn"], h[:, None], cfg, bcfg,
                         jnp.zeros((x.shape[0], 1), jnp.int32),
                         kv_override=enc_out)
        x = x + o[:, 0]
    if bcfg.ffn != "none":
        h = _norm(x, p["ln2"], cfg.norm, plus_one)
        o = _ffn_fwd(p, h[:, None], cfg, bcfg, rt)[:, 0]
        if cfg.post_norms:
            o = _norm(o, p["post_ln2"], cfg.norm, plus_one)
        x = x + o
    return x, newc
