"""Mamba-2 (SSD) mixer: chunked-scan training path, recurrent decode path.

The training/prefill path evaluates the SSD chunk algebra with a
``lax.scan`` over chunks (identical math to the Pallas kernel in
``repro.kernels.ssd_scan``; the kernel is selected with ``impl='kernel'``
on TPU runtimes).  Sub-quadratic in sequence length: O(S*L) with chunk
length L, which is what makes the 500k-token cells feasible.

Decode is the O(1)-per-token recurrence on the [H, N, P] state plus the
width-4 depthwise-conv ring buffer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import dense_init, rms_norm

__all__ = ["MambaConfig", "mamba_params", "mamba_apply", "mamba_decode_step",
           "mamba_init_cache"]

CONV_W = 4


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 128          # N
    expand: int = 2
    head_dim: int = 64          # P
    n_groups: int = 1           # G
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_params(key, cfg: MambaConfig, dtype=jnp.float32) -> dict:
    """Per-component input projections (z, x, B, C, dt) instead of the
    reference's fused in_proj: each projection then has a TP-shardable
    output dim (the fused inner dim 2*Di+2*G*N+H rarely divides a mesh
    axis)."""
    ks = jax.random.split(key, 8)
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    return {
        "in_z": dense_init(ks[0], (cfg.d_model, di), dtype=dtype),
        "in_x": dense_init(ks[1], (cfg.d_model, di), dtype=dtype),
        "in_b": dense_init(ks[2], (cfg.d_model, g * n), dtype=dtype),
        "in_c": dense_init(ks[3], (cfg.d_model, g * n), dtype=dtype),
        "in_dt": dense_init(ks[4], (cfg.d_model, h), dtype=dtype),
        "conv_w": dense_init(ks[5], (CONV_W, cfg.conv_dim), dtype=dtype,
                             scale=1.0),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[6], (di, cfg.d_model), dtype=dtype),
    }


def _project(params, x_in):
    return (x_in @ params["in_z"], x_in @ params["in_x"],
            x_in @ params["in_b"], x_in @ params["in_c"],
            x_in @ params["in_dt"])


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv, width CONV_W.  xbc [B, S, C]."""
    pad = jnp.pad(xbc, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(CONV_W))
    return jax.nn.silu(out + b[None, None, :])


def _ssd_chunked(x, dt, a, bmat, cmat, cfg: MambaConfig,
                 state0: Optional[jnp.ndarray] = None):
    """Chunk-parallel SSD (same algebra as the Pallas kernel), scanning
    chunks.  x [B,S,H,P]; dt [B,S,H]; a [H]; b/c [B,S,G,N].
    Returns (y, final_state [B,H,N,P])."""
    B, S, H, P = x.shape
    G, N = bmat.shape[2], bmat.shape[3]
    L = min(cfg.chunk, S)
    nc = S // L
    assert S % L == 0, (S, L)
    hg = H // G

    # NOTE(perf, refuted): casting the matmul operands to bf16 was tried
    # (§Perf B-iter2) — correct on TPU, but the CPU-derived traffic
    # census regressed 14% from legalisation copies and bf16 noise broke
    # the 1e-4 oracle tolerance; kept in f32.
    xf = x.astype(jnp.float32).reshape(B, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, L, H)
    bf = bmat.astype(jnp.float32).reshape(B, nc, L, G, N)
    cf = cmat.astype(jnp.float32).reshape(B, nc, L, G, N)
    bf = jnp.repeat(bf, hg, axis=3)      # [B,nc,L,H,N]
    cf = jnp.repeat(cf, hg, axis=3)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(state, inp):
        xc, dtc, bc, cc = inp            # [B,L,H,P], [B,L,H], [B,L,H,N] x2
        dA = dtc * a[None, None, :]      # [B,L,H]
        cum = jnp.cumsum(dA, axis=1)     # [B,L,H]
        cb = jnp.einsum("bihn,bjhn->bhij", cc, bc)
        seg = cum.transpose(0, 2, 1)[:, :, :, None] \
            - cum.transpose(0, 2, 1)[:, :, None, :]        # [B,H,i,j]
        # clamp the non-causal (positive) segment sums *before* exp so the
        # masked entries cannot poison the backward pass with inf * 0
        seg = jnp.where(causal[None, None], seg, -1e30)
        m = cb * jnp.exp(seg) * dtc.transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhij,bjhp->bihp", m, xc)
        # inter-chunk
        y += jnp.einsum("bihn,bhnp,bih->bihp", cc, state, jnp.exp(cum))
        cl = cum[:, -1, :]               # [B,H]
        decay_end = jnp.exp(cl[:, None, :] - cum) * dtc       # [B,L,H]
        s_new = jnp.exp(cl)[:, :, None, None] * state \
            + jnp.einsum("bjhn,bjhp->bhnp", bc * decay_end[..., None], xc)
        return s_new, y

    st0 = state0 if state0 is not None else jnp.zeros((B, H, N, P),
                                                      jnp.float32)
    stf, ys = lax.scan(chunk_step, st0,
                       (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                        jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y, stf


def mamba_apply(params: dict, x_in: jnp.ndarray, cfg: MambaConfig, *,
                impl: str = "chunked") -> jnp.ndarray:
    """Full Mamba-2 block (minus the outer residual): x [B, S, D]."""
    B, S, D = x_in.shape
    h, p, g, n = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z, xr, b, c, dt = _project(params, x_in)
    xbc = jnp.concatenate([xr, b, c], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xr = xbc[..., :cfg.d_inner]
    b = xbc[..., cfg.d_inner:cfg.d_inner + g * n]
    c = xbc[..., cfg.d_inner + g * n:]

    dt_v = jax.nn.softplus(dt.astype(jnp.float32)
                           + params["dt_bias"][None, None, :])  # [B,S,H]
    a = -jnp.exp(params["a_log"])                                # [H]
    xh = xr.reshape(B, S, h, p)
    bg = b.reshape(B, S, g, n)
    cg = c.reshape(B, S, g, n)

    if impl == "kernel":
        from repro.kernels.ssd_scan.ops import ssd
        y = ssd(xh, dt_v, a, bg, cg, chunk=cfg.chunk)
    else:
        y, _ = _ssd_chunked(xh, dt_v, a, bg, cg, cfg)
    y = y.astype(x_in.dtype) + xh.astype(x_in.dtype) \
        * params["d_skip"].astype(x_in.dtype)[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    return y @ params["out_proj"]


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def mamba_init_cache(batch: int, cfg: MambaConfig, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, cfg.conv_dim), dtype),
    }


def mamba_decode_step(params: dict, x_t: jnp.ndarray, cache: dict,
                      cfg: MambaConfig) -> Tuple[jnp.ndarray, dict]:
    """x_t [B, D] one token.  Returns (y [B, D], new cache)."""
    B, D = x_t.shape
    h, p, g, n = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z, xr, b, c, dt = _project(params, x_t)
    xbc = jnp.concatenate([xr, b, c], axis=-1)                # [B, conv_dim]
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = sum(window[:, i, :] * params["conv_w"][i][None, :]
               for i in range(CONV_W))
    xbc = jax.nn.silu(conv + params["conv_b"][None, :])
    xr = xbc[:, :cfg.d_inner]
    b = xbc[:, cfg.d_inner:cfg.d_inner + g * n].reshape(B, g, n)
    c = xbc[:, cfg.d_inner + g * n:].reshape(B, g, n)

    dt_v = jax.nn.softplus(dt.astype(jnp.float32)
                           + params["dt_bias"][None, :])      # [B, H]
    a = -jnp.exp(params["a_log"])
    xh = xr.reshape(B, h, p).astype(jnp.float32)
    hg = h // g
    bh = jnp.repeat(b, hg, axis=1).astype(jnp.float32)        # [B, H, N]
    ch = jnp.repeat(c, hg, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt_v * a[None, :])                        # [B, H]
    upd = jnp.einsum("bhn,bhp->bhnp", bh, xh * dt_v[..., None])
    ssm = decay[:, :, None, None] * cache["ssm"] + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, ssm)
    y = y.astype(x_t.dtype) + xh.astype(x_t.dtype) \
        * params["d_skip"].astype(x_t.dtype)[None, :, None]
    y = y.reshape(B, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = (y @ params["out_proj"]).astype(x_t.dtype)
    new_cache = {"ssm": ssm, "conv": window[:, 1:, :]}
    return out, new_cache
