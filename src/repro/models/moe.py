"""Mixture-of-Experts block with expert parallelism over the model axis.

Experts are sharded over ``model`` (EP); activations arrive replicated
over ``model`` (the Megatron block pattern), so *no token ever moves*:
each shard runs its local experts on the tokens routed to them
(capacity-padded gather), and the weighted combine is part of the same
output all-reduce the dense MLP already pays.  This is the TPU-native
realisation of the paper's "sparse h-relation" — the communication volume
is independent of the routing pattern, which is exactly the
model-compliance property LPF demands (worst-case h == realised h).

The expert count is padded up to a multiple of the EP degree; padding
experts are masked to -inf router logits.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import compat

__all__ = ["MoEConfig", "moe_params", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int            # logical experts (pre-padding)
    top_k: int
    capacity_factor: float = 1.25
    ep_degree: int = 1        # model-axis size at runtime
    router_dtype: str = "float32"

    @property
    def padded_experts(self) -> int:
        e = self.n_experts
        d = max(self.ep_degree, 1)
        return -(-e // d) * d


def moe_params(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    from .common import dense_init
    ep = cfg.padded_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (cfg.d_model, ep), dtype=jnp.float32),
        "w_gate": dense_init(k2, (ep, cfg.d_model, cfg.d_ff), in_axis=1,
                             dtype=dtype),
        "w_up": dense_init(k3, (ep, cfg.d_model, cfg.d_ff), in_axis=1,
                           dtype=dtype),
        "w_down": dense_init(k4, (ep, cfg.d_ff, cfg.d_model), in_axis=1,
                             dtype=dtype),
    }


def _local_expert_ffn(x_e, wg, wu, wd):
    """x_e [C, D] tokens for one expert -> [C, D]."""
    h = jax.nn.silu(x_e @ wg) * (x_e @ wu)
    return h @ wd


def moe_apply(params: dict, x: jnp.ndarray, cfg: MoEConfig, *,
              mesh, model_axis: str = "model",
              dp_axes: Tuple[str, ...] = ("pod", "data")) -> jnp.ndarray:
    """x [B, S, D] (replicated over model axis) -> [B, S, D].

    Runs under shard_map manual over ``model`` only; batch/seq dims keep
    their GSPMD sharding over the dp axes.
    """
    B, S, D = x.shape
    E = cfg.padded_experts
    k = cfg.top_k

    def body(xb, router, wg, wu, wd):
        # xb [B_l, S, D] (local over dp via auto sharding handled outside;
        # here manual over model only: full B,S view, local experts)
        Bl = xb.shape[0]
        T = Bl * S
        xt = xb.reshape(T, D)
        logits = (xt.astype(jnp.float32) @ router)            # [T, E]
        if E > cfg.n_experts:
            pad_mask = jnp.arange(E) >= cfg.n_experts
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        gate_vals, gate_idx = lax.top_k(logits, min(k, E))            # [T, k]
        gates = jax.nn.softmax(gate_vals, axis=-1)            # [T, k]

        e_local = wg.shape[0]                                  # E / ep
        shard = lax.axis_index(model_axis)
        first = shard * e_local
        cap = min(T, max(8, int(cfg.capacity_factor * k * T / E)))
        cap = max(1, min(cap, T))

        out = jnp.zeros((T, D), jnp.float32)
        for le in range(e_local):
            ge = first + le
            # weight of this expert per token (0 if not routed here)
            w_tok = jnp.sum(jnp.where(gate_idx == ge, gates, 0.0), axis=1)
            # capacity-padded token selection: take the top-`cap` weights
            sel_w, sel_idx = lax.top_k(w_tok, cap)            # [cap]
            x_e = jnp.take(xt, sel_idx, axis=0)               # [cap, D]
            y_e = _local_expert_ffn(x_e.astype(wg.dtype), wg[le], wu[le],
                                    wd[le]).astype(jnp.float32)
            out = out.at[sel_idx].add(y_e * sel_w[:, None])
        # combine across expert shards (the Megatron-style all-reduce)
        out = lax.psum(out, model_axis)
        return out.reshape(Bl, S, D).astype(xb.dtype)

    dp = tuple(dp_axes) or None
    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None),
                  P(None, None),
                  P(model_axis, None, None),
                  P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
