"""The language model: embed -> scanned block groups -> head, with
training loss, prefill and single-token decode, for decoder-only,
encoder-decoder (audio), and stub-multimodal (vision) architectures.

Batch conventions
-----------------
train:  {"tokens" [B, St] i32, "labels" [B, St] i32 (-1 = ignore),
         optional "embeds" [B, P, D] (vision stub, prepended),
         optional "frames" [B, Se, D] (audio stub -> encoder)}
decode: serve_step(params, token [B] i32, caches, pos scalar, enc_out?)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .blocks import (Runtime, block_apply, block_decode, block_init_cache,
                     block_params)
from .common import dense_init, layer_norm, rms_norm, sinusoidal_positions
from .config import Group, ModelConfig

__all__ = ["init_params", "forward", "loss_fn", "init_caches",
           "prefill", "decode_step", "count_params", "model_flops"]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _group_params(key, g: Group, cfg: ModelConfig, dtype) -> dict:
    def one_layer(k):
        ks = jax.random.split(k, len(g.blocks))
        return {f"b{i}": block_params(ks[i], b, cfg, dtype)
                for i, b in enumerate(g.blocks)}
    keys = jax.random.split(key, g.repeats)
    return jax.vmap(one_layer)(keys)


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8 + len(cfg.groups) + len(cfg.encoder_groups))
    p: Dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_padded, cfg.d_model),
                            in_axis=1, dtype=dtype),
        "final_norm": {"w": jnp.ones((cfg.d_model,), jnp.float32)}
        if cfg.norm == "rms" else
        {"w": jnp.ones((cfg.d_model,), jnp.float32),
         "b": jnp.zeros((cfg.d_model,), jnp.float32)},
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_padded),
                               dtype=dtype)
    if cfg.pos_embed == "learned":
        p["pos_embed"] = dense_init(ks[2], (cfg.max_seq, cfg.d_model),
                                    in_axis=1, dtype=dtype)
    for i, g in enumerate(cfg.groups):
        p[f"dec_{g.name}"] = _group_params(ks[4 + i], g, cfg, dtype)
    for i, g in enumerate(cfg.encoder_groups):
        p[f"enc_{g.name}"] = _group_params(
            ks[4 + len(cfg.groups) + i], g, cfg, dtype)
    if cfg.encoder_groups:
        p["enc_final_norm"] = {"w": jnp.ones((cfg.d_model,), jnp.float32),
                               "b": jnp.zeros((cfg.d_model,), jnp.float32)} \
            if cfg.norm == "layer" else \
            {"w": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.mtp:
        p["mtp_proj"] = dense_init(ks[3], (2 * cfg.d_model, cfg.d_model),
                                   dtype=dtype)
        p["mtp_block"] = block_params(
            ks[3], cfg.groups[-1].blocks[-1], cfg, dtype)
    return p


# --------------------------------------------------------------------------
# scan-group execution
# --------------------------------------------------------------------------

def _cast_params(tree, cdt):
    """Cast weight matrices to the compute dtype; norms/scalars stay f32.
    Applied per-layer *inside* scan bodies so the FSDP all-gather moves
    bf16 and the backward's reduce-scatter stays inside the loop (casting
    the whole stacked tree outside the scan strands an unsharded f32
    gradient accumulator)."""
    def one(a):
        if a.dtype == jnp.int8 and a.ndim > 1:
            # serving quantization: int8-at-rest, dequantised at use (the
            # per-tensor scale is folded into the stored values for the
            # dry-run; a production loader carries explicit scales)
            return a.astype(cdt) * jnp.asarray(0.01, cdt)
        if a.dtype in (jnp.float32, jnp.bfloat16) and a.ndim > 1:
            return a.astype(cdt)
        return a
    return jax.tree.map(one, tree)


def _scan_group(gp, x, g: Group, cfg: ModelConfig, rt: Runtime, positions,
                enc_out=None):
    cdt = _dtype(cfg.compute_dtype)

    def body(carry, layer_p):
        h = carry
        layer_p = _cast_params(layer_p, cdt)
        for i, b in enumerate(g.blocks):
            h = block_apply(layer_p[f"b{i}"], h, b, cfg, rt, positions,
                            enc_out)
        # the carry is what remat saves per layer: keep it SP-sharded
        return _constrain_act(h, rt), None

    if cfg.remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.unroll_layers:
        # python loop: identical math, layer-count-proportional HLO (used
        # by the dry-run's cost-analysis calibration; see launch/dryrun)
        for l in range(g.repeats):
            x, _ = body(x, jax.tree.map(lambda a: a[l], gp))
        return x
    x, _ = lax.scan(body, x, gp)
    return x


def _constrain_act(x, rt: Runtime):
    """Pin hidden states to the canonical activation sharding at layer and
    group boundaries: batch over the dp axes and — when sequence
    parallelism is on — sequence over the model axis (Megatron-SP: the
    TP all-reduce splits into reduce-scatter + all-gather with identical
    wire bytes, while resident activations and remat-saved layer inputs
    shrink by the TP degree).  Without the pin, GSPMD's propagation
    through scan bodies can drift into a layout that forces large
    re-materialisation at the head."""
    if rt is None or rt.mesh is None or not rt.dp_axes:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    sp = (getattr(rt, "sp", False) and rt.model_axis and x.ndim >= 3
          and x.shape[1] % rt.mesh.shape[rt.model_axis] == 0)
    if sp:
        spec = P(rt.dp_axes, rt.model_axis, *([None] * (x.ndim - 2)))
    else:
        spec = P(rt.dp_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, spec))


def _final_norm(x, p, cfg):
    if cfg.norm == "layer":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], plus_one=True)


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(params, x, cfg: ModelConfig, rt: Optional[Runtime] = None):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ w).astype(jnp.float32)
    if rt is not None and rt.mesh is not None and rt.model_axis:
        # keep the vocab dim model-sharded through the loss
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(rt.dp_axes or None, *([None] * (logits.ndim - 2)),
                 rt.model_axis)
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(rt.mesh, spec))
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.vocab_padded != cfg.vocab:
        # vocab-padding columns must never win softmax/argmax
        logits = jnp.where(jnp.arange(cfg.vocab_padded) >= cfg.vocab,
                           -1e30, logits)
    return logits


def _run_encoder(params, frames, cfg: ModelConfig, rt: Runtime):
    x = frames.astype(_dtype(cfg.compute_dtype))
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], x.shape[:2])
    x = _constrain_act(x, rt)
    for g in cfg.encoder_groups:
        x = _scan_group(params[f"enc_{g.name}"], x, g, cfg, rt, positions,
                        None)
        x = _constrain_act(x, rt)
    return _final_norm(x, params["enc_final_norm"], cfg)


def forward(params, batch: dict, cfg: ModelConfig, rt: Runtime
            ) -> jnp.ndarray:
    """Training/prefill forward -> logits [B, S, V] (f32)."""
    cdt = _dtype(cfg.compute_dtype)
    params = {k: (v if k.startswith(("dec_", "enc_")) else
                  _cast_params(v, cdt)) for k, v in params.items()}
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg).astype(cdt)
    if cfg.modality == "vision" and "embeds" in batch:
        x = jnp.concatenate([batch["embeds"].astype(cdt), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"][:S][None].astype(cdt)
    elif cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_positions(S, cfg.d_model)[None].astype(cdt)

    enc_out = None
    if cfg.encoder_groups:
        enc_out = _run_encoder(params, batch["frames"], cfg, rt)

    x = _constrain_act(x, rt)
    for g in cfg.groups:
        x = _scan_group(params[f"dec_{g.name}"], x, g, cfg, rt, positions,
                        enc_out)
        x = _constrain_act(x, rt)
    x = _final_norm(x, params["final_norm"], cfg)
    if cfg.modality == "vision" and "embeds" in batch:
        x = x[:, batch["embeds"].shape[1]:]  # logits over text positions
    logits = _head(params, x, cfg, rt)
    if cfg.mtp:
        # multi-token prediction: combine h_t with embed(token_{t+1})
        emb_next = jnp.roll(_embed_tokens(params, tokens, cfg), -1, axis=1)
        h_mtp = jnp.concatenate([x.astype(cdt), emb_next.astype(cdt)],
                                axis=-1) @ params["mtp_proj"]
        h_mtp = block_apply(params["mtp_block"], h_mtp,
                            cfg.groups[-1].blocks[-1], cfg, rt, positions)
        logits_mtp = _head(params, _final_norm(
            h_mtp, params["final_norm"], cfg), cfg, rt)
        return logits, logits_mtp
    return logits


def loss_fn(params, batch: dict, cfg: ModelConfig, rt: Runtime):
    """Mean next-token cross-entropy (labels -1 are masked)."""
    out = forward(params, batch, cfg, rt)
    logits_mtp = None
    if cfg.mtp:
        logits, logits_mtp = out
    else:
        logits = out
    labels = batch["labels"]

    def xent(lg, lb):
        # one-hot einsum keeps the vocab dim sharded (take_along_axis over
        # a model-sharded vocab would all-gather the logits)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(lb, 0), lg.shape[-1],
                                dtype=lg.dtype)
        picked = jnp.einsum("bsv,bsv->bs", lg, onehot)
        mask = (lb >= 0).astype(jnp.float32)
        return jnp.sum((lse - picked) * mask) / jnp.maximum(
            jnp.sum(mask), 1.0)

    loss = xent(logits, labels)
    if logits_mtp is not None:
        labels2 = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1)
        loss = loss + 0.3 * xent(logits_mtp, labels2)
    return loss


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                dtype=None) -> dict:
    dtype = dtype or _dtype(cfg.compute_dtype)

    caches: Dict[str, Any] = {}
    for g in cfg.groups:
        def one_layer(_):
            return {f"b{i}": block_init_cache(b, cfg, batch, cache_len,
                                              dtype)
                    for i, b in enumerate(g.blocks)}
        caches[g.name] = jax.vmap(one_layer)(jnp.arange(g.repeats))
    return caches


def decode_step(params, token: jnp.ndarray, caches: dict, pos, cfg: ModelConfig,
                rt: Runtime, enc_out=None):
    """One greedy decode step.  token [B] i32; pos scalar i32 (absolute
    position of the new token; cache writes roll modulo cache length).
    Returns (next_token [B], logits [B, V], new caches)."""
    cdt = _dtype(cfg.compute_dtype)
    params = {k: (v if k.startswith(("dec_", "enc_")) else
                  _cast_params(v, cdt)) for k, v in params.items()}
    x = _embed_tokens(params, token, cfg).astype(cdt)
    if cfg.pos_embed == "learned":
        x = x + params["pos_embed"][
            jnp.minimum(pos, cfg.max_seq - 1)][None].astype(cdt)

    new_caches = {}
    for g in cfg.groups:
        gp = params[f"dec_{g.name}"]
        gc = caches[g.name]

        def body(carry, xs):
            h = carry
            layer_p, layer_c = xs
            layer_p = _cast_params(layer_p, cdt)
            newc = {}
            for i, b in enumerate(g.blocks):
                h, c = block_decode(layer_p[f"b{i}"], h, layer_c[f"b{i}"],
                                    b, cfg, rt, pos, enc_out)
                newc[f"b{i}"] = c
            return h, newc

        if cfg.unroll_layers:
            ncs = []
            for l in range(g.repeats):
                x, c_l = body(x, (jax.tree.map(lambda a: a[l], gp),
                                  jax.tree.map(lambda a: a[l], gc)))
                ncs.append(c_l)
            nc = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)
        else:
            x, nc = lax.scan(body, x, (gp, gc))
        new_caches[g.name] = nc
    x = _final_norm(x, params["final_norm"], cfg)
    logits = _head(params, x, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, logits, new_caches


def prefill(params, batch: dict, cfg: ModelConfig, rt: Runtime):
    """Prefill = the forward pass producing last-position logits.  (Cache
    population during prefill shares the forward path; the dry-run's
    prefill cell measures exactly this compute.)"""
    out = forward(params, batch, cfg, rt)
    logits = out[0] if cfg.mtp else out
    return logits[:, -1]


# --------------------------------------------------------------------------
# accounting
# --------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(
        partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    total = 0
    moe_total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += n
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if "moe" in keys and any(
                str(k).startswith("w_") for k in keys):
            moe_total += n
    if not active_only or cfg.moe is None:
        return total
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - moe_total + moe_total * frac)


def model_flops(cfg: ModelConfig, tokens: int) -> float:
    """6*N*D useful-training flops (6*N_active*D for MoE); for serve cells
    the caller divides by 3 (forward only)."""
    n = count_params(cfg, active_only=True)
    return 6.0 * n * tokens
