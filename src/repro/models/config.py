"""Model configuration: heterogeneous layer patterns as scan groups.

A model is a sequence of *groups*; each group is a repeating unit of
block configs executed under one ``lax.scan`` (stacked params), so HLO
size is independent of depth — an 80-layer model compiles like a 2-layer
one.  Heterogeneous architectures express their period as the unit:
gemma-2 scans (local, global) pairs, jamba scans its 8-layer
mamba/attention/MoE period, deepseek scans a dense prefix then the MoE
body.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .mamba import MambaConfig
from .moe import MoEConfig

__all__ = ["BlockCfg", "Group", "MLACfg", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    mixer: str = "attn"            # attn | mla | mamba | none
    ffn: str = "dense"             # dense | moe | none
    causal: bool = True
    window: Optional[int] = None   # sliding-window (local) attention
    cross_attn: bool = False       # decoder block attending to encoder


@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    blocks: Tuple[BlockCfg, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.blocks) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    groups: Tuple[Group, ...]
    # attention geometry
    n_heads: int = 8
    n_kv: int = 8
    head_dim: Optional[int] = None
    d_ff: int = 0
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    norm: str = "rms"              # rms | layer
    post_norms: bool = False       # gemma-2 sandwich norms
    pos_embed: str = "rope"        # rope | sinusoidal | learned | none
    tie_embeddings: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaConfig] = None
    shared_expert: bool = False    # deepseek shared expert alongside MoE
    # enc-dec
    encoder_groups: Tuple[Group, ...] = ()
    # modality stub: input embeddings are provided directly for the first
    # `stub_prefix` positions (vision patches / audio frames)
    modality: str = "none"         # none | vision | audio
    stub_prefix: int = 0
    # multi-token prediction (deepseek): extra next-next-token head
    mtp: bool = False
    scale_embed: bool = False      # gemma: embeddings scaled by sqrt(d)
    # execution policy
    unroll_layers: bool = False    # python-loop groups (FLOP calibration)
    attn_impl: str = "blocked"
    q_chunk: int = 512
    remat: str = "full"            # full | dots | none
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    max_seq: int = 8192            # RoPE/learned-position capacity

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables padded to a 256 multiple so the vocab dim
        divides any production mesh axis; padded logits are masked."""
        return -(-self.vocab // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.groups) \
            + sum(g.n_layers for g in self.encoder_groups)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline flops)."""
        from . import lm
        return lm.count_params(self)

    def active_param_count(self) -> int:
        from . import lm
        return lm.count_params(self, active_only=True)
