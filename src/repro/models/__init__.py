"""Model zoo: composable blocks (GQA/MLA attention, SwiGLU, MoE-EP,
Mamba-2 SSD) assembled into decoder-only / enc-dec LMs via scan groups."""

from .blocks import Runtime
from .config import BlockCfg, Group, MLACfg, ModelConfig
from .lm import (count_params, decode_step, forward, init_caches,
                 init_params, loss_fn, model_flops, prefill)
from .mamba import MambaConfig
from .moe import MoEConfig

__all__ = [
    "Runtime", "BlockCfg", "Group", "MLACfg", "ModelConfig",
    "MambaConfig", "MoEConfig",
    "init_params", "forward", "loss_fn", "prefill", "decode_step",
    "init_caches", "count_params", "model_flops",
]
