"""repro: LPF-on-JAX multi-pod training/inference framework."""
