"""The async overlap engine: split-phase supersteps, the overlap cost
term, and the optimizer's overlap grouping.

The overlap rewrite schedules adjacent compute-independent supersteps
that the merge gate keeps separate (differing attrs, or a merged plan
the model prices higher) as start/done pairs: all members read the
group-entry slot state and launch their collectives back-to-back, then
apply their writes.  Its ledger entry is
``max_i(h_i)g + max_i(rounds_i)l + (k-1)*l_overlap``.  These tests
check the grouping is sound (members must commute — we simulate them in
reversed order and demand bit-identical slots), the gate never
regresses the predicted schedule, and the XLA execution path ledgers
exactly the planned overlap cost.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (LPF_SYNC_DEFAULT, Msg, OVERLAP_L_FRACTION,
                        OVERLAPPABLE_METHODS, ProgramStep, Slot,
                        SuperstepCost, SyncAttributes, optimize_program,
                        overlap_cost, plan_sync, simulate_program)
from repro.core.machine import CPU_HOST, probe
from repro.core.program import trace_slot_map

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.fast

MACHINE = probe({"x": 8}, CPU_HOST)


def table_property(fn):
    if HAVE_HYPOTHESIS:
        return settings(deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(60))(fn)


def make_slot(sid, size, dtype="int32", kind="global"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind=kind, orig_shape=(size,))


# ---------------------------------------------------------------------------
# the overlap cost term
# ---------------------------------------------------------------------------

def _cost(wire, rounds, h=None, n_msgs=4, method="direct"):
    return SuperstepCost(label="", h_bytes=h if h is not None else wire,
                         wire_bytes=wire, total_wire_bytes=wire * 4,
                         rounds=rounds, n_msgs=n_msgs, method=method)


def test_overlap_cost_fields():
    a, b = _cost(100, 1, method="fused_ag"), _cost(40, 2, method="fused_rs")
    c = overlap_cost([a, b], label="a||b")
    assert c.wire_bytes == 100          # max: one wire hides the other
    assert c.h_bytes == 100
    assert c.total_wire_bytes == a.total_wire_bytes + b.total_wire_bytes
    assert c.rounds == 2                # shared barrier: max of members
    assert c.n_msgs == a.n_msgs + b.n_msgs
    assert c.overlap_extra == 1
    assert c.method == "overlap[fused_ag+fused_rs]"
    # max(h_a,h_b)*g + max(r)*l + l_overlap
    expect = (100 * MACHINE.g + 2 * MACHINE.l
              + OVERLAP_L_FRACTION * MACHINE.l)
    assert abs(c.predicted_seconds(MACHINE) - expect) < 1e-18
    # a single-member "group" degenerates to the member itself
    solo = overlap_cost([a], label="x")
    assert solo == dataclasses.replace(a, label="x")
    with pytest.raises(ValueError):
        overlap_cost([])


def test_overlap_cost_beats_sequential_iff_nontrivial():
    a, b = _cost(100, 1), _cost(40, 1)
    seq = a.predicted_seconds(MACHINE) + b.predicted_seconds(MACHINE)
    assert overlap_cost([a, b]).predicted_seconds(MACHINE) < seq
    # overlapping a zero-cost noop only adds issue latency — worse
    noop = _cost(0, 0, n_msgs=0, method="noop")
    seq2 = a.predicted_seconds(MACHINE) + noop.predicted_seconds(MACHINE)
    assert overlap_cost([a, noop]).predicted_seconds(MACHINE) > seq2


# ---------------------------------------------------------------------------
# optimizer overlap grouping
# ---------------------------------------------------------------------------

def _rs_ag_trace(p, n_buckets, w=4):
    """The DDP bucket shape: per bucket, a fused reduce-scatter into a
    chunk slot, then a fused all-gather of the chunks — adjacent
    cross-bucket supersteps are independent, in-bucket ones are not."""
    steps = []
    sid = 100
    for k in range(n_buckets):
        src = make_slot(sid, p * w)
        buf = make_slot(sid + 1, w)
        out = make_slot(sid + 2, p * w)
        sid += 3
        rs = tuple(Msg(s, d, src, d * w, buf, 0, w, origin="table")
                   for s in range(p) for d in range(p))
        ag = tuple(Msg(s, d, buf, 0, out, s * w, w, origin="table")
                   for s in range(p) for d in range(p))
        steps.append(ProgramStep(rs, SyncAttributes(reduce_op="sum"),
                                 f"b{k}.rs"))
        steps.append(ProgramStep(ag, LPF_SYNC_DEFAULT, f"b{k}.ag"))
    return steps


def test_ddp_bucket_chain_overlaps():
    """[rs0, ag0, rs1, ag1, rs2, ag2]: the DAG list-scheduler hoists the
    cross-bucket reduce-scatters together (they are mutually ready and
    commute) and then the all-gathers: [rs0||rs1||rs2][ag0||ag1||ag2] —
    never overlapping a bucket's own all-gather with its reduce-scatter
    (data dependence).  The adjacent-only peephole could only reach
    [rs0][ag0||rs1][ag1||rs2][ag2]; the searched schedule must beat it."""
    p = 4
    steps = _rs_ag_trace(p, 3)
    prog = optimize_program(steps, p, MACHINE)
    assert [s.plan.method for s in prog.steps] == \
        ["fused_rs"] * 3 + ["fused_ag"] * 3
    assert prog.overlap_groups == ((0, 1, 2), (3, 4, 5))
    assert prog.n_overlapped == 4
    assert prog.n_merged == 0           # differing attrs: merge refused
    assert prog.n_hoisted >= 2          # rs2/ag2 hoists were non-adjacent
    # the overlapped schedule is predicted strictly faster than both the
    # sequential trace and the adjacent-only peephole's schedule
    seq = sum(s.plan.cost.predicted_seconds(MACHINE) for s in prog.steps)
    assert prog.predicted_seconds(MACHINE) < seq
    peephole = optimize_program(steps, p, MACHINE, search=False)
    assert peephole.overlap_groups == ((0,), (1, 2), (3, 4), (5,))
    assert prog.predicted_seconds(MACHINE) < \
        peephole.predicted_seconds(MACHINE)


def test_dependent_steps_never_overlap():
    p = 4
    A, B, C = make_slot(1, 16), make_slot(2, 16), make_slot(3, 16)
    w1 = ProgramStep((Msg(0, 1, A, 0, B, 0, 4),),
                     SyncAttributes(reduce_op="sum"), "w1")
    # reads what w1 wrote -> must stay sequential
    r1 = ProgramStep((Msg(1, 2, B, 0, C, 0, 4),), LPF_SYNC_DEFAULT, "r1")
    prog = optimize_program([w1, r1], p, MACHINE)
    assert prog.overlap_groups == ((0,), (1,))
    # overlapping destination writes -> must stay sequential (WAW)
    w2 = ProgramStep((Msg(2, 1, A, 4, B, 2, 4),), LPF_SYNC_DEFAULT, "w2")
    prog2 = optimize_program([w1, w2], p, MACHINE)
    assert prog2.overlap_groups == ((0,), (1,))


def test_valiant_excluded_from_overlap():
    assert "valiant" not in OVERLAPPABLE_METHODS
    for m in ("direct", "fused", "fused_ag", "fused_rs", "fused_scatter",
              "fused_gather", "bruck", "seq", "noop"):
        assert m in OVERLAPPABLE_METHODS


# ---------------------------------------------------------------------------
# differential properties: overlapped traces preserve semantics
# ---------------------------------------------------------------------------

def random_program(seed):
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 8))
    slots = [make_slot(100 + i, int(rng.integers(8, 25)), "int32")
             for i in range(int(rng.integers(2, 5)))]
    steps = []
    for k in range(int(rng.integers(2, 7))):
        reduce_op = [None, None, None, "sum", "max", "min"][
            int(rng.integers(6))]
        attrs = SyncAttributes(
            method=["auto", "direct"][int(rng.integers(2))],
            reduce_op=reduce_op)
        msgs = []
        for _ in range(int(rng.integers(0, 9))):
            a = slots[int(rng.integers(len(slots)))]
            b = slots[int(rng.integers(len(slots)))]
            size = int(rng.integers(1, min(a.size, b.size) + 1))
            msgs.append(Msg(
                src=int(rng.integers(p)), dst=int(rng.integers(p)),
                src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
                dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
                size=size))
        steps.append(ProgramStep(tuple(msgs), attrs, f"s{k}"))
    return p, slots, steps


def initial_values(slots, p, seed):
    rng = np.random.default_rng(seed + 1)
    return {s.sid: rng.integers(-10_000, 10_000,
                                size=(p, s.size)).astype(np.int32)
            for s in slots}


@table_property
def test_overlap_groups_commute_bit_for_bit(seed):
    """Overlap is only sound if group members commute: executing each
    group's members in REVERSED order must leave every slot bit-identical
    to eager superstep-by-superstep execution."""
    p, slots, steps = random_program(seed)
    prog = optimize_program(steps, p, MACHINE)
    covered = sorted(i for grp in prog.groups() for i in grp)
    assert covered == list(range(len(prog.steps)))
    values = initial_values(slots, p, seed)
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    slot_map = trace_slot_map(steps)
    tables = [(msgs, attrs)
              for msgs, attrs, _, _ in prog.materialize(slot_map)]
    permuted = [tables[i] for grp in prog.groups()
                for i in reversed(grp)]
    opt = simulate_program(permuted, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid


@table_property
def test_overlap_never_regresses_predicted_schedule(seed):
    """The overlap gate is cost-driven: the optimized program's
    predicted seconds (overlap priced in, l_overlap included) never
    exceed the raw per-superstep schedule's."""
    p, slots, steps = random_program(seed)
    prog = optimize_program(steps, p, MACHINE)
    raw = sum(
        plan_sync(list(s.msgs), p, s.attrs).cost.predicted_seconds(MACHINE)
        for s in steps)
    assert prog.predicted_seconds(MACHINE) <= raw + 1e-15
    # every multi-member group is strictly cheaper than issuing its
    # members sequentially (the gate's invariant)
    for grp in prog.groups():
        if len(grp) < 2:
            continue
        costs = [prog.steps[i].plan.cost for i in grp]
        assert overlap_cost(costs).predicted_seconds(MACHINE) < \
            sum(c.predicted_seconds(MACHINE) for c in costs)
        for i in grp:
            assert prog.steps[i].plan.method in OVERLAPPABLE_METHODS


# ---------------------------------------------------------------------------
# XLA: split-phase execution on a mesh, ledger == planned overlap cost
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_overlapped_bucket_pipeline_on_mesh(mesh8):
    """Two split-phase allreduces staged in one recorded program: the
    schedule search must issue [rs0||rs1][ag0||ag1] (the reduce-scatters
    are mutually ready and commute; each all-gather depends only on its
    own bucket), produce results identical to two sequential allreduces,
    and ledger each overlap group as exactly ``overlap_cost`` of its
    members' plans."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import bsp
    from repro import core as lpf
    from repro.core import compat

    ledgers = {}

    def run(split):
        def wrapped(_):
            ctx = lpf.LPFContext(("x",))
            ledgers[split] = ctx.ledger
            x0 = (jnp.arange(8.0) + ctx.pid).astype(jnp.float32)
            x1 = (jnp.arange(8.0) * 2 - ctx.pid).astype(jnp.float32)
            if split:
                with ctx.program("buckets"):
                    h0 = bsp.allreduce_start(ctx, x0, label="b0")
                    h1 = bsp.allreduce_start(ctx, x1, label="b1")
                return (bsp.allreduce_done(ctx, h0),
                        bsp.allreduce_done(ctx, h1))
            return (bsp.allreduce(ctx, x0, label="b0"),
                    bsp.allreduce(ctx, x1, label="b1"))

        fn = jax.jit(compat.shard_map(
            wrapped, mesh=mesh8, in_specs=(P(),),
            out_specs=(P(), P()), check_vma=False))
        return [np.asarray(v) for v in fn(jnp.zeros(1))]

    eager = run(False)
    overlapped = run(True)
    for e, o in zip(eager, overlapped):
        np.testing.assert_array_equal(e, o)

    methods = [r.method for r in ledgers[True].records]
    assert methods == ["overlap[fused_rs+fused_rs]",
                       "overlap[fused_ag+fused_ag]"], methods
    mid = ledgers[True].records[1]
    assert mid.overlap_extra == 1
    assert mid.label == "b0.ag||b1.ag"
    # ledgered == planned, bit for bit: rebuild the member plans from
    # scratch and compare against the executed overlap record
    w = 1            # 8 elems over p=8
    p = 8
    src = lpf.Slot(sid=0, name="src", size=p * w,
                   dtype=np.dtype("float32"), kind="global",
                   orig_shape=(p * w,))
    buf = lpf.Slot(sid=1, name="buf", size=w, dtype=np.dtype("float32"),
                   kind="global", orig_shape=(w,))
    out = lpf.Slot(sid=2, name="out", size=p * w,
                   dtype=np.dtype("float32"), kind="global",
                   orig_shape=(p * w,))
    ag_msgs = [lpf.Msg(s, d, buf, 0, out, s * w, w, origin="table")
               for s in range(p) for d in range(p)]
    rs_msgs = [lpf.Msg(s, d, src, d * w, buf, 0, w, origin="table")
               for s in range(p) for d in range(p)]
    ag_plan = lpf.plan_sync(ag_msgs, p, lpf.LPF_SYNC_DEFAULT)
    rs_plan = lpf.plan_sync(rs_msgs, p,
                            lpf.SyncAttributes(reduce_op="sum"))
    first = ledgers[True].records[0]
    assert first.label == "b0.rs||b1.rs"
    assert lpf.overlap_cost([rs_plan.cost, rs_plan.cost],
                            label=first.label) == first
    assert lpf.overlap_cost([ag_plan.cost, ag_plan.cost],
                            label=mid.label) == mid
    # overlap hides supersteps: 4 eager barriers become 2 groups
    assert len(ledgers[True].records) == len(ledgers[False].records) - 2
