"""Differential tests for the SuperstepProgram optimizer.

The program layer rewrites recorded traces (coalescing, dead-transfer
elimination, cost-gated superstep batching); every rewrite must preserve
the LPF superstep semantics *bit-for-bit*.  The oracle is
:func:`repro.core.simulate_program`, a pure-numpy interpreter of the
p >= 2 semantics, so random programs over integer payloads are checked
in milliseconds without a mesh.  Property tests run under hypothesis
when available (``--hypothesis-profile=ci-slow`` raises the example
budget in the nightly workflow) and fall back to a fixed seed sweep
otherwise, mirroring ``test_sync_plan.py``.  The XLA tests at the
bottom check the real ``ctx.program()`` record/replay path, including
the program-level cache counters, and are marked ``slow``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (LPF_SYNC_DEFAULT, Msg, PlanCache, ProgramCache,
                        ProgramStep, Slot, SyncAttributes,
                        optimize_program, plan_sync, program_signature,
                        simulate_program)
from repro.core.machine import CPU_HOST, probe
from repro.core.program import trace_slot_map

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.fast

MACHINE = probe({"x": 8}, CPU_HOST)


def table_property(fn):
    if HAVE_HYPOTHESIS:
        return settings(deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(60))(fn)


def make_slot(sid, size, dtype="int32", kind="global"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind=kind, orig_shape=(size,))


def random_program(seed):
    """A random legal multi-superstep trace: (p, slots, steps)."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 8))
    slots = [make_slot(100 + i, int(rng.integers(8, 25)), "int32")
             for i in range(int(rng.integers(2, 5)))]
    steps = []
    for k in range(int(rng.integers(2, 6))):
        reduce_op = [None, None, None, "sum", "max", "min"][
            int(rng.integers(6))]
        attrs = SyncAttributes(
            method=["auto", "direct"][int(rng.integers(2))],
            reduce_op=reduce_op,
            no_conflict=False)
        msgs = []
        for _ in range(int(rng.integers(0, 9))):
            a = slots[int(rng.integers(len(slots)))]
            b = slots[int(rng.integers(len(slots)))]
            size = int(rng.integers(1, min(a.size, b.size) + 1))
            msgs.append(Msg(
                src=int(rng.integers(p)), dst=int(rng.integers(p)),
                src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
                dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
                size=size))
        steps.append(ProgramStep(tuple(msgs), attrs, f"s{k}"))
    return p, slots, steps


def initial_values(slots, p, seed):
    rng = np.random.default_rng(seed + 1)
    return {s.sid: rng.integers(-10_000, 10_000,
                                size=(p, s.size)).astype(np.int32)
            for s in slots}


def run_eager(steps, values):
    return simulate_program([(st_.msgs, st_.attrs) for st_ in steps],
                            values)


def run_optimized(prog, steps, values):
    slot_map = trace_slot_map(steps)
    tables = [(msgs, attrs)
              for msgs, attrs, _, _ in prog.materialize(slot_map)]
    return simulate_program(tables, values)


# ---------------------------------------------------------------------------
# the differential property: optimized replay == eager, bit for bit
# ---------------------------------------------------------------------------

@table_property
def test_optimized_program_bit_identical_to_eager(seed):
    """Random multi-superstep integer programs: the optimized trace must
    leave every slot on every process bit-identical to superstep-by-
    superstep execution — across CRCW and reduce_op supersteps, through
    coalescing, dead-transfer elimination, and batching."""
    p, slots, steps = random_program(seed)
    prog = optimize_program(steps, p, MACHINE)
    values = initial_values(slots, p, seed)
    eager = run_eager(steps, values)
    opt = run_optimized(prog, steps, values)
    assert set(eager) == set(opt)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all(), sid


@table_property
def test_optimizer_never_regresses_predicted_cost(seed):
    """Every rewrite is cost-gated, so the optimized trace's total
    predicted BSP time must never exceed the recorded trace's."""
    p, slots, steps = random_program(seed)
    prog = optimize_program(steps, p, MACHINE)

    def t(plan):
        return plan.cost.wire_bytes * MACHINE.g + plan.cost.rounds * MACHINE.l

    raw = sum(t(plan_sync(list(st_.msgs), p, st_.attrs)) for st_ in steps)
    opt = sum(t(st_.plan) for st_ in prog.steps)
    assert opt <= raw + 1e-12
    # bookkeeping is consistent: merged_from covers every recorded step
    covered = sorted(i for st_ in prog.steps for i in st_.merged_from)
    assert covered == list(range(len(steps)))


@table_property
def test_program_signature_slot_renaming(seed):
    """Re-recording the same trace through freshly registered slots must
    produce the same signature (the replay hit path)."""
    p, slots, steps = random_program(seed)
    remap = {}

    def clone(s):
        if s.sid not in remap:
            remap[s.sid] = make_slot(500 + len(remap), s.size, s.dtype)
        return remap[s.sid]

    steps2 = [ProgramStep(tuple(
        dataclasses.replace(m, src_slot=clone(m.src_slot),
                            dst_slot=clone(m.dst_slot))
        for m in st_.msgs), st_.attrs, st_.label) for st_ in steps]
    assert program_signature(steps, p) == program_signature(steps2, p)


# ---------------------------------------------------------------------------
# targeted optimizer behaviour
# ---------------------------------------------------------------------------

def test_dead_transfer_eliminated():
    """A write fully overwritten by a later superstep with no read in
    between is dropped; the same write with an interposed read is not."""
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)
    dead = ProgramStep((Msg(0, 1, A, 0, B, 0, 8),), LPF_SYNC_DEFAULT, "w1")
    overwrite = ProgramStep((Msg(2, 1, A, 8, B, 0, 8),), LPF_SYNC_DEFAULT,
                            "w2")
    prog = optimize_program([dead, overwrite], p, MACHINE)
    assert prog.n_eliminated == 1
    assert sum(len(st_.table) for st_ in prog.steps) == 1

    read = ProgramStep((Msg(1, 3, B, 0, A, 0, 4),), LPF_SYNC_DEFAULT, "r")
    prog2 = optimize_program([dead, read, overwrite], p, MACHINE)
    assert prog2.n_eliminated == 0
    assert sum(len(st_.table) for st_ in prog2.steps) == 3


def test_dead_transfer_elimination_in_reduce_step():
    """Accumulating writes are eliminable too, and the result still
    matches eager execution exactly."""
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)
    steps = [
        ProgramStep((Msg(0, 1, A, 0, B, 0, 4), Msg(2, 1, A, 0, B, 2, 4)),
                    SyncAttributes(reduce_op="sum"), "acc"),
        ProgramStep((Msg(3, 1, A, 8, B, 0, 8),), LPF_SYNC_DEFAULT, "over"),
    ]
    prog = optimize_program(steps, p, MACHINE)
    assert prog.n_eliminated == 2
    values = initial_values([A, B], p, 7)
    eager = run_eager(steps, values)
    opt = run_optimized(prog, steps, values)
    for sid in eager:
        assert (eager[sid] == opt[sid]).all()


def test_contiguous_messages_coalesce():
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)
    steps = [ProgramStep((Msg(0, 1, A, 0, B, 0, 4), Msg(0, 1, A, 4, B, 4, 4),
                          Msg(0, 1, A, 8, B, 8, 4)), LPF_SYNC_DEFAULT, "c")]
    prog = optimize_program(steps, p, MACHINE)
    assert prog.n_coalesced == 2
    assert len(prog.steps[0].table) == 1
    (src, dst, _, soff, _, doff, size, _) = prog.steps[0].table[0]
    assert (src, dst, soff, doff, size) == (0, 1, 0, 0, 12)
    # a same-pair gap (non-contiguous) must not coalesce
    steps2 = [ProgramStep((Msg(0, 1, A, 0, B, 0, 4),
                           Msg(0, 1, A, 8, B, 8, 4)), LPF_SYNC_DEFAULT, "g")]
    assert optimize_program(steps2, p, MACHINE).n_coalesced == 0


def test_independent_supersteps_batch_when_cheaper():
    """Two one-round supersteps over disjoint processes on the same slot
    pair colour into a single round when merged — the model approves and
    the trace shrinks to one sync; a data-dependent pair must not merge."""
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)
    s1 = ProgramStep((Msg(0, 1, A, 0, B, 0, 4),), LPF_SYNC_DEFAULT, "x")
    s2 = ProgramStep((Msg(2, 3, A, 4, B, 4, 4),), LPF_SYNC_DEFAULT, "y")
    prog = optimize_program([s1, s2], p, MACHINE)
    assert prog.n_merged == 1 and len(prog.steps) == 1
    assert prog.steps[0].label == "x+y"
    assert prog.steps[0].plan.cost.rounds == 1
    assert prog.steps[0].merged_from == (0, 1)

    # s3 reads what s1 wrote -> dependent, stays a separate superstep
    s3 = ProgramStep((Msg(1, 3, B, 0, A, 8, 4),), LPF_SYNC_DEFAULT, "z")
    prog2 = optimize_program([s1, s3], p, MACHINE)
    assert prog2.n_merged == 0 and len(prog2.steps) == 2


def test_batching_respects_attrs_boundaries():
    """Supersteps with different attributes (a reduce next to a CRCW
    step) never merge, whatever the cost model says."""
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)
    s1 = ProgramStep((Msg(0, 1, A, 0, B, 0, 4),),
                     SyncAttributes(reduce_op="sum"), "r")
    s2 = ProgramStep((Msg(2, 3, A, 4, B, 4, 4),), LPF_SYNC_DEFAULT, "w")
    prog = optimize_program([s1, s2], p, MACHINE)
    assert prog.n_merged == 0 and len(prog.steps) == 2


# ---------------------------------------------------------------------------
# cache statistics (plan + program level)
# ---------------------------------------------------------------------------

def test_plan_cache_counts_evictions():
    a, b = make_slot(1, 16), make_slot(2, 16)
    cache = PlanCache(maxsize=2)
    for dst in (1, 2, 3):
        cache.get_or_plan([Msg(0, dst, a, 0, b, 0, 4)], 4, LPF_SYNC_DEFAULT)
    assert cache.stats.evictions == 1
    assert cache.stats.misses == 3 and cache.stats.hits == 0


def test_program_cache_hits_and_evictions():
    p = 4
    A, B = make_slot(1, 16), make_slot(2, 16)

    def step(dst):
        return [ProgramStep((Msg(0, dst, A, 0, B, 0, 4),),
                            LPF_SYNC_DEFAULT, "s")]

    cache = ProgramCache(maxsize=2)
    prog1 = cache.get_or_build(step(1), p, MACHINE)
    prog2 = cache.get_or_build(step(1), p, MACHINE)
    assert prog1 is prog2
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    cache.get_or_build(step(2), p, MACHINE)
    cache.get_or_build(step(3), p, MACHINE)
    assert cache.stats.evictions == 1 and len(cache) == 2


# ---------------------------------------------------------------------------
# XLA: the real ctx.program() record/replay path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_recorded_program_matches_eager_on_mesh(mesh8):
    """A program with a dead transfer, a reduce superstep and two
    batchable shifts must produce bit-identical int32 slots through
    ``ctx.program()`` and through eager per-superstep sync — and the
    recorded path's ledger must carry fewer messages (the dead transfer
    is gone)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf

    def body(ctx, s, p, recorded):
        ctx.resize_memory_register(3)
        ctx.resize_message_queue(4 * p)
        a = ctx.register_global(
            "a", (jnp.arange(8) + 100 * ctx.pid).astype(jnp.int32))
        b = ctx.register_global("b", jnp.zeros(8, jnp.int32))
        c = ctx.register_global("c", jnp.zeros(8, jnp.int32))

        def steps():
            # dead: fully overwritten by the next superstep, never read
            ctx.put(a, b, to=lambda s_: (s_ + 1) % p, size=4)
            ctx.sync(label="dead")
            ctx.put(a, b, to=lambda s_: (s_ + 2) % p, src_off=4, size=4)
            ctx.sync(label="live")
            # independent write to c on disjoint offsets -> batchable
            ctx.put(a, c, to=lambda s_: (s_ + 3) % p, dst_off=4, size=4)
            ctx.sync(label="other")
            # accumulating superstep: all pids add into c[0:2] of pid 0
            ctx.put(a, c, to=0, size=2)
            ctx.sync(lpf.SyncAttributes(reduce_op="sum"), label="acc")

        if recorded:
            with ctx.program():
                steps()
        else:
            steps()
        return ctx.value(b), ctx.value(c)

    from repro.core import compat
    import jax

    results = {}
    ledgers = {}
    for recorded in (False, True):
        box = {}

        def wrapped(_):
            ctx = lpf.LPFContext(("x",))
            box["ledger"] = ctx.ledger
            return body(ctx, ctx.pid, ctx.p, recorded)

        fn = jax.jit(compat.shard_map(
            wrapped, mesh=mesh8, in_specs=(P(),),
            out_specs=(P("x"), P("x")), check_vma=False))
        results[recorded] = [np.asarray(v) for v in fn(jnp.zeros(1))]
        ledgers[recorded] = box["ledger"]

    for ve, vr in zip(results[False], results[True]):
        assert (ve == vr).all()
    eager_msgs = sum(r.n_msgs for r in ledgers[False].records)
    replay_msgs = sum(r.n_msgs for r in ledgers[True].records)
    assert replay_msgs < eager_msgs       # the dead transfer is gone
    # ledger-predicted == executed for every optimized superstep: the
    # entries are the plans' own costs with labels attached
    for r in ledgers[True].records:
        assert r.wire_bytes >= 0 and (
            r.method.startswith("overlap[") or r.method in (
                "direct", "bruck", "valiant", "noop", "fused", "fused_ag",
                "fused_rs", "fused_scatter", "fused_gather", "seq"))


@pytest.mark.slow
def test_program_cache_stats_over_replay_loop(mesh8):
    """Replaying one recorded program 10x: >= 9 program-cache hits and
    zero planning passes after the first iteration."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf

    plan_cache = lpf.PlanCache()
    program_cache = lpf.ProgramCache()
    stats_box = {}

    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * p)
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))
        for i in range(10):
            with ctx.program():
                ctx.put(a, b, to=lambda s_: (s_ + 1) % p, size=4)
                ctx.sync(label="shift")
                ctx.put(a, b, to=lambda s_: (s_ + 2) % p, dst_off=4,
                        size=4)
                ctx.sync(label="shift2")
            if i == 0:
                stats_box["plans_after_first"] = ctx.plan_cache.stats.misses
        stats_box["stats"] = ctx.cache_stats
        return ctx.value(b)

    def wrapped(_):
        ctx = lpf.LPFContext(("x",), plan_cache=plan_cache,
                             program_cache=program_cache)
        return spmd(ctx, ctx.pid, ctx.p, None)

    import jax
    from repro.core import compat
    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8, in_specs=(P(),),
                                  out_specs=P("x"), check_vma=False))
    out = np.asarray(fn(jnp.zeros(1))).reshape(8, 8)
    for d in range(8):
        np.testing.assert_allclose(out[d, :4], np.arange(4.0) + (d - 1) % 8)
        np.testing.assert_allclose(out[d, 4:], np.arange(4.0) + (d - 2) % 8)
    stats = stats_box["stats"]
    assert stats["program"].hits >= 9
    assert stats["program"].misses == 1
    # zero re-plans: no planner activity after the first iteration
    assert plan_cache.stats.misses == stats_box["plans_after_first"]
