"""The static analyzer: race linter (LPF001–006), schedule verifier
(LPF101–107), sanitizer mode, and the certificate-gated program cache.

Four layers:

1. every linter code has a firing and a non-firing case;
2. the verifier accepts every schedule the real optimizer emits — a
   300-seed sweep over random and structured traces, both search modes,
   with and without scratch (zero false positives) — and rejects a
   hand-built negative fixture per LPF101–107;
3. sanitizer mode: ``LPFContext(sanitize=True)`` (or ``LPF_SANITIZE=1``)
   raises :class:`LPFAnalysisError` on error diagnostics before any
   communication and accumulates warnings on ``ctx.diagnostics``; slot
   generations catch stale handles after deregister-then-reuse;
4. ``ProgramCache.set_compiled`` refuses uncertified (or failed)
   entries, and ``explain`` renders the certificate summary.
"""

import dataclasses
import pickle

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (CANNED_TRACES, canned_bucketed_trace,
                            canned_fft_trace, canned_fragmented_trace,
                            lint_program, lint_trace, verify_program)
from repro.analysis.__main__ import main as analysis_main
from repro.core import (LPF_SYNC_DEFAULT, LPFAnalysisError, LPFContext,
                        LPFFatalError, Msg, OptimizedStep, ProgramCache,
                        ProgramStep, Slot, SlotRegistry, SuperstepProgram,
                        SyncAttributes, optimize_program, plan_sync,
                        trace_slot_map)
from repro.core.machine import CPU_HOST, TPU_V5E, probe

pytestmark = pytest.mark.fast

MACHINE = probe({"x": 8}, CPU_HOST)


def make_slot(sid, size, dtype="int32", kind="global"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind=kind, orig_shape=(size,))


A, B, C, D = (make_slot(100 + i, 16) for i in range(4))


def step(msgs, attrs=LPF_SYNC_DEFAULT, label="s"):
    return ProgramStep(tuple(msgs), attrs, label)


def codes(diags):
    return {d.code for d in diags}


# ---------------------------------------------------------------------------
# (1) the linter: one firing + one non-firing case per code
# ---------------------------------------------------------------------------

def test_lpf001_no_conflict_race():
    racy = step([Msg(0, 1, A, 0, B, 0, 4), Msg(0, 1, A, 4, B, 2, 4)],
                SyncAttributes(no_conflict=True))
    assert "LPF001" in codes(lint_trace([racy], 2))
    # same table without the assertion: CRCW arbitration is defined
    assert "LPF001" not in codes(lint_trace(
        [step(racy.msgs)], 2))
    # reduce tables combine overlapping writes by construction
    assert "LPF001" not in codes(lint_trace(
        [step(racy.msgs, SyncAttributes(no_conflict=True,
                                        reduce_op="sum"))], 2))


def test_lpf002_read_of_undefined_region():
    trace = [step([Msg(0, 1, B, 0, C, 0, 4)])]          # reads B undefined
    assert "LPF002" in codes(lint_trace(trace, 2, undefined=[B.sid]))
    defined_first = [step([Msg(1, 0, A, 0, B, 0, 8)]),  # writes B[0:8) @0
                     step([Msg(0, 1, B, 0, C, 0, 4)])]
    assert "LPF002" not in codes(
        lint_trace(defined_first, 2, undefined=[B.sid]))
    # a partial write does not define the whole read range
    partial = [step([Msg(1, 0, A, 0, B, 0, 2)]),
               step([Msg(0, 1, B, 0, C, 0, 4)])]
    assert "LPF002" in codes(lint_trace(partial, 2, undefined=[B.sid]))


def test_lpf003_use_after_deregister_and_leak():
    trace = [step([Msg(0, 1, A, 0, B, 0, 4)]),
             step([Msg(0, 1, A, 0, B, 4, 4)])]
    fired = lint_trace(trace, 2, events=[(1, "deregister", A.sid)])
    assert any(d.code == "LPF003" and d.severity == "error" and d.step == 1
               for d in fired)
    # deregistered only after the last step: clean
    after = lint_trace(trace, 2, events=[(2, "deregister", A.sid)])
    assert not any(d.code == "LPF003" and d.severity == "error"
                   for d in after)
    # registered during the trace, never deregistered: a leak warning
    leak = lint_trace(trace, 2, events=[(0, "register", A.sid)])
    assert any(d.code == "LPF003" and d.severity == "warning"
               for d in leak)


def test_lpf004_out_of_bounds_extents():
    oob = [step([Msg(0, 1, A, 12, B, 0, 8)])]       # src [12,20) > 16
    assert "LPF004" in codes(lint_trace(oob, 2))
    assert "LPF004" in codes(lint_trace(
        [step([Msg(0, 1, A, 0, B, 10, 8)])], 2))    # dst [10,18) > 16
    assert "LPF004" in codes(lint_trace(
        [step([Msg(0, 5, A, 0, B, 0, 4)])], 2))     # pid out of range
    local = make_slot(500, 16, kind="local")
    assert "LPF004" in codes(lint_trace(
        [step([Msg(0, 1, A, 0, local, 0, 4)])], 2))  # remote local slot
    assert "LPF004" not in codes(lint_trace(
        [step([Msg(0, 1, A, 0, B, 8, 8)])], 2))     # exactly in bounds


def test_lpf005_aliasing_self_message():
    alias = [step([Msg(1, 1, A, 0, A, 2, 8)])]      # shifted overlap
    assert "LPF005" in codes(lint_trace(alias, 2))
    assert "LPF005" not in codes(lint_trace(
        [step([Msg(1, 1, A, 0, A, 8, 8)])], 2))     # disjoint move
    assert "LPF005" not in codes(lint_trace(
        [step([Msg(1, 1, A, 0, B, 2, 8)])], 2))     # different slot


def test_lpf006_dead_transfer_in_trace():
    dead = [step([Msg(0, 1, A, 0, B, 0, 8)], label="dead"),
            step([Msg(0, 1, C, 0, B, 0, 8)], label="clobber")]
    assert "LPF006" in codes(lint_trace(dead, 2))
    read_between = [dead[0],
                    step([Msg(1, 0, B, 0, C, 0, 4)]),   # observes B
                    dead[1]]
    assert "LPF006" not in codes(lint_trace(read_between, 2))


def test_lpf006_dead_transfer_surviving_optimization():
    # the union-of-two-writes overwrite is invisible to the optimizer's
    # single-message eliminator (and the halves cannot coalesce: their
    # src->dst shifts differ), so the dead transfer survives into the
    # schedule and lint_program reports it
    trace = [step([Msg(0, 1, A, 0, B, 0, 8)], label="dead"),
             step([Msg(0, 1, A, 8, B, 0, 4), Msg(0, 1, A, 0, B, 4, 4)],
                  label="clobber2")]
    prog = optimize_program(trace, 2, MACHINE)
    assert prog.n_eliminated == 0
    assert "LPF006" in codes(lint_program(prog, trace))
    assert verify_program(trace, prog).ok
    # the single-message overwrite IS eliminated -> nothing survives,
    # and the verifier accepts the drop (provably dead: LPF107 clean)
    trace2 = [step([Msg(0, 1, A, 0, B, 0, 8)], label="dead"),
              step([Msg(0, 1, C, 0, B, 0, 8)], label="clobber")]
    prog2 = optimize_program(trace2, 2, MACHINE)
    assert prog2.n_eliminated == 1
    assert "LPF006" not in codes(lint_program(prog2, trace2))
    assert verify_program(trace2, prog2).ok


# ---------------------------------------------------------------------------
# (2a) the verifier accepts everything the real optimizer emits
# ---------------------------------------------------------------------------

def _sweep_trace(seed):
    """Random or structured (merge/overlap/valiant-shaped) trace."""
    rng = np.random.default_rng(seed)
    pattern = seed % 4
    if pattern == 1:
        return canned_bucketed_trace(p=int(rng.choice([4, 8])),
                                     n_buckets=int(rng.integers(1, 4)),
                                     w=int(rng.integers(4, 17)))
    if pattern == 2:
        return canned_fft_trace(p=int(rng.choice([2, 4, 8])),
                                w=int(rng.integers(4, 17)))
    if pattern == 3:
        return canned_fragmented_trace(p=int(rng.choice([4, 8])))
    p = int(rng.integers(2, 9))
    n_slots = int(rng.integers(2, 5))
    sizes = rng.choice(np.arange(8, 40), size=n_slots, replace=False)
    slots = [make_slot(100 + i, int(sizes[i])) for i in range(n_slots)]
    steps = []
    for k in range(int(rng.integers(2, 7))):
        reduce_op = [None, None, None, "sum", "max", "min"][
            int(rng.integers(6))]
        attrs = SyncAttributes(
            method=["auto", "direct"][int(rng.integers(2))],
            reduce_op=reduce_op)
        msgs = []
        for _ in range(int(rng.integers(0, 9))):
            a = slots[int(rng.integers(len(slots)))]
            b = slots[int(rng.integers(len(slots)))]
            size = int(rng.integers(1, min(a.size, b.size) + 1))
            msgs.append(Msg(
                src=int(rng.integers(p)), dst=int(rng.integers(p)),
                src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
                dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
                size=size))
        steps.append(ProgramStep(tuple(msgs), attrs, f"s{k}"))
    scratch = make_slot(999, 4096) if seed % 3 == 0 else None
    return p, slots, steps, scratch


def test_verifier_accepts_every_searched_schedule():
    """300 seeds x {search, peephole}: zero false positives."""
    for seed in range(300):
        p, _slots, steps, scratch = _sweep_trace(seed)
        hw = TPU_V5E if seed % 5 == 0 else CPU_HOST
        machine = probe({"x": p}, hw)
        for search in (True, False):
            prog = optimize_program(steps, p, machine, scratch=scratch,
                                    search=search)
            rep = verify_program(steps, prog, scratch=scratch)
            assert rep.ok, (
                f"false positive at seed={seed} search={search}: "
                + "; ".join(str(d) for d in rep.diagnostics))


def test_verifier_accepts_canned_traces_on_dcn():
    dcn = probe({"pod": 8}, TPU_V5E)
    for name, build in CANNED_TRACES.items():
        p, _slots, steps, scratch = build()
        prog = optimize_program(steps, p, dcn, scratch=scratch)
        rep = verify_program(steps, prog, scratch=scratch)
        assert rep.ok, (name, rep.diagnostics)
        assert rep.summary().startswith("verified:")


# ---------------------------------------------------------------------------
# (2b) one hand-built negative fixture per verifier code
# ---------------------------------------------------------------------------

def _canon(msgs, sidx):
    return tuple((m.src, m.dst, sidx[m.src_slot.sid], m.src_off,
                  sidx[m.dst_slot.sid], m.dst_off, m.size, m.origin)
                 for m in msgs)


def _build_program(steps, p, partition, overlap_groups=(),
                   plan_scratch=None, rewrites=None):
    """Hand-assemble a recorded-order (``canonical=False``) program
    scheduling ``steps`` per ``partition`` — a list of merged_from
    tuples in emission order.  Bypasses the optimizer so tests can
    construct *illegal* schedules the optimizer would never emit."""
    order = list(range(len(steps)))
    smap = trace_slot_map(steps, order)
    sidx = {s.sid: i for i, s in enumerate(smap)}
    opt = []
    for gi, ranks in enumerate(partition):
        msgs = [m for r in ranks for m in steps[r].msgs]
        attrs = steps[ranks[0]].attrs
        rw = (rewrites or {}).get(gi, "")
        if rw == "valiant":
            attrs = dataclasses.replace(attrs, method="valiant")
        plan = plan_sync(msgs, p, attrs, plan_scratch)
        opt.append(OptimizedStep(
            _canon(msgs, sidx), attrs,
            "+".join(steps[r].label for r in ranks), plan,
            tuple(ranks), rewrite=rw))
    return SuperstepProgram(
        p=p, steps=tuple(opt), n_recorded=len(steps), n_coalesced=0,
        n_eliminated=0, n_merged=0, overlap_groups=tuple(overlap_groups),
        canonical=False)


W = step([Msg(0, 1, A, 0, B, 0, 4)], label="w")     # writes B on pid 1
R = step([Msg(1, 0, B, 0, C, 0, 4)], label="r")     # reads it (RAW)


def _verify(prog, steps=(W, R), scratch=None):
    return verify_program(list(steps), prog, scratch=scratch)


def test_handbuilt_legal_schedule_verifies():
    assert _verify(_build_program([W, R], 2, [(0,), (1,)])).ok


def test_lpf101_broken_partition():
    good = _build_program([W, R], 2, [(0,), (1,)])
    rep = _verify(dataclasses.replace(good, n_recorded=3))
    assert not rep.ok and "LPF101" in codes(rep.diagnostics)
    dup = dataclasses.replace(
        good, steps=(dataclasses.replace(good.steps[0],
                                         merged_from=(0, 0)),
                     good.steps[1]))
    rep = _verify(dup)
    assert not rep.ok and "LPF101" in codes(rep.diagnostics)


def test_lpf102_conflicting_steps_reordered():
    rep = _verify(_build_program([W, R], 2, [(1,), (0,)]))
    assert not rep.ok and "LPF102" in codes(rep.diagnostics)


def test_lpf103_raw_pair_merged():
    rep = _verify(_build_program([W, R], 2, [(0, 1)]))
    assert not rep.ok and "LPF103" in codes(rep.diagnostics)


def test_lpf103_waw_pair_merged():
    w2 = step([Msg(0, 1, C, 0, B, 2, 4)], label="w2")   # overlaps W's dst
    rep = verify_program([W, w2], _build_program([W, w2], 2, [(0, 1)]))
    assert not rep.ok and "LPF103" in codes(rep.diagnostics)


def test_lpf104_conflicting_overlap_group():
    rep = _verify(_build_program([W, R], 2, [(0,), (1,)],
                                 overlap_groups=((0, 1),)))
    assert not rep.ok and "LPF104" in codes(rep.diagnostics)


def test_lpf105_bogus_valiant_rewrite():
    # a declared valiant rewrite with no scratch slot to route through
    scratch = make_slot(999, 4096)
    prog = _build_program([W], 2, [(0,)], plan_scratch=scratch,
                          rewrites={0: "valiant"})
    rep = verify_program([W], prog, scratch=None)
    assert not rep.ok and "LPF105" in codes(rep.diagnostics)
    # an unknown rewrite tag is never certified
    good = _build_program([W, R], 2, [(0,), (1,)])
    bad = dataclasses.replace(
        good, steps=(dataclasses.replace(good.steps[0], rewrite="wat"),
                     good.steps[1]))
    rep = _verify(bad)
    assert not rep.ok and "LPF105" in codes(rep.diagnostics)


def test_lpf106_tampered_plan_cost():
    good = _build_program([W, R], 2, [(0,), (1,)])
    st0 = good.steps[0]
    cost = st0.plan.cost
    tampered = dataclasses.replace(
        good, steps=(dataclasses.replace(
            st0, plan=dataclasses.replace(
                st0.plan, cost=dataclasses.replace(
                    cost, wire_bytes=cost.wire_bytes + 64))),
            good.steps[1]))
    rep = _verify(tampered)
    assert not rep.ok and "LPF106" in codes(rep.diagnostics)


def test_lpf107_live_transfer_dropped():
    good = _build_program([W, R], 2, [(0,), (1,)])
    dropped = dataclasses.replace(
        good, steps=(dataclasses.replace(good.steps[0], table=()),
                     good.steps[1]))
    rep = _verify(dropped)
    assert not rep.ok and "LPF107" in codes(rep.diagnostics)


def test_lpf107_fabricated_transfer():
    good = _build_program([W, R], 2, [(0,), (1,)])
    smap = trace_slot_map([W, R], [0, 1])
    sidx = {s.sid: i for i, s in enumerate(smap)}
    extra = _canon([Msg(0, 1, A, 8, B, 8, 4)], sidx)    # never recorded
    fat = dataclasses.replace(
        good, steps=(dataclasses.replace(
            good.steps[0], table=good.steps[0].table + extra),
            good.steps[1]))
    rep = _verify(fat)
    assert not rep.ok and "LPF107" in codes(rep.diagnostics)


# ---------------------------------------------------------------------------
# (3) slot generations + sanitizer mode
# ---------------------------------------------------------------------------

def test_stale_handle_after_sid_reuse_is_fatal():
    reg = SlotRegistry(capacity=2)
    a = reg.register("a", jnp.zeros(4, jnp.int32), "global")
    reg.deregister(a)
    b = reg.register("b", jnp.zeros(8, jnp.int32), "global")
    assert b.sid == a.sid and b.gen != a.gen     # sid reused, new epoch
    with pytest.raises(LPFFatalError, match="stale"):
        reg.value(a)
    with pytest.raises(LPFFatalError, match="stale"):
        reg.deregister(a)
    assert not reg.is_registered(a)
    assert reg.is_registered(b)
    assert int(reg.value(b).shape[0]) == 8


def _eager_ctx(sanitize=None):
    ctx = LPFContext((), sanitize=sanitize)      # p = 1, no mesh needed
    ctx.resize_memory_register(4)
    ctx.resize_message_queue(16)
    return ctx


def test_put_validates_extents_at_stage_time():
    ctx = _eager_ctx()
    a = ctx.register_global("a", jnp.arange(8, dtype=jnp.int32))
    b = ctx.register_global("b", jnp.arange(4, dtype=jnp.int32))
    with pytest.raises(LPFFatalError, match="OOB"):
        ctx.put(a, b, to=0, size=8)              # dst extent 8 > 4
    assert not ctx._queue                        # nothing staged
    with ctx.program("rec"):                     # also under recording
        with pytest.raises(LPFFatalError, match="OOB"):
            ctx.put(a, b, to=0, src_off=6, size=4)


def test_sanitize_stale_handle_raises_at_put():
    ctx = _eager_ctx(sanitize=True)
    a = ctx.register_global("a", jnp.zeros(8, jnp.int32))
    ctx.deregister(a)
    c = ctx.register_global("c", jnp.zeros(8, jnp.int32))
    assert c.sid == a.sid and c.gen != a.gen
    with pytest.raises(LPFAnalysisError, match="LPF003"):
        ctx.put_msgs([(0, 0, c, 0, a, 0, 4)])    # stale dst handle
    assert not ctx._queue


def test_stale_handle_without_sanitize_still_fatal_at_sync():
    ctx = _eager_ctx(sanitize=False)
    a = ctx.register_global("a", jnp.zeros(8, jnp.int32))
    ctx.deregister(a)
    c = ctx.register_global("c", jnp.zeros(8, jnp.int32))
    ctx.put_msgs([(0, 0, c, 0, a, 0, 4)])
    with pytest.raises(LPFFatalError, match="stale"):
        ctx.sync()


def test_sanitize_no_conflict_race_raises_before_execution():
    ctx = _eager_ctx(sanitize=True)
    a = ctx.register_global("a", jnp.arange(8, dtype=jnp.int32))
    b = ctx.register_global("b", jnp.zeros(8, jnp.int32))
    ctx.put_msgs([(0, 0, a, 0, b, 0, 4), (0, 0, a, 4, b, 2, 4)])
    before = jnp.asarray(ctx.registry.value(b))
    with pytest.raises(LPFAnalysisError, match="LPF001"):
        ctx.sync(SyncAttributes(no_conflict=True))
    assert (np.asarray(ctx.registry.value(b)) ==
            np.asarray(before)).all()            # raised before execution


def test_sanitize_warnings_accumulate_on_diagnostics():
    ctx = _eager_ctx(sanitize=True)
    a = ctx.register_global("a", jnp.arange(8, dtype=jnp.int32))
    ctx.put_msgs([(0, 0, a, 0, a, 2, 4)])        # aliasing self-copy
    ctx.sync()
    assert any(d.code == "LPF005" for d in ctx.diagnostics)


def test_sanitize_recorded_trace_and_leak_warning():
    ctx = _eager_ctx(sanitize=True)
    ctx.compile_programs = False
    a = ctx.register_global("a", jnp.arange(8, dtype=jnp.int32))
    with ctx.program("loop"):
        b = ctx.register_global("b", jnp.zeros(8, jnp.int32))
        ctx.put_msgs([(0, 0, a, 0, b, 0, 8)])
        ctx.sync()
    # b was registered inside the recording and never deregistered
    assert any(d.code == "LPF003" and d.severity == "warning"
               for d in ctx.diagnostics)


def test_sanitize_env_default(monkeypatch):
    monkeypatch.setenv("LPF_SANITIZE", "1")
    assert LPFContext(()).sanitize
    monkeypatch.setenv("LPF_SANITIZE", "0")
    assert not LPFContext(()).sanitize
    assert LPFContext((), sanitize=True).sanitize    # explicit overrides


# ---------------------------------------------------------------------------
# (4) certificate-gated program cache + explain
# ---------------------------------------------------------------------------

def test_set_compiled_requires_passing_certificate():
    cache = ProgramCache(maxsize=4)
    trace = [W, R]
    prog, key = cache.get_or_build_keyed(trace, 2, MACHINE)
    with pytest.raises(LPFAnalysisError, match="uncertified"):
        cache.set_compiled(key, ("x",), object())
    cert = cache.certify(key, trace)
    assert cert.ok and cache.certificate(key) is cert
    assert cache.certify(key, trace) is cert         # memoized
    cache.set_compiled(key, ("x",), object())        # now admitted
    assert cache.compiled(key, ("x",)) is not None
    # a failed certificate refuses compiled artifacts outright
    cache._certs[key] = dataclasses.replace(cert, ok=False)
    with pytest.raises(LPFAnalysisError, match="failed verification"):
        cache.set_compiled(key, ("x",), object())
    cache.clear()
    assert not cache._certs


def test_explain_renders_certificate_summary():
    p, _slots, steps, scratch = canned_fft_trace(4, 8)
    prog = optimize_program(steps, p, MACHINE, scratch=scratch)
    txt = prog.explain(MACHINE, steps=steps, scratch=scratch)
    assert "verified:" in txt and "0 diagnostics" in txt
    # certify() attaches the certificate for later explain() calls
    cache = ProgramCache()
    prog2, key = cache.get_or_build_keyed(steps, p, MACHINE,
                                          scratch=scratch)
    cache.certify(key, steps, scratch=scratch)
    assert "verified:" in prog2.explain()


# ---------------------------------------------------------------------------
# (5) the CLI
# ---------------------------------------------------------------------------

def test_cli_canned_traces_exit_zero(capsys):
    assert analysis_main(["fft_redistribute", "pagerank"]) == 0
    out = capsys.readouterr().out
    assert "verified:" in out and "fft_redistribute" in out


def test_cli_pickled_racy_trace_exits_nonzero(tmp_path, capsys):
    racy = [step([Msg(0, 1, A, 0, B, 0, 4), Msg(0, 1, A, 4, B, 2, 4)],
                 SyncAttributes(no_conflict=True), label="racy")]
    path = tmp_path / "racy.pkl"
    with open(path, "wb") as fh:
        pickle.dump((2, racy), fh)
    assert analysis_main(["--pickle", str(path)]) == 1
    assert "LPF001" in capsys.readouterr().out
