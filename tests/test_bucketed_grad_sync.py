"""Bucketed gradient synchronization: HLO + ledger compliance.

``pod_allreduce(method="bucketed", bucket_bytes=B)`` packs per-layer
gradients into ~B-byte buckets, each synced as one reduce-scatter +
all-gather pair: L per-layer supersteps become ceil(sum(B)/bucket).
The compiled HLO must carry exactly that many native collectives, the
ledger's superstep count must drop accordingly, and the total wire
bytes must stay within one bucket's padding of the unbucketed run.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.bsp.pod_sync import bucketize, pod_allreduce
from repro.core import CostLedger, compat
from repro.core.hlo_analysis import parse_collectives


@pytest.mark.fast
def test_bucketize_packing():
    # four equal layers, bucket of two -> two buckets
    assert bucketize([256] * 4, 512) == [[0, 1], [2, 3]]
    # None -> one bucket; tiny bucket -> per-leaf
    assert bucketize([256] * 4, None) == [[0, 1, 2, 3]]
    assert bucketize([256] * 4, 1) == [[0], [1], [2], [3]]
    # an oversized leaf still gets (its own) bucket
    assert bucketize([100, 900, 100], 512) == [[0], [1], [2]]
    assert bucketize([100, 100, 900], 512) == [[0, 1], [2]]
    assert bucketize([], 512) == []


#: a 4-layer toy model: equal f32 layers, 64 elements (256 B) each
LAYERS = 4
LAYER_ELEMS = 64
BUCKET_BYTES = 2 * LAYER_ELEMS * 4          # 2 layers per bucket


def _toy_grads():
    return {f"layer{i}": (jnp.arange(LAYER_ELEMS, dtype=jnp.float32)
                          + i) for i in range(LAYERS)}


def _compile_sync(mesh8, method, bucket_bytes):
    ledger = CostLedger()

    def body(grads):
        return pod_allreduce(grads, 8, "x", mean=True, ledger=ledger,
                             method=method, bucket_bytes=bucket_bytes)

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh8,
        in_specs=(jax.tree.map(lambda _: P(), _toy_grads()),),
        out_specs=jax.tree.map(lambda _: P(), _toy_grads()),
        check_vma=False))
    compiled = fn.lower(_toy_grads()).compile()
    return fn, compiled, ledger


@pytest.mark.slow
def test_bucketed_grad_sync_hlo_and_ledger(mesh8):
    total_bytes = LAYERS * LAYER_ELEMS * 4
    n_buckets = -(-total_bytes // BUCKET_BYTES)         # ceil = 2

    fn, compiled, ledger = _compile_sync(mesh8, "bucketed", BUCKET_BYTES)
    stats = parse_collectives(compiled.as_text())
    # exactly ceil(sum(B)/bucket) reduce-scatter/all-gather pairs
    assert stats.count_by_kind.get("reduce-scatter", 0) == n_buckets
    assert stats.count_by_kind.get("all-gather", 0) == n_buckets
    assert stats.count_by_kind.get("collective-permute", 0) == 0
    assert ledger.supersteps == n_buckets
    assert all(r.method == "bucketed" and r.rounds == 2
               for r in ledger.records)

    # per-layer baseline: one pair per layer, 2x the supersteps
    _, compiled_pl, ledger_pl = _compile_sync(mesh8, "bucketed", 1)
    stats_pl = parse_collectives(compiled_pl.as_text())
    assert stats_pl.count_by_kind.get("reduce-scatter", 0) == LAYERS
    assert ledger_pl.supersteps == LAYERS
    assert ledger.supersteps * (LAYERS // n_buckets) == ledger_pl.supersteps

    # unbucketed (single flatten): wire totals agree within one bucket
    _, _, ledger_un = _compile_sync(mesh8, "rs+ag", None)
    assert ledger_un.supersteps == 1
    assert abs(ledger.wire_bytes - ledger_un.wire_bytes) <= BUCKET_BYTES
    assert abs(ledger_pl.wire_bytes - ledger_un.wire_bytes) <= BUCKET_BYTES

    # and the sync is still an exact mean across the pod axis (every
    # pod feeds the same grads, so the mean is the identity)
    out = fn(_toy_grads())
    for i in range(LAYERS):
        np.testing.assert_allclose(
            np.asarray(out[f"layer{i}"]),
            np.arange(LAYER_ELEMS, dtype=np.float32) + i, rtol=1e-6)


@pytest.mark.slow
def test_bucketed_auto_selection(mesh8):
    """``method='auto'`` rides the overlapped bucket pipeline when
    bucket_bytes is given."""
    _, _, ledger = _compile_sync(mesh8, "auto", BUCKET_BYTES)
    # 2 buckets -> 3 schedule entries: [rs0][ag0||rs1][ag1]
    assert ledger.supersteps == 3
    assert all(r.method == "bucketed_overlap"
               or r.method.startswith("overlap[")
               for r in ledger.records)
    _, _, ledger2 = _compile_sync(mesh8, "auto", None)
    assert ledger2.supersteps == 1 and ledger2.records[0].method == "rs+ag"


@pytest.mark.slow
def test_bucketed_overlap_matches_sync_bit_for_bit(mesh8):
    """The overlapped pipeline is a pure scheduling change: same HLO
    collective counts (for the plain and fenced baselines alike), same
    total wire on the flat ledger, identical results — but the ledger
    records the overlapped schedule: B+1 entries for B buckets
    ([rs0][ag||rs]...[ag]), overlap groups priced by ``overlap_cost``,
    and a strictly smaller time-equivalent wire."""
    n_buckets = 2
    fn_s, compiled_s, ledger_s = _compile_sync(mesh8, "bucketed",
                                               BUCKET_BYTES)
    fn_f, compiled_f, ledger_f = _compile_sync(mesh8, "bucketed_fenced",
                                               BUCKET_BYTES)
    fn_o, compiled_o, ledger_o = _compile_sync(mesh8, "bucketed_overlap",
                                               BUCKET_BYTES)
    stats_s = parse_collectives(compiled_s.as_text())
    stats_f = parse_collectives(compiled_f.as_text())
    stats_o = parse_collectives(compiled_o.as_text())
    for kind in ("reduce-scatter", "all-gather"):
        assert stats_o.count_by_kind.get(kind, 0) == \
            stats_s.count_by_kind.get(kind, 0) == \
            stats_f.count_by_kind.get(kind, 0) == n_buckets
    # flat totals agree: overlap hides time, not traffic
    assert ledger_o.total_wire_bytes == ledger_s.total_wire_bytes \
        == ledger_f.total_wire_bytes
    # the overlapped schedule: B+1 superstep entries, middle ones
    # overlap groups, time-equivalent wire strictly below sequential
    assert ledger_s.supersteps == ledger_f.supersteps == n_buckets
    assert ledger_o.supersteps == n_buckets + 1
    assert ledger_o.records[0].method == "bucketed_overlap"
    assert all(r.method.startswith("overlap[") and r.overlap_extra == 1
               for r in ledger_o.records[1:-1])
    assert ledger_o.wire_bytes < ledger_s.wire_bytes
    out_s, out_f, out_o = (fn(_toy_grads()) for fn in (fn_s, fn_f, fn_o))
    for k in out_s:
        np.testing.assert_array_equal(np.asarray(out_s[k]),
                                      np.asarray(out_o[k]))
        np.testing.assert_array_equal(np.asarray(out_s[k]),
                                      np.asarray(out_f[k]))


@pytest.mark.fast
def test_bucket_staleness_schedule():
    """Satellite: per-bucket staleness — the last-layer bucket (highest
    gradient variance) is always fresh; earlier buckets inherit k."""
    from repro.bsp.grad_sync import bucket_staleness
    assert bucket_staleness(3, 2) == [2, 2, 0]
    assert bucket_staleness(1, 4) == [0]
    assert bucket_staleness(0, 4) == []
    assert bucket_staleness(3, 0) == [0, 0, 0]


@pytest.mark.slow
def test_bucketed_overlap_reversed_issue_order(mesh8):
    """Satellite: ``bucketed_overlap`` issues reduce-scatters
    last-layer-first (matching backward-pass gradient availability):
    the ledger leads with the last bucket, and the traced module carries
    the last bucket's (smaller) reduce-scatter before the first
    bucket's."""
    import re
    grads = {"layer0": jnp.arange(256, dtype=jnp.float32),
             "layer1": jnp.arange(64, dtype=jnp.float32)}
    specs = jax.tree.map(lambda _: P(), grads)
    ledger = CostLedger()

    def body(g):
        return pod_allreduce(g, 8, "x", mean=True, ledger=ledger,
                             method="bucketed_overlap",
                             bucket_bytes=256 * 4)

    fn = jax.jit(compat.shard_map(body, mesh=mesh8, in_specs=(specs,),
                                  out_specs=specs, check_vma=False))
    lowered = fn.lower(grads).as_text()
    # ledger order: [rs1][ag1||rs0][ag0] — bucket 1 (the last layer)
    # leads the schedule
    labels = [r.label for r in ledger.records]
    assert labels[0].startswith("pod_allreduce.b1.rs")
    assert labels[-1].startswith("pod_allreduce.b0.ag")
    # HLO order: the reduce-scatter of the 64-elem bucket (result
    # [1, 8] over q=8) is traced before the 256-elem bucket's ([1, 32])
    rs_shapes = [int(m.group(1)) for m in re.finditer(
        r"reduce_scatter.*?->\s*tensor<1x(\d+)xf32>", lowered, re.S)]
    assert rs_shapes == [8, 32], rs_shapes
    # numerics: still an exact mean (identical grads on every pod)
    out = fn(grads)
    for k, v in grads.items():
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(v),
                                   rtol=1e-6)


@pytest.mark.slow
def test_cross_pod_sync_per_bucket_staleness(mesh_pdm):
    """Satellite: ``attrs.stale = k`` with buckets skips individual
    *buckets* on off-steps — the last-layer bucket still syncs every
    step, earlier buckets keep their pod-local gradients."""
    from repro.core import SyncAttributes
    from repro.bsp.grad_sync import build_cross_pod_sync

    grads = {"a": jnp.arange(16, dtype=jnp.float32).reshape(2, 8),
             "b": jnp.arange(8, dtype=jnp.float32).reshape(2, 4) + 100,
             "c": jnp.arange(4, dtype=jnp.float32).reshape(2, 2) - 7}
    specs = {k: P("pod") for k in grads}
    sync = build_cross_pod_sync(mesh_pdm, specs, pod_axis="pod",
                                mean=True, bucket_bytes=1,
                                attrs=SyncAttributes(stale=2))

    def mean_rows(v):
        m = np.asarray(v).mean(axis=0, keepdims=True)
        return np.repeat(m, 2, axis=0)

    # off-step: only the last bucket ("c") syncs; "a"/"b" stay local
    out1 = jax.jit(lambda g: sync(g, step=1))(grads)
    np.testing.assert_array_equal(np.asarray(out1["a"]),
                                  np.asarray(grads["a"]))
    np.testing.assert_array_equal(np.asarray(out1["b"]),
                                  np.asarray(grads["b"]))
    np.testing.assert_allclose(np.asarray(out1["c"]),
                               mean_rows(grads["c"]), rtol=1e-6)
    # sync step: every bucket averages
    out0 = jax.jit(lambda g: sync(g, step=2))(grads)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out0[k]),
                                   mean_rows(grads[k]), rtol=1e-6)


@pytest.mark.fast
def test_bucketize_validation():
    """Satellite: clear errors for non-positive bucket sizes; zero-byte
    leaves ride no bucket instead of emitting empty ones."""
    with pytest.raises(ValueError, match="bucket_bytes"):
        bucketize([256], 0)
    with pytest.raises(ValueError, match="bucket_bytes"):
        bucketize([256], -4)
    with pytest.raises(ValueError, match="negative"):
        bucketize([256, -1], 512)
    # zero-byte leaves are skipped, never wrapped in empty buckets
    assert bucketize([0, 256, 0, 256, 0], 512) == [[1, 3]]
    assert bucketize([0, 0], 512) == []
    assert bucketize([0, 256, 0], None) == [[1]]


@pytest.mark.slow
def test_cross_pod_sync_bucketed_lpf_path(mesh_pdm):
    """The slot-machinery path (``build_cross_pod_sync(bucket_bytes=)``)
    records each bucket's allreduce as its own LPF program and still
    averages exactly across the pod axis."""
    from repro.bsp.grad_sync import build_cross_pod_sync

    grads = {"a": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
             "b": jnp.arange(24, dtype=jnp.float32),
             "c": jnp.float32(3.0)}
    specs = jax.tree.map(lambda _: P(), grads)
    sync = build_cross_pod_sync(mesh_pdm, specs, pod_axis="pod",
                                mean=True, bucket_bytes=64)
    out = jax.jit(sync)(grads)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(grads[k]), rtol=1e-6)
