"""Bucketed gradient synchronization: HLO + ledger compliance.

``pod_allreduce(method="bucketed", bucket_bytes=B)`` packs per-layer
gradients into ~B-byte buckets, each synced as one reduce-scatter +
all-gather pair: L per-layer supersteps become ceil(sum(B)/bucket).
The compiled HLO must carry exactly that many native collectives, the
ledger's superstep count must drop accordingly, and the total wire
bytes must stay within one bucket's padding of the unbucketed run.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.bsp.pod_sync import bucketize, pod_allreduce
from repro.core import CostLedger, compat
from repro.core.hlo_analysis import parse_collectives


@pytest.mark.fast
def test_bucketize_packing():
    # four equal layers, bucket of two -> two buckets
    assert bucketize([256] * 4, 512) == [[0, 1], [2, 3]]
    # None -> one bucket; tiny bucket -> per-leaf
    assert bucketize([256] * 4, None) == [[0, 1, 2, 3]]
    assert bucketize([256] * 4, 1) == [[0], [1], [2], [3]]
    # an oversized leaf still gets (its own) bucket
    assert bucketize([100, 900, 100], 512) == [[0], [1], [2]]
    assert bucketize([100, 100, 900], 512) == [[0, 1], [2]]
    assert bucketize([], 512) == []


#: a 4-layer toy model: equal f32 layers, 64 elements (256 B) each
LAYERS = 4
LAYER_ELEMS = 64
BUCKET_BYTES = 2 * LAYER_ELEMS * 4          # 2 layers per bucket


def _toy_grads():
    return {f"layer{i}": (jnp.arange(LAYER_ELEMS, dtype=jnp.float32)
                          + i) for i in range(LAYERS)}


def _compile_sync(mesh8, method, bucket_bytes):
    ledger = CostLedger()

    def body(grads):
        return pod_allreduce(grads, 8, "x", mean=True, ledger=ledger,
                             method=method, bucket_bytes=bucket_bytes)

    fn = jax.jit(compat.shard_map(
        body, mesh=mesh8,
        in_specs=(jax.tree.map(lambda _: P(), _toy_grads()),),
        out_specs=jax.tree.map(lambda _: P(), _toy_grads()),
        check_vma=False))
    compiled = fn.lower(_toy_grads()).compile()
    return fn, compiled, ledger


@pytest.mark.slow
def test_bucketed_grad_sync_hlo_and_ledger(mesh8):
    total_bytes = LAYERS * LAYER_ELEMS * 4
    n_buckets = -(-total_bytes // BUCKET_BYTES)         # ceil = 2

    fn, compiled, ledger = _compile_sync(mesh8, "bucketed", BUCKET_BYTES)
    stats = parse_collectives(compiled.as_text())
    # exactly ceil(sum(B)/bucket) reduce-scatter/all-gather pairs
    assert stats.count_by_kind.get("reduce-scatter", 0) == n_buckets
    assert stats.count_by_kind.get("all-gather", 0) == n_buckets
    assert stats.count_by_kind.get("collective-permute", 0) == 0
    assert ledger.supersteps == n_buckets
    assert all(r.method == "bucketed" and r.rounds == 2
               for r in ledger.records)

    # per-layer baseline: one pair per layer, 2x the supersteps
    _, compiled_pl, ledger_pl = _compile_sync(mesh8, "bucketed", 1)
    stats_pl = parse_collectives(compiled_pl.as_text())
    assert stats_pl.count_by_kind.get("reduce-scatter", 0) == LAYERS
    assert ledger_pl.supersteps == LAYERS
    assert ledger.supersteps * (LAYERS // n_buckets) == ledger_pl.supersteps

    # unbucketed (single flatten): wire totals agree within one bucket
    _, _, ledger_un = _compile_sync(mesh8, "rs+ag", None)
    assert ledger_un.supersteps == 1
    assert abs(ledger.wire_bytes - ledger_un.wire_bytes) <= BUCKET_BYTES
    assert abs(ledger_pl.wire_bytes - ledger_un.wire_bytes) <= BUCKET_BYTES

    # and the sync is still an exact mean across the pod axis (every
    # pod feeds the same grads, so the mean is the identity)
    out = fn(_toy_grads())
    for i in range(LAYERS):
        np.testing.assert_allclose(
            np.asarray(out[f"layer{i}"]),
            np.arange(LAYER_ELEMS, dtype=np.float32) + i, rtol=1e-6)


@pytest.mark.slow
def test_bucketed_auto_selection(mesh8):
    """``method='auto'`` rides bucketed when bucket_bytes is given."""
    _, _, ledger = _compile_sync(mesh8, "auto", BUCKET_BYTES)
    assert ledger.supersteps == 2
    assert all(r.method == "bucketed" for r in ledger.records)
    _, _, ledger2 = _compile_sync(mesh8, "auto", None)
    assert ledger2.supersteps == 1 and ledger2.records[0].method == "rs+ag"


@pytest.mark.slow
def test_cross_pod_sync_bucketed_lpf_path(mesh_pdm):
    """The slot-machinery path (``build_cross_pod_sync(bucket_bytes=)``)
    records each bucket's allreduce as its own LPF program and still
    averages exactly across the pod axis."""
    from repro.bsp.grad_sync import build_cross_pod_sync

    grads = {"a": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
             "b": jnp.arange(24, dtype=jnp.float32),
             "c": jnp.float32(3.0)}
    specs = jax.tree.map(lambda _: P(), grads)
    sync = build_cross_pod_sync(mesh_pdm, specs, pod_axis="pod",
                                mean=True, bucket_bytes=64)
    out = jax.jit(sync)(grads)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(grads[k]), rtol=1e-6)
