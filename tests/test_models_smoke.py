"""Per-architecture smoke tests: a REDUCED same-family config runs one
train step (finite loss, non-zero finite grads) and one decode step on
CPU, asserting output shapes — the full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (Runtime, count_params, decode_step, init_caches,
                          init_params, loss_fn, prefill)

pytestmark = pytest.mark.slow


def _batch(cfg, rng, B=2, S=64):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.modality == "vision":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.stub_prefix, cfg.d_model)),
            jnp.float32)
    if cfg.modality == "audio" and cfg.encoder_groups:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    rt = Runtime()
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg, rt)))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # every leaf finite
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g)).all(), \
            jax.tree_util.keystr(path)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, cache_len = 2, 32
    caches = init_caches(cfg, B, cache_len)
    enc_out = None
    if cfg.encoder_groups:
        enc_out = jnp.asarray(rng.standard_normal((B, 16, cfg.d_model)),
                              jnp.bfloat16)
    rt = Runtime()
    tok = jnp.zeros((B,), jnp.int32)
    nxt, logits, caches2 = jax.jit(
        lambda p, t, c: decode_step(p, t, c, jnp.int32(5), cfg, rt,
                                    enc_out))(params, tok, caches)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits[:, :cfg.vocab])).all()
    assert nxt.shape == (B,)
    assert int(nxt.max()) < cfg.vocab      # padded ids can never win
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-130m",
                                  "whisper-base"])
def test_prefill_matches_decode_logits(arch, rng):
    """Teacher-forced decode over a short prompt must produce the same
    final logits as prefill (cache correctness)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 8
    batch = _batch(cfg, rng, B=B, S=S)
    rt = Runtime()
    enc_out = None
    if cfg.encoder_groups:
        from repro.models.lm import _run_encoder, _cast_params
        import jax.numpy as jnp2
        cast = _cast_params(params, jnp2.bfloat16)
        enc_out = _run_encoder(
            {k: (v if k.startswith(("dec_", "enc_")) else cast[k])
             for k, v in params.items()}, batch["frames"], cfg, rt)
    want = prefill(params, batch, cfg, rt)          # [B, V]

    caches = init_caches(cfg, B, S)
    logits = None
    for t in range(S):
        _, logits, caches = decode_step(
            params, batch["tokens"][:, t], caches, jnp.int32(t), cfg, rt,
            enc_out)
    got = logits
    wa = np.asarray(want[:, :cfg.vocab])
    ga = np.asarray(got[:, :cfg.vocab])
    # bf16 accumulation differences only
    assert np.abs(wa - ga).max() / (np.abs(wa).max() + 1e-9) < 0.08


def test_param_counts_match_published():
    expect = {
        "qwen1.5-110b": 111e9, "llama3.2-1b": 1.24e9, "qwen3-14b": 14.8e9,
        "gemma2-9b": 9.2e9, "deepseek-v3-671b": 682e9,
        "mamba2-130m": 0.13e9, "llava-next-mistral-7b": 7.2e9,
        "jamba-v0.1-52b": 51.5e9, "whisper-base": 0.106e9,  # +32k learned positions
        "granite-moe-3b-a800m": 3.9e9,
    }
    for arch, n in expect.items():
        got = count_params(get_config(arch))
        assert abs(got - n) / n < 0.06, (arch, got, n)


def test_active_params_moe():
    ds = count_params(get_config("deepseek-v3-671b"), active_only=True)
    assert 34e9 < ds < 42e9                 # ~37B active
    ja = count_params(get_config("jamba-v0.1-52b"), active_only=True)
    assert 10e9 < ja < 14e9                 # ~12B active
