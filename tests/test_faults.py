"""Fault-tolerant execution: deterministic injection, the graceful
degradation ladder, and recovery supervision.

Three layers under test:
  * the injection machinery itself — plan grammar roundtrip, seeded
    determinism, one counting point per seam, zero-fault transparency;
  * the degradation ladder — persist I/O retry -> disk_errors ->
    memory-only mode, the in-memory poison set for undeletable corrupt
    entries, compiled->dispatched fallback (covered in
    test_compiled_program.py), and with_capacity's resize-and-retry;
  * the supervisor — transient errors absorbed via checkpoint-restore
    with a bounded restart budget, fatal errors propagated unchanged.
"""

import dataclasses
import errno
import os

import numpy as np
import pytest

pytestmark = pytest.mark.fast

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (LPF_SYNC_DEFAULT, LPFCapacityError,  # noqa: E402
                        LPFError, LPFFatalError, LPFTransientError,
                        InjectedFault, LPFMachine, Msg, ProgramCache,
                        ProgramStep, Slot, classify)
from repro.core import faultpoints  # noqa: E402
from repro.core.persist import entry_filename  # noqa: E402
from repro.runtime import faults  # noqa: E402
from repro.runtime.faults import (FaultEvent, FaultPlan,  # noqa: E402
                                  FaultInjector, SMOKE_PLANS)
from repro.runtime.train_loop import (Anomaly, StepSupervisor,  # noqa: E402
                                      TrainLoopConfig, train_loop)

P = 4
MACHINE = LPFMachine(p=P, g=1e-9, l=1e-6, r=1e-10)


def make_slot(sid, size=16):
    return Slot(sid=sid, name=f"s{sid}", size=size,
                dtype=np.dtype("float32"), kind="global",
                orig_shape=(size,))


def shift_trace(n_steps=3, base_sid=0):
    steps = []
    for k in range(n_steps):
        a = make_slot(base_sid + 2 * k)
        b = make_slot(base_sid + 2 * k + 1)
        msgs = tuple(Msg(s, (s + k + 1) % P, a, 0, b, 0, 4 * (k + 1),
                         origin="put") for s in range(P))
        steps.append(ProgramStep(msgs, LPF_SYNC_DEFAULT, f"s{k}"))
    return steps


def build_and_certify(cache, steps=None, base_sid=0):
    steps = steps if steps is not None else shift_trace(base_sid=base_sid)
    prog, key = cache.get_or_build_keyed(steps, P, MACHINE)
    cert = cache.certify(key, steps, prog)
    assert cert.ok
    return prog, key, steps


# ---------------------------------------------------------------------------
# the error taxonomy
# ---------------------------------------------------------------------------

def test_classify_taxonomy():
    assert classify(LPFCapacityError("full")) == "mitigable"
    assert classify(LPFTransientError("blip")) == "transient"
    assert classify(LPFFatalError("broken")) == "fatal"
    assert classify(OSError(errno.EIO, "io")) == "transient"
    assert classify(TimeoutError()) == "transient"
    assert classify(InjectedFault("boom")) == "transient"
    # anything unclassified is fatal — never silently retried
    assert classify(ValueError("?")) == "fatal"
    assert classify(KeyboardInterrupt()) == "fatal"


def test_capacity_error_structured_fields():
    e = LPFCapacityError("full", required=12, capacity=4, kind="queue")
    assert (e.required, e.capacity, e.kind) == (12, 4, "queue")
    assert isinstance(e, LPFError)
    # default-constructed (legacy call sites) stays valid
    e2 = LPFCapacityError("full")
    assert (e2.required, e2.capacity, e2.kind) == (0, 0, "queue")


# ---------------------------------------------------------------------------
# plans: grammar, determinism, arming
# ---------------------------------------------------------------------------

def test_plan_spec_roundtrip():
    spec = ("persist_save@0;persist_load@1x2:bitflip;compile@0x-1;"
            "straggler@2=0.005;capacity@1x3")
    plan = FaultPlan.parse(spec)
    assert plan.spec() == spec
    assert FaultPlan.parse(plan.spec()).spec() == spec
    assert plan.seams() == ("capacity", "compile", "persist_load",
                            "persist_save", "straggler")


@pytest.mark.parametrize("bad", [
    "nosuchseam@0", "persist_save@-1", "persist_save@0x0",
    "persist_save@0:nosuchmode", "compile", "compile@", "@0",
])
def test_plan_rejects_malformed(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


def test_random_plans_are_seed_deterministic():
    seams = ("compile", "straggler", "capacity")
    specs = [FaultPlan.random(seed, seams=seams).spec()
             for seed in range(50)]
    again = [FaultPlan.random(seed, seams=seams).spec()
             for seed in range(50)]
    assert specs == again
    assert len(set(specs)) > 10          # the space is actually explored
    for spec in specs:
        for e in FaultPlan.parse(spec).events:
            assert e.seam in seams


def test_event_due_semantics():
    one = FaultEvent(seam="compile", at=2)
    assert [one.due(i) for i in range(5)] == [False, False, True, False,
                                              False]
    rep = FaultEvent(seam="compile", at=1, repeat=2)
    assert [rep.due(i) for i in range(5)] == [False, True, True, False,
                                              False]
    forever = FaultEvent(seam="compile", at=3, repeat=-1)
    assert [forever.due(i) for i in range(6)] == [False] * 3 + [True] * 3


def test_unarmed_seams_are_noops():
    assert faults.active() is None
    faultpoints.fire("persist_save")            # nothing raises
    assert faultpoints.corrupt("persist_load", b"abc") == b"abc"
    assert faultpoints.delay("straggler") == 0.0


def test_inject_restores_previous_injector():
    outer = faults.arm(FaultPlan.parse("compile@50"))
    try:
        with faults.inject(FaultPlan.parse("compile@60")) as inner:
            assert faults.active() is inner
        assert faults.active() is outer
    finally:
        faults.disarm()
    assert faults.active() is None


def test_env_plan_arming(monkeypatch):
    monkeypatch.setenv("LPF_FAULT_PLAN", "persist_save@0")
    try:
        inj = faults.ensure_env_plan()
        assert inj is not None
        assert inj.plan.spec() == "persist_save@0"
        # idempotent: a second root context must not reset the counters
        inj.counts["persist_save"] = 5
        assert faults.ensure_env_plan() is inj
    finally:
        faults.disarm()


def test_injector_counts_and_fired_log():
    inj = FaultInjector(FaultPlan.parse("persist_save@1"))
    with pytest.raises(OSError):
        try:
            inj.fire("persist_save")             # idx 0: pass
            inj.fire("persist_save")             # idx 1: ENOSPC
        except OSError as e:
            assert e.errno == errno.ENOSPC
            raise
    assert inj.counts["persist_save"] == 2
    assert inj.fired == [("persist_save", 1, "default")]


# ---------------------------------------------------------------------------
# the persist seams + the disk degradation ladder
# ---------------------------------------------------------------------------

def test_save_fault_is_absorbed_and_counted(tmp_path):
    """An injected ENOSPC during write-back costs the warm start (and
    bumps disk_errors), never the execution."""
    cache = ProgramCache(persist_dir=str(tmp_path))
    with faults.inject(FaultPlan.parse("persist_save@0x-1")) as inj:
        prog, key, steps = build_and_certify(cache)
    assert inj.fired
    assert prog is not None
    assert cache.stats.disk_errors >= 1
    assert not os.path.exists(tmp_path / entry_filename(key))
    # the entry is served from memory regardless
    prog2, _ = cache.get_or_build_keyed(steps, P, MACHINE)
    assert prog2 is prog


def test_persistent_disk_failure_degrades_to_memory_only(tmp_path):
    """DISK_STRIKE_LIMIT *consecutive* failed store operations detach
    the store: later lookups never touch the disk (no retry tax), and
    the reason is recorded.  (A single save failure does NOT detach —
    any successful disk op in between resets the strike counter.)"""
    seed = ProgramCache(persist_dir=str(tmp_path))
    traces = []
    for k in range(ProgramCache.DISK_STRIKE_LIMIT):
        # structurally distinct traces (slot renumbering canonicalizes
        # away a mere sid shift, which would collapse them to one key)
        steps = shift_trace(n_steps=k + 1)
        build_and_certify(seed, steps=steps)
        traces.append(steps)

    warm = ProgramCache(persist_dir=str(tmp_path))
    with faults.inject(FaultPlan.parse("persist_load@0x-1")):
        for steps in traces:      # every entry exists -> every read fails
            prog, _ = warm.get_or_build_keyed(steps, P, MACHINE)
            assert prog is not None              # cold build absorbed it
    assert warm.store is None
    assert warm.memory_only_reason is not None
    assert "consecutive" in warm.memory_only_reason
    assert warm.stats.disk_errors == warm.DISK_STRIKE_LIMIT
    # re-attaching resets the ladder
    warm.attach_store(str(tmp_path))
    assert warm.store is not None
    assert warm.memory_only_reason is None


def test_successful_disk_op_resets_strikes(tmp_path):
    """A working disk clears the consecutive-failure count: alternating
    one save failure per build with successful loads never detaches."""
    cache = ProgramCache(persist_dir=str(tmp_path))
    with faults.inject(FaultPlan.parse("persist_save@0x-1")):
        for k in range(cache.DISK_STRIKE_LIMIT + 1):
            build_and_certify(cache, steps=shift_trace(n_steps=k + 1))
    assert cache.store is not None               # still attached
    assert cache.memory_only_reason is None
    assert cache.stats.disk_errors == cache.DISK_STRIKE_LIMIT + 1


def test_transient_load_error_does_not_invalidate(tmp_path):
    """persist_load:oserror is transient: the warm start degrades to a
    cold miss, but the on-disk entry — which is perfectly fine — must
    survive for the next attempt."""
    seed = ProgramCache(persist_dir=str(tmp_path))
    _, key, steps = build_and_certify(seed)
    path = tmp_path / entry_filename(key)
    assert path.exists()

    warm = ProgramCache(persist_dir=str(tmp_path))
    with faults.inject(FaultPlan.parse("persist_load@0x-1")) as inj:
        prog, _ = warm.get_or_build_keyed(steps, P, MACHINE)
    assert inj.fired
    assert prog is not None                      # cold build succeeded
    assert warm.stats.invalidated == 0
    assert warm.stats.disk_errors >= 1
    assert path.exists()                         # NOT invalidated

    # with the fault gone, a fresh cache warm-starts from that entry
    clean = ProgramCache(persist_dir=str(tmp_path))
    clean.get_or_build_keyed(steps, P, MACHINE)
    assert clean.stats.disk_hits == 1


@pytest.mark.parametrize("mode", ["truncate", "bitflip"])
def test_corrupting_load_fault_invalidates(tmp_path, mode):
    """Corruption (vs transient I/O) is final: the entry is counted
    invalidated, removed, and rebuilt cold."""
    seed = ProgramCache(persist_dir=str(tmp_path))
    _, key, steps = build_and_certify(seed)

    warm = ProgramCache(persist_dir=str(tmp_path))
    with faults.inject(FaultPlan.parse(f"persist_load@0:{mode}")) as inj:
        prog, _ = warm.get_or_build_keyed(steps, P, MACHINE)
    assert inj.fired
    assert prog is not None
    assert warm.stats.invalidated == 1
    assert not (tmp_path / entry_filename(key)).exists()


def test_undeletable_invalid_entry_is_poisoned(tmp_path, monkeypatch):
    """When a corrupt entry cannot be removed (read-only cache dir),
    its filename is poisoned in memory: the decode+verify cost is paid
    once, later misses skip the file without touching the disk."""
    seed = ProgramCache(persist_dir=str(tmp_path))
    _, key, steps = build_and_certify(seed)
    fname = entry_filename(key)
    # corrupt the payload on disk (checksum now fails)
    path = tmp_path / fname
    blob = path.read_bytes()
    path.write_bytes(blob[:-4] + b"XXXX")

    warm = ProgramCache(persist_dir=str(tmp_path))
    monkeypatch.setattr(os, "remove",
                        lambda p: (_ for _ in ()).throw(
                            OSError(errno.EROFS, "read-only", str(p))))
    prog, _ = warm.get_or_build_keyed(steps, P, MACHINE)
    assert prog is not None
    assert warm.stats.invalidated == 1
    assert fname in warm._poisoned
    assert path.exists()                         # could not be removed

    # the poisoned entry short-circuits: no second decode, no second
    # invalidation — just a disk miss
    warm._programs.clear(); warm._certs.clear()  # force an in-memory miss
    before = warm.stats.invalidated
    prog2, _ = warm.get_or_build_keyed(steps, P, MACHINE)
    assert prog2 is not None
    assert warm.stats.invalidated == before


def test_attach_store_failure_is_memory_only(tmp_path):
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    cache = ProgramCache(persist_dir=str(blocker / "sub"))
    assert cache.store is None
    assert cache.memory_only_reason is not None
    assert cache.stats.disk_errors == 1
    # and the cache still works
    prog, _, _ = build_and_certify(cache)
    assert prog is not None


# ---------------------------------------------------------------------------
# with_capacity: the paper's resize-and-retry contract
# ---------------------------------------------------------------------------

def _stage_ctx():
    from repro.core import LPFContext
    return LPFContext(())


def test_with_capacity_resizes_queue_and_retries():
    ctx = _stage_ctx()
    a, b = None, None
    ctx.resize_memory_register(2)
    a = ctx.register_global("a", jnp.zeros(8))
    b = ctx.register_global("b", jnp.zeros(8))
    attempts = []

    def body(c):
        attempts.append(c._queue_capacity)
        c.put_msgs([(0, 0, a, 0, b, 0, 8)])
        c._queue = []        # consume (p=1 has no real sync path here)
        return "done"

    assert ctx._queue_capacity == 0
    assert ctx.with_capacity(body) == "done"
    assert len(attempts) == 2                    # failed once, resized
    assert ctx._queue_capacity >= 1


def test_with_capacity_respects_required_field():
    ctx = _stage_ctx()
    calls = []

    def body(c):
        calls.append(True)
        if len(calls) == 1:
            raise LPFCapacityError("need much more", required=1000,
                                   capacity=0, kind="queue")
        return c._queue_capacity

    assert ctx.with_capacity(body) >= 1000


def test_with_capacity_resizes_register():
    ctx = _stage_ctx()

    def body(c):
        # registry capacity 0: first attempt raises kind="register"
        s = c.register_global("x", jnp.zeros(4))
        c.deregister(s)
        return c.registry.capacity

    assert ctx.with_capacity(body) >= 1


def test_with_capacity_bounded_attempts():
    ctx = _stage_ctx()
    calls = []

    def body(c):
        calls.append(True)
        raise LPFCapacityError("never enough", required=2, capacity=1)

    with pytest.raises(LPFCapacityError):
        ctx.with_capacity(body, max_attempts=3)
    assert len(calls) == 3


def test_with_capacity_other_errors_propagate_immediately():
    ctx = _stage_ctx()
    calls = []

    def body(c):
        calls.append(True)
        raise LPFFatalError("not a capacity problem")

    with pytest.raises(LPFFatalError):
        ctx.with_capacity(body)
    assert len(calls) == 1


def test_program_abort_discards_pending_steps():
    """An exception inside ``with ctx.program()`` discards the recorded
    supersteps — a failed region must not flush (execute) a partial
    trace, or the capacity error would have side effects."""
    ctx = _stage_ctx()
    ctx.resize_memory_register(2)
    ctx.resize_message_queue(4)
    a = ctx.register_global("a", jnp.zeros(8))
    b = ctx.register_global("b", jnp.zeros(8))

    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        with ctx.program("doomed"):
            ctx.put_msgs([(0, 0, a, 0, b, 0, 8)])
            ctx.sync(label="recorded-then-aborted")
            raise Boom()
    assert ctx._rec_pending == []
    assert ctx._rec_depth == 0
    assert ctx._queue == []
    assert ctx.ledger.records == []              # nothing executed


# ---------------------------------------------------------------------------
# recovery supervision
# ---------------------------------------------------------------------------

def test_supervisor_absorbs_transient_within_budget():
    sup = StepSupervisor(max_restarts=2, backoff=0.0)
    assert sup.on_error(3, OSError(errno.EIO, "blip")) is True
    assert sup.on_error(5, InjectedFault("xla")) is True
    # budget exhausted: the third transient propagates
    assert sup.on_error(7, OSError(errno.EIO, "blip")) is False
    kinds = [(a.kind, a.action) for a in sup.anomalies]
    assert kinds == [("transient", "restore"), ("transient", "restore"),
                     ("transient", "propagate")]


def test_supervisor_never_retries_fatal_or_mitigable():
    sup = StepSupervisor(max_restarts=5, backoff=0.0)
    assert sup.on_error(0, LPFFatalError("contract")) is False
    assert sup.on_error(1, LPFCapacityError("full")) is False
    assert sup.on_error(2, ValueError("unclassified")) is False
    assert sup.restarts == 0
    assert all(a.action == "propagate" for a in sup.anomalies)


def test_supervisor_records_straggler_verdicts():
    from repro.runtime.monitor import StepVerdict
    sup = StepSupervisor()
    sup.on_verdict(StepVerdict(0, 0.1, 0.0, False, "ok"))
    sup.on_verdict(StepVerdict(1, 9.0, 8.0, True, "skip_sync"))
    sup.on_verdict(StepVerdict(2, 9.0, 8.0, True, "rescale"))
    assert [(a.step, a.action) for a in sup.anomalies] == [
        (1, "skip_sync"), (2, "rescale")]


class _FakeStream:
    def batch(self, step):
        return {"x": np.full((2,), float(step), np.float32)}

    def state(self, step):
        return {"step": step}


def _fake_train_step(fail_at=(), taken=None):
    """A TrainStep-shaped object whose step_fn fails transiently at the
    given global step indices (once each)."""
    from repro.runtime.train_step import TrainStep
    pending = set(fail_at)

    def init_fn(key):
        return {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}

    def step_fn(params, opt, batch):
        step = int(batch["x"][0])
        if taken is not None:
            taken.append(step)
        if step in pending:
            pending.discard(step)
            raise OSError(errno.EIO, f"injected transient at step {step}")
        params = {"w": params["w"] + batch["x"]}
        return params, opt, {"loss": jnp.sum(params["w"])}

    return TrainStep(step_fn=step_fn, init_fn=init_fn,
                     param_sharding=None, opt_sharding=None,
                     batch_sharding=None, rt=None, ledger=None)


def test_train_loop_restores_from_checkpoint_on_transient(tmp_path):
    taken = []
    ts = _fake_train_step(fail_at=(5,), taken=taken)
    out = train_loop(ts, _FakeStream(),
                     TrainLoopConfig(steps=8, ckpt_dir=str(tmp_path),
                                     ckpt_every=2, max_restarts=2,
                                     restart_backoff=0.0))
    assert out["restarts"] == 1
    restores = [a for a in out["anomalies"] if a.action == "restore"]
    assert len(restores) == 1 and restores[0].step == 5
    # rolled back to the newest published checkpoint (step 4) and
    # re-ran 4 and 5 — the loop still completes all 8 steps
    assert taken == [0, 1, 2, 3, 4, 5, 4, 5, 6, 7]
    assert len(out["losses"]) == 8
    # numerics equal the failure-free run (pure-function data pipeline)
    clean = train_loop(_fake_train_step(), _FakeStream(),
                       TrainLoopConfig(steps=8, ckpt_dir=None))
    assert out["losses"] == clean["losses"]


def test_train_loop_propagates_when_budget_exhausted(tmp_path):
    ts = _fake_train_step(fail_at=(2, 3, 4))
    with pytest.raises(OSError):
        train_loop(ts, _FakeStream(),
                   TrainLoopConfig(steps=8, ckpt_dir=str(tmp_path),
                                   ckpt_every=2, max_restarts=2,
                                   restart_backoff=0.0))


def test_train_loop_propagates_fatal_immediately(tmp_path):
    from repro.runtime.train_step import TrainStep

    def init_fn(key):
        return {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}

    def step_fn(params, opt, batch):
        raise LPFFatalError("one-sided contract violation")

    ts = TrainStep(step_fn=step_fn, init_fn=init_fn, param_sharding=None,
                   opt_sharding=None, batch_sharding=None, rt=None,
                   ledger=None)
    with pytest.raises(LPFFatalError):
        train_loop(ts, _FakeStream(),
                   TrainLoopConfig(steps=4, ckpt_dir=str(tmp_path),
                                   max_restarts=5, restart_backoff=0.0))


def test_restore_latest_roundtrip(tmp_path):
    from repro.checkpoint import AsyncCheckpointer
    ckpt = AsyncCheckpointer(str(tmp_path))
    like = {"w": jax.ShapeDtypeStruct((2,), jnp.float32)}
    step, state = ckpt.restore_latest(like)
    assert step is None and state is None
    ckpt.save(7, {"w": jnp.arange(2.0)})
    step, state = ckpt.restore_latest(like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(state["w"]), [0.0, 1.0])


# ---------------------------------------------------------------------------
# the chaos invariant, in-process (one cheap plan per seam family)
# ---------------------------------------------------------------------------

def test_chaos_smoke_warm_start_plans():
    from repro.runtime.faults import _run_one
    baselines = {}
    for workload, spec in SMOKE_PLANS:
        if workload != "warm_start":
            continue                 # mesh workloads run in the chaos tier
        verdict, detail = _run_one(workload, FaultPlan.parse(spec),
                                   baselines)
        assert verdict in ("identical", "classified"), \
            (workload, spec, verdict, detail)


def test_zero_fault_path_is_transparent(tmp_path):
    """With no plan armed, a run through every seam-bearing path equals
    a run of the seed code: same programs, same stats, no injector
    consulted."""
    assert faults.active() is None
    c1 = ProgramCache(persist_dir=str(tmp_path / "a"))
    c2 = ProgramCache(persist_dir=str(tmp_path / "b"))
    _, k1, _ = build_and_certify(c1)
    _, k2, _ = build_and_certify(c2)
    assert k1 == k2
    blob1 = (tmp_path / "a" / entry_filename(k1)).read_bytes()
    blob2 = (tmp_path / "b" / entry_filename(k2)).read_bytes()
    assert blob1 == blob2                        # byte-identical entries
    assert c1.stats.disk_errors == 0 and c1.stats.compile_fallbacks == 0
    assert dataclasses.asdict(c1.stats) == dataclasses.asdict(c2.stats)
