"""End-to-end behaviour of the paper's system: the three headline claims.

1. Model compliance: sync cost is pattern-independent (h-relation only).
2. Immortal FFT: one algorithm, correct on any mesh width, cost
   parametrised by lpf_probe.
3. Interoperability: the same LPF PageRank runs unmodified inside a
   foreign host program (here: hooked into an arbitrary jit'd step).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import bsp, core as lpf
from repro.core import compat
from repro.algorithms import (bsp_fft, partition_graph, reference_pagerank,
                              rmat_graph)
from repro.algorithms.pagerank import pagerank_spmd

pytestmark = pytest.mark.slow


def test_model_compliance_pattern_independence(mesh8):
    """Two very different patterns with the same h-relation must be
    billed the same h by the ledger (the BSP promise)."""
    def shift(ctx, s, p, _):
        src = ctx.register_global("a", jnp.zeros(8))
        dst = ctx.register_global("b", jnp.zeros(8))
        ctx.resize_message_queue(p)
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=7)
        ctx.sync()
        return ctx.tensor(dst)

    def scatter7(ctx, s, p, _):
        src = ctx.register_global("a", jnp.zeros(8))
        dst = ctx.register_global("b", jnp.zeros(8))
        ctx.resize_message_queue(p * p)
        # each pid sends 1 element to every OTHER pid: h = 7 elements
        ctx.put_msgs([(s_, d, src, d, dst, s_, 1)
                      for s_ in range(p) for d in range(p) if s_ != d])
        ctx.sync()
        return ctx.tensor(dst)

    ledgers = []
    for fn in (shift, scatter7):
        def spmd(ctx, s, p, a, fn=fn):
            ctx.resize_memory_register(2)
            return fn(ctx, s, p, a)
        _, ledger = lpf.exec_(mesh8, spmd, out_specs=P("x"),
                              return_ledger=True)
        ledgers.append(ledger)
    h1 = ledgers[0].records[0].h_bytes
    h2 = ledgers[1].records[0].h_bytes
    assert h1 == 7 * 4 and h2 == 7 * 4   # identical h despite the pattern


def test_immortal_fft_any_width(rng):
    """The same FFT code on p = 2, 4, 8 — immortality in practice."""
    n = 1024
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    ref = np.fft.fft(x)
    for p in (2, 4, 8):
        mesh = compat.make_mesh((p,), ("x",))
        y = bsp_fft(mesh, jnp.asarray(x))
        assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 2e-4


def test_interop_hook_inside_host_program(mesh8):
    """Algorithm 3 analogue: a 'host' SPMD program (not written for LPF)
    calls the LPF PageRank via hook, zero changes to either side."""
    n, p = 64, 8
    edges = rmat_graph(n, 180, seed=11)
    g = partition_graph(edges, n, p)
    ref, _ = reference_pagerank(edges, n)

    shard = {
        "row_ids": jnp.asarray(g.row_ids), "col_ext": jnp.asarray(g.col_ext),
        "vals": jnp.asarray(g.vals), "pack_idx": jnp.asarray(g.pack_idx),
        "dangling": jnp.asarray(g.dangling),
    }

    def host_program(args):
        # ... arbitrary host computation ...
        acc = jnp.sum(args["row_ids"] * 0.0)

        def spmd(ctx, s, p_, a):
            local = {k: v.reshape(v.shape[1:]) for k, v in a.items()}
            r, it, res = pagerank_spmd(ctx, g, local, tol=1e-7,
                                       max_iter=200)
            return r

        r_local = lpf.hook(("x",), spmd, args)   # <- the interop call
        return r_local + acc

    fn = jax.jit(compat.shard_map(
        host_program, mesh=mesh8,
        in_specs=({k: P("x") for k in shard},), out_specs=P("x"),
        check_vma=False))
    r = np.asarray(fn(shard)).reshape(-1)
    assert np.abs(r - ref).max() / ref.max() < 1e-3
