"""``fft_h_bytes`` vs the *measured* ledger — the immortal cost claim.

The BSP FFT's documented cost is (n/p)(p-1)/p * itemsize bytes per
superstep (one redistribution unordered, plus an equal reorder pass when
ordered), with itemsize the complex element width: 8 for complex64, 16
for complex128.  Until now only the precision path was regression-tested
(``test_fft_precision.py``); here the predictor is checked against the
h-relation the executed supersteps actually ledgered, for both dtypes
and both output orders — through the recorded-program path the FFT now
runs on.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.algorithms import bsp_fft
from repro.algorithms.fft import fft_h_bytes

pytestmark = pytest.mark.slow


def _run(mesh8, n, dtype, ordered):
    rng = np.random.default_rng(42)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(dtype)
    y, ledger = bsp_fft(mesh8, jnp.asarray(x), ordered=ordered,
                        return_ledger=True)
    ref = np.fft.fft(x)
    rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
    return ledger, rel


@pytest.mark.parametrize("ordered", [True, False])
def test_fft_ledger_matches_h_bytes_complex64(mesh8, ordered):
    n, p = 1024, 8
    ledger, rel = _run(mesh8, n, np.complex64, ordered)
    assert rel < 2e-4
    assert ledger.supersteps == (2 if ordered else 1)
    want = fft_h_bytes(n, p, ordered=ordered, itemsize=8)
    assert ledger.h_bytes == want
    # each superstep is the canonical total exchange: a single fused
    # collective whose wire bytes equal its h-relation
    for r in ledger.records:
        assert r.method == "fused" and r.rounds == 1
        assert r.wire_bytes == r.h_bytes


@pytest.mark.parametrize("ordered", [True, False])
def test_fft_ledger_matches_h_bytes_complex128(mesh8, ordered):
    n, p = 1024, 8
    with jax.experimental.enable_x64():
        ledger, rel = _run(mesh8, n, np.complex128, ordered)
    assert rel < 1e-10
    assert ledger.supersteps == (2 if ordered else 1)
    want = fft_h_bytes(n, p, ordered=ordered, itemsize=16)
    assert ledger.h_bytes == want
    assert want == 2 * fft_h_bytes(n, p, ordered=ordered, itemsize=8)
    for r in ledger.records:
        assert r.method == "fused" and r.rounds == 1
