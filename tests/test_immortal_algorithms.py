"""Immortal algorithms: BSP FFT and LPF PageRank vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.algorithms import (banded_graph, bsp_fft, dataflow_pagerank,
                              fft_h_bytes, lpf_pagerank, partition_graph,
                              reference_pagerank, rmat_graph)

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("n", [64, 512, 4096])
@pytest.mark.parametrize("ordered", [True, False])
def test_fft_matches_numpy(mesh8, rng, n, ordered):
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    y = bsp_fft(mesh8, jnp.asarray(x), ordered=ordered)
    ref = np.fft.fft(x)
    assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 2e-4


def test_fft_inverse_roundtrip(mesh8, rng):
    n = 1024
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    y = bsp_fft(mesh8, jnp.asarray(x))
    xi = bsp_fft(mesh8, y, inverse=True)
    assert np.abs(np.asarray(xi) - x).max() < 2e-3


def test_fft_ledger_matches_immortal_cost(mesh8, rng):
    n = 2048
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    _, ledger = bsp_fft(mesh8, jnp.asarray(x), return_ledger=True)
    assert ledger.h_bytes == fft_h_bytes(n, 8, ordered=True)
    assert ledger.supersteps == 2          # one redistribution + ordering


@settings(max_examples=6, deadline=None)
@given(st.integers(6, 12))
def test_fft_property_sizes(mesh8, logn):
    n = 1 << logn
    rng = np.random.default_rng(logn)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    y = bsp_fft(mesh8, jnp.asarray(x))
    ref = np.fft.fft(x)
    assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 2e-4


def test_pagerank_banded(mesh8):
    edges = banded_graph(64, 3)
    g = partition_graph(edges, 64, 8)
    r, iters, res = lpf_pagerank(mesh8, g, tol=1e-7)
    ref, _ = reference_pagerank(edges, 64)
    assert np.abs(np.asarray(r) - ref).max() < 1e-5
    assert abs(np.asarray(r).sum() - 1.0) < 1e-4


def test_pagerank_rmat_with_dangling(mesh8):
    edges = rmat_graph(128, 400, seed=3)
    g = partition_graph(edges, 128, 8)
    r, iters, res = lpf_pagerank(mesh8, g, tol=1e-7, max_iter=300)
    ref, _ = reference_pagerank(edges, 128, tol=1e-12)
    assert np.abs(np.asarray(r) - ref).max() / ref.max() < 1e-3
    assert iters < 300                     # converged, not capped


def test_pagerank_h_bytes_static(mesh8):
    edges = rmat_graph(128, 400, seed=3)
    g = partition_graph(edges, 128, 8)
    # halo plan is static: h-relation independent of values
    assert g.h_bytes() > 0
    assert g.halo_max >= max(c for (_, _, _, _, c) in g.msgs)


def test_dataflow_baseline_unnormalised(rng):
    """The 'pure Spark' baseline reproduces SparkPageRank semantics:
    ranks sum to ~n only when there are no dangling nodes."""
    edges = banded_graph(32, 2)
    r = dataflow_pagerank(edges, 32, iters=20)
    assert abs(r.sum() - 32.0) < 1e-2


def test_partition_roundtrip_spmv(mesh8, rng):
    """One LPF halo exchange + local SpMV equals the dense A @ r."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.algorithms.pagerank import _halo_exchange

    n, p = 64, 8
    edges = rmat_graph(n, 200, seed=5)
    g = partition_graph(edges, n, p)
    r0 = rng.random(n).astype(np.float32)

    A = np.zeros((n, n), np.float32)
    outdeg = np.bincount(edges[:, 0], minlength=n)
    for s, d in edges:
        A[d, s] = 1.0 / outdeg[s]
    want = A @ r0

    args = {
        "row_ids": jnp.asarray(g.row_ids), "col_ext": jnp.asarray(g.col_ext),
        "vals": jnp.asarray(g.vals), "pack_idx": jnp.asarray(g.pack_idx),
        "r": jnp.asarray(r0.reshape(p, -1)),
    }

    def spmd(ctx, s, pp, a):
        rl = a["r"].reshape(a["r"].shape[1:])
        halo = _halo_exchange(ctx, g, rl, lpf.LPF_SYNC_DEFAULT,
                              a["pack_idx"].reshape(-1))
        x_ext = jnp.concatenate([rl, halo])
        contrib = a["vals"].reshape(-1) * x_ext[a["col_ext"].reshape(-1)]
        return jax.ops.segment_sum(contrib, a["row_ids"].reshape(-1),
                                   num_segments=g.rows + 1)[:g.rows]

    out = lpf.exec_(mesh8, spmd, args,
                    in_specs={k: P("x") for k in args},
                    out_specs=P("x"))
    np.testing.assert_allclose(np.asarray(out).reshape(-1), want,
                               rtol=1e-5, atol=1e-6)
