"""Whole-program compilation: fused replay vs step-by-step dispatch.

The tentpole invariant: compiling an optimized ``SuperstepProgram`` into
one jitted XLA computation changes *nothing observable* — slot values
are bit-identical to per-superstep dispatch (and to the numpy
differential oracle), and the ledger records the exact same
``SuperstepCost`` entries (model compliance survives fusion).  Plus the
``compile_loop`` surface: counted/conditional iterated programs rolled
into one ``lax.scan``/``while_loop``.
"""

import numpy as np
import pytest

pytestmark = pytest.mark.fast

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import core as lpf  # noqa: E402
from repro.core import (LPF_SYNC_DEFAULT, Msg, ProgramStep, Slot,  # noqa: E402
                        SyncAttributes, compat, simulate_program)

P_MESH = 8


def make_slot(sid, size, dtype="int32"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind="global", orig_shape=(size,))


# ---------------------------------------------------------------------------
# canned traces (the shapes the paper's workloads record)
# ---------------------------------------------------------------------------

def fft_redistribute_trace(p=P_MESH, w=8):
    """Redistribute + reorder, the reorder reading the redistribute's
    destination (a serial dependency chain)."""
    src, buf, out = (make_slot(100, p * w), make_slot(101, p * w),
                     make_slot(102, p * w))
    redist = tuple(Msg(s, d, src, d * w, buf, s * w, w)
                   for s in range(p) for d in range(p))
    reorder = tuple(Msg(s, d, buf, d * w, out, s * w, w)
                    for s in range(p) for d in range(p))
    return [src, buf, out], [
        ProgramStep(redist, LPF_SYNC_DEFAULT, "fft.redistribute"),
        ProgramStep(reorder, LPF_SYNC_DEFAULT, "fft.reorder")]


def bucketed_sync_trace(p=P_MESH, n_buckets=3, w=8):
    """The DDP bucket shape: per bucket a reduce-scatter into a chunk,
    then an all-gather of the chunks (independent across buckets — the
    schedule search overlaps them)."""
    slots, steps, sid = [], [], 200
    for k in range(n_buckets):
        src, buf, out = (make_slot(sid, p * w), make_slot(sid + 1, w),
                         make_slot(sid + 2, p * w))
        sid += 3
        slots += [src, buf, out]
        rs = tuple(Msg(s, d, src, d * w, buf, 0, w)
                   for s in range(p) for d in range(p))
        ag = tuple(Msg(s, d, buf, 0, out, s * w, w)
                   for s in range(p) for d in range(p))
        steps += [ProgramStep(rs, SyncAttributes(reduce_op="sum"),
                              f"b{k}.rs"),
                  ProgramStep(ag, LPF_SYNC_DEFAULT, f"b{k}.ag")]
    return slots, steps


def pagerank_trace(p=P_MESH, w=8):
    """The PageRank iteration shape: an irregular halo permutation, an
    accumulating reduction of a 3-word stats vector to pid 0, and its
    broadcast back."""
    rank = make_slot(300, p * w)
    halo = make_slot(301, w)
    stats = make_slot(302, 3)
    tot = make_slot(303, 3)
    halo_msgs = tuple(Msg(s, (s * 3 + 1) % p, rank, (s % 4) * w, halo, 0, w)
                      for s in range(p))
    red = tuple(Msg(s, 0, stats, 0, tot, 0, 3) for s in range(p))
    bcast = tuple(Msg(0, d, tot, 0, tot, 0, 3) for d in range(1, p))
    return [rank, halo, stats, tot], [
        ProgramStep(halo_msgs, LPF_SYNC_DEFAULT, "pr.halo"),
        ProgramStep(red, SyncAttributes(reduce_op="sum"), "pr.red"),
        ProgramStep(bcast, LPF_SYNC_DEFAULT, "pr.bcast")]


CANNED = {
    "fft_redistribute": fft_redistribute_trace,
    "bucketed_sync": bucketed_sync_trace,
    "pagerank": pagerank_trace,
}


def _init_np(slots, p):
    """Deterministic initial values, mirrored on the numpy oracle and
    the mesh (both a pure function of (sid, pid, index))."""
    return {s.sid: np.stack([
        np.arange(s.size, dtype=np.int64) * 7 + s.sid * 1000 + pid * 37
        for pid in range(p)]).astype(np.int32) for s in slots}


def _run_trace_on_mesh(mesh8, slots, steps, *, compiled,
                       plan_cache=None, program_cache=None):
    """Issue a canned ProgramStep trace through the real ``ctx.program``
    path; returns ({sid: [p, size] np.ndarray}, ledger records, ctx)."""
    # NOT `plan_cache or ...`: both caches define __len__, so an EMPTY
    # cache passed by a test is falsy and would be silently replaced
    pc = plan_cache if plan_cache is not None else lpf.PlanCache()
    pgc = program_cache if program_cache is not None \
        else lpf.ProgramCache()
    box = {}

    def wrapped(_):
        ctx = lpf.LPFContext(("x",), plan_cache=pc, program_cache=pgc)
        if compiled is not None:   # None: leave the env default in charge
            ctx.compile_programs = compiled
        ctx.resize_memory_register(len(slots) + 1)
        ctx.resize_message_queue(max(len(st.msgs) for st in steps))
        smap = {}
        for s in slots:
            init = (jnp.arange(s.size, dtype=jnp.int32) * 7
                    + s.sid * 1000 + ctx.pid.astype(jnp.int32) * 37)
            smap[s.sid] = ctx.register_global(s.name, init)
        with ctx.program("canned"):
            for st in steps:
                ctx.put_msgs([(m.src, m.dst, smap[m.src_slot.sid],
                               m.src_off, smap[m.dst_slot.sid],
                               m.dst_off, m.size) for m in st.msgs])
                ctx.sync(st.attrs, label=st.label)
        box["ledger"] = ctx.ledger
        box["ctx"] = ctx
        return tuple(ctx.value(smap[s.sid]) for s in slots)

    fn = jax.jit(compat.shard_map(
        wrapped, mesh=mesh8, in_specs=(P(),),
        out_specs=tuple(P("x") for _ in slots), check_vma=False))
    outs = fn(jnp.zeros(1))
    values = {s.sid: np.asarray(v).reshape(P_MESH, s.size)
              for s, v in zip(slots, outs)}
    return values, list(box["ledger"].records), box["ctx"]


# ---------------------------------------------------------------------------
# fused == dispatched == oracle, values AND ledger
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CANNED))
def test_fused_matches_dispatched_and_oracle(mesh8, name):
    slots, steps = CANNED[name]()
    oracle = simulate_program([(s.msgs, s.attrs) for s in steps],
                              _init_np(slots, P_MESH))
    fused, led_f, _ = _run_trace_on_mesh(mesh8, slots, steps,
                                         compiled=True)
    disp, led_d, _ = _run_trace_on_mesh(mesh8, slots, steps,
                                        compiled=False)
    for s in slots:
        assert (fused[s.sid] == oracle[s.sid]).all(), (name, s.sid)
        assert (fused[s.sid] == disp[s.sid]).all(), (name, s.sid)
    # ledger bit-for-bit: fusion must not change a single cost field
    assert led_f == led_d, name
    assert len(led_f) >= 1


def test_compiled_entry_created_and_replayed(mesh8):
    """10 replays of one recorded program: ONE compiled artifact,
    called once per replay — the XLA computation is built once and the
    per-replay Python work is a cache lookup + call."""
    pc, pgc = lpf.PlanCache(), lpf.ProgramCache()
    box = {}

    def wrapped(_):
        ctx = lpf.LPFContext(("x",), plan_cache=pc, program_cache=pgc)
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * ctx.p)
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))
        for _i in range(10):
            with ctx.program():
                ctx.put(a, b, to=lambda s_: (s_ + 1) % ctx.p, size=4)
                ctx.sync(label="shift")
                ctx.put(a, b, to=lambda s_: (s_ + 2) % ctx.p, dst_off=4,
                        size=4)
                ctx.sync(label="shift2")
        box["stats"] = ctx.cache_stats
        return ctx.value(b)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8, in_specs=(P(),),
                                  out_specs=P("x"), check_vma=False))
    out = np.asarray(fn(jnp.zeros(1))).reshape(8, 8)
    for d in range(8):
        np.testing.assert_allclose(out[d, :4], np.arange(4.0) + (d - 1) % 8)
        np.testing.assert_allclose(out[d, 4:], np.arange(4.0) + (d - 2) % 8)
    assert box["stats"]["program"].misses == 1
    assert len(pgc._compiled) == 1
    (cp,) = [cp for per_axes in pgc._compiled.values()
             for cp in per_axes.values()]
    assert cp.n_calls == 10


def test_compile_opt_out_env(mesh8, monkeypatch):
    """LPF_COMPILE_PROGRAMS=0 restores per-superstep dispatch."""
    monkeypatch.setenv("LPF_COMPILE_PROGRAMS", "0")
    slots, steps = fft_redistribute_trace()
    pgc = lpf.ProgramCache()
    vals, _, ctx = _run_trace_on_mesh(mesh8, slots, steps, compiled=None,
                                      program_cache=pgc)

    oracle = simulate_program([(s.msgs, s.attrs) for s in steps],
                              _init_np(slots, P_MESH))
    for s in slots:
        assert (vals[s.sid] == oracle[s.sid]).all()
    assert not ctx.compile_programs
    assert len(pgc._compiled) == 0


# ---------------------------------------------------------------------------
# compile_loop
# ---------------------------------------------------------------------------

def test_compile_loop_counted_with_collect(mesh8):
    """4 counted iterations of a one-superstep ring shift in ONE scan:
    final value equals 4 composed shifts, the collected ys stack one
    entry per iteration, the body's program is ledgered exactly once,
    and the program cache sees exactly one miss."""
    pc, pgc = lpf.PlanCache(), lpf.ProgramCache()
    box = {}

    def wrapped(_):
        ctx = lpf.LPFContext(("x",), plan_cache=pc, program_cache=pgc)

        def body(c2, carry):
            c2.resize_memory_register(2)
            c2.resize_message_queue(c2.p)
            a = c2.register_global("a", carry)
            b = c2.register_global("b", jnp.zeros_like(carry))
            c2.put(a, b, to=lambda s_: (s_ + 1) % c2.p, size=4)
            c2.sync(label="shift")
            out = c2.value(b)
            c2.deregister(a)
            c2.deregister(b)
            return out

        x0 = jnp.arange(4.0) + ctx.pid
        final, ys = ctx.compile_loop(body, x0, n_iters=4,
                                     label="ring",
                                     collect=lambda c: c[:1])
        box["ledger"] = ctx.ledger
        return final, ys

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8, in_specs=(P(),),
                                  out_specs=(P("x"), P(None, "x")),
                                  check_vma=False))
    final, ys = fn(jnp.zeros(1))
    final = np.asarray(final).reshape(8, 4)
    ys = np.asarray(ys).reshape(4, 8)
    for d in range(8):
        np.testing.assert_allclose(final[d], np.arange(4.0) + (d - 4) % 8)
        # iteration k collects element 0 of the (k+1)-shifted vector
        np.testing.assert_allclose(ys[:, d],
                                   [(d - k - 1) % 8 for k in range(4)])
    # one superstep per body, ledgered once (trace-once semantics)
    records = box["ledger"].records
    assert len(records) == 1 and records[0].label == "shift"
    assert pgc.stats.misses == 1


def test_compile_loop_while_matches_python_loop(mesh8):
    """cond-driven loop == the same body iterated by hand."""
    def run(use_loop):
        def wrapped(_):
            ctx = lpf.LPFContext(("x",))

            def body(c2, carry):
                v, it = carry
                c2.resize_memory_register(2)
                c2.resize_message_queue(c2.p)
                a = c2.register_global("a", v)
                b = c2.register_global("b", jnp.zeros_like(v))
                c2.put(a, b, to=lambda s_: (s_ + 1) % c2.p, size=4)
                c2.sync(label="shift")
                out = c2.value(b)
                c2.deregister(a)
                c2.deregister(b)
                return out + 1.0, it + 1

            v0 = (jnp.arange(4.0) + ctx.pid, jnp.zeros((), jnp.int32))
            if use_loop:
                v, it = ctx.compile_loop(
                    body, v0, cond=lambda c: c[1] < 3, label="w")
            else:
                v, it = v0
                for _ in range(3):
                    v, it = body(ctx, (v, it))
            return v

        fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8,
                                      in_specs=(P(),), out_specs=P("x"),
                                      check_vma=False))
        return np.asarray(fn(jnp.zeros(1))).reshape(8, 4)

    np.testing.assert_array_equal(run(True), run(False))


def test_compile_loop_argument_validation(mesh8):
    def wrapped_both(_):
        ctx = lpf.LPFContext(("x",))
        ctx.compile_loop(lambda c2, c: c, jnp.zeros(1), n_iters=2,
                         cond=lambda c: True)
        return jnp.zeros(1)

    def wrapped_collect_while(_):
        ctx = lpf.LPFContext(("x",))
        ctx.compile_loop(lambda c2, c: c, jnp.zeros(1),
                         cond=lambda c: True, collect=lambda c: c)
        return jnp.zeros(1)

    for bad in (wrapped_both, wrapped_collect_while):
        fn = jax.jit(compat.shard_map(bad, mesh=mesh8, in_specs=(P(),),
                                      out_specs=P(), check_vma=False))
        with pytest.raises(Exception, match="compile_loop|collect"):
            fn(jnp.zeros(1))


# ---------------------------------------------------------------------------
# graceful degradation: compiled -> dispatched fallback + quarantine
# ---------------------------------------------------------------------------

def test_compile_failure_falls_back_to_dispatched(mesh8):
    """An injected whole-program compilation failure degrades to the
    dispatched ``execute_schedule`` path: values AND ledger bit-for-bit
    identical to both the clean compiled run and the oracle, the
    (key, axes) is quarantined, and ``compile_fallbacks`` counts it."""
    from repro.runtime import faults

    slots, steps = fft_redistribute_trace()
    oracle = simulate_program([(s.msgs, s.attrs) for s in steps],
                              _init_np(slots, P_MESH))
    clean_vals, clean_led, _ = _run_trace_on_mesh(
        mesh8, slots, steps, compiled=True)

    pgc = lpf.ProgramCache()
    with faults.inject(faults.FaultPlan.parse("compile@0x-1")) as inj:
        vals, led, ctx = _run_trace_on_mesh(
            mesh8, slots, steps, compiled=True, program_cache=pgc)
    assert inj.fired, "the compile seam never fired"
    for s in slots:
        assert (vals[s.sid] == oracle[s.sid]).all()
        assert (vals[s.sid] == clean_vals[s.sid]).all()
    assert led == clean_led
    assert pgc.stats.compile_fallbacks == 1
    assert len(pgc._compiled) == 0
    (key,) = pgc._programs.keys()
    assert pgc.compile_quarantined(key, ("x",))


def test_quarantine_skips_compile_on_replay(mesh8):
    """After a compile failure quarantines the signature, replays go
    straight to the dispatched path: the compile seam is never
    consulted again (no repeated doomed compiles), and the fallback
    counter stays at one."""
    from repro.runtime import faults

    slots, steps = fft_redistribute_trace()
    pc, pgc = lpf.PlanCache(), lpf.ProgramCache()
    with faults.inject(faults.FaultPlan.parse("compile@0x-1")) as inj:
        vals1, led1, _ = _run_trace_on_mesh(
            mesh8, slots, steps, compiled=True,
            plan_cache=pc, program_cache=pgc)
        fired_after_first = len(inj.fired)
        vals2, led2, _ = _run_trace_on_mesh(
            mesh8, slots, steps, compiled=True,
            plan_cache=pc, program_cache=pgc)
    assert fired_after_first == 1
    # the replay hit the quarantine before compile_program ran: the
    # forever-armed compile event had no second invocation to fire on
    assert len(inj.fired) == 1
    assert inj.counts["compile"] == 1
    assert pgc.stats.compile_fallbacks == 1
    for s in slots:
        assert (vals1[s.sid] == vals2[s.sid]).all()
    assert led1 == led2


def test_lpf_errors_never_degraded_around(mesh8):
    """The ladder only degrades around *foreign* failures: an LPF error
    raised during compilation (here: a capacity error injected at the
    compile seam's position via a monkeypatched compile_program) must
    propagate, not fall back."""
    import repro.core.context as context_mod

    slots, steps = fft_redistribute_trace()
    pgc = lpf.ProgramCache()
    orig = context_mod.compile_program

    def boom(*a, **k):
        raise lpf.LPFFatalError("contract violation during lowering")

    context_mod.compile_program = boom
    try:
        with pytest.raises(Exception, match="contract violation"):
            _run_trace_on_mesh(mesh8, slots, steps, compiled=True,
                               program_cache=pgc)
    finally:
        context_mod.compile_program = orig
    assert pgc.stats.compile_fallbacks == 0
