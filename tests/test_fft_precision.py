"""Twiddle-precision regression for the BSP FFT.

The time-shifted twiddle ``w_n^{s k2}`` must be computed in the real
dtype matching the input's precision: a float32 phase wraps ``s * k2``
products up to ~p * n, which at n >= 2**16 costs ~1e-3 relative error —
three orders of magnitude above complex128's capability.  (Standalone
from ``test_immortal_algorithms.py`` so it runs without hypothesis.)
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.algorithms import bsp_fft

pytestmark = pytest.mark.slow


def test_fft_complex128_twiddle_precision(mesh8):
    """n = 2**16 complex128 FFT must reach float64-grade accuracy; the
    float32-phase bug sat at ~1e-3 relative error on this input."""
    n = 1 << 16
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(0)
        x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
             ).astype(np.complex128)
        y = np.asarray(bsp_fft(mesh8, jnp.asarray(x)))
        ref = np.fft.fft(x)
        rel = np.abs(y - ref).max() / np.abs(ref).max()
        assert rel < 1e-10, rel


def test_fft_complex64_still_accurate(mesh8):
    """The dtype-dependent phase must not disturb the complex64 path."""
    n = 1 << 12
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    y = np.asarray(bsp_fft(mesh8, jnp.asarray(x)))
    ref = np.fft.fft(x)
    assert np.abs(y - ref).max() / np.abs(ref).max() < 2e-4
