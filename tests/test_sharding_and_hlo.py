"""Unit tests: sharding rules, HLO collective parsing, roofline math,
dry-run cell helpers (configs x shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable, get_config, input_specs
from repro.core import compat
from repro.core.hlo_analysis import (RooflineTerms, parse_collectives,
                                     roofline_terms)
from repro.models import init_params
from repro.sharding.rules import batch_specs, cache_specs, param_specs

pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_cover_all_archs(mesh_pdm):
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        shapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        specs = param_specs(shapes, mesh_pdm)
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)
        for sh, sp in zip(flat_shapes, flat_specs):
            assert len(sp) <= len(sh.shape)


def test_param_specs_names(mesh_pdm):
    cfg = get_config("llama3.2-1b", smoke=True)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, mesh_pdm)
    assert specs["embed"] == P("model", "data")
    # scanned leaves have the layer dim unsharded
    assert specs["dec_body"]["b0"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["dec_body"]["b0"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["dec_body"]["b0"]["ln1"]["w"] == P(None, None)


def test_specs_drop_missing_axes():
    mesh_d = compat.make_mesh((8,), ("data",))
    cfg = get_config("llama3.2-1b", smoke=True)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, mesh_d)
    # 'model' silently dropped -> elastic to smaller meshes
    assert specs["dec_body"]["b0"]["attn"]["wq"] == P(None, "data", None)


def test_cache_specs_divisibility(mesh_pdm):
    from repro.models import init_caches
    cfg = get_config("mamba2-130m", smoke=True)
    shapes = jax.eval_shape(lambda: init_caches(cfg, 4, 16))
    specs = cache_specs(shapes, mesh_pdm, batch_axes=("data",),
                        seq_axes=("model",))
    ssm = specs["body"]["b0"]["ssm"]
    # smoke mamba has 8 heads (128*2/32): divisible by model=2 -> sharded
    assert ssm == P(None, ("data",), "model", None, None)


def test_batch_specs(mesh_pdm):
    b = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = batch_specs(b, mesh_pdm)
    assert specs["tokens"] == P(("pod", "data"), None)


# ---------------------------------------------------------------------------
# input specs / applicability (the 40-cell definition)
# ---------------------------------------------------------------------------

def test_matrix_is_40_cells():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if applicable(*c)[0]]
    skipped = [c for c in cells if not applicable(*c)[0]]
    assert len(skipped) == 8               # long_500k for 8 full-attn archs
    assert all(s == "long_500k" for _, s in skipped)
    assert ("mamba2-130m", "long_500k") in runnable
    assert ("jamba-v0.1-52b", "long_500k") in runnable


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    b = input_specs(cfg, "train_4k")
    total = b["tokens"].shape[1] + (cfg.stub_prefix
                                    if cfg.modality == "vision" else 0)
    assert b["tokens"].shape[0] == 256
    assert total == 4096
    d = input_specs(cfg, "decode_32k")
    assert d["token"].shape == (128,)
    if cfg.encoder_groups:
        assert d["enc_out"].shape[0] == 128


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule test
fused {
  a = f32[128,256]{1,0} parameter(0)
}
ENTRY main {
  p0 = f32[128,256]{1,0} parameter(0)
  ag = f32[256,256]{1,0} all-gather(p0), dimensions={0}
  ar.1 = f32[128,256]{1,0} all-reduce(p0), to_apply=add
  rs = f32[64,256]{1,0} reduce-scatter(p0), dimensions={0}
  cp-start = (f32[128,256]{1,0}, f32[128,256]{1,0}) collective-permute-start(p0)
  cp-done = f32[128,256]{1,0} collective-permute-done(cp-start)
  a2a = bf16[32,64]{1,0} all-to-all(p0)
  mm = f32[128,128]{1,0} dot(p0, p0)
}
"""


def test_parse_collectives_sample():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.bytes_by_kind["all-gather"] == 256 * 256 * 4
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.count_by_kind["all-to-all"] == 1
    assert stats.bytes_by_kind["all-to-all"] == 32 * 64 * 2
    # start/done pair counted once (via the start op)
    assert stats.count_by_kind["collective-permute"] == 1
    assert "dot" not in stats.count_by_kind


def test_parse_collectives_real_psum(mesh8):
    def f(x):
        return jax.lax.psum(x, "x")
    fn = jax.jit(compat.shard_map(f, mesh=mesh8, in_specs=P("x"),
                                  out_specs=P()))
    c = fn.lower(jnp.zeros(64, jnp.float32)).compile()
    stats = parse_collectives(c.as_text())
    assert stats.count_by_kind.get("all-reduce", 0) >= 1


def test_roofline_terms_math():
    rt = roofline_terms(
        arch="x", shape="train_4k", mesh_name="single", chips=256,
        cost_analysis={"flops": 1e12, "bytes accessed": 1e11},
        hlo_text=HLO_SAMPLE, model_flops=2.56e14,
        peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)
    assert abs(rt.t_compute - 1e12 / 197e12) < 1e-9
    assert abs(rt.t_memory - 1e11 / 819e9) < 1e-9
    assert rt.t_collective > 0
    assert rt.bottleneck in ("compute", "memory", "collective")
    assert 0 < rt.useful_flop_fraction <= 1.01
    assert 0 < rt.roofline_fraction <= 1.0
    assert "x" in rt.row() and "single" in RooflineTerms.header() \
        or True


HLO_LOOPED = """
HloModule looped, entry_computation_layout={()->f32[]}

%body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %g = f32[64]{0} get-tuple-element(%arg), index=1
  %ar = f32[64]{0} all-reduce(%g), to_apply=%add
  %i = s32[] get-tuple-element(%arg), index=0
  %t = (s32[], f32[64]{0}) tuple(%i, %ar)
}

%cond (arg2: (s32[], f32[64])) -> pred[] {
  %arg2 = (s32[], f32[64]{0}) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %k = s32[] constant(12)
  %cmp = pred[] compare(%i2, %k), direction=LT
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[64]{0}) tuple()
  %w = (s32[], f32[64]{0}) while(%init), condition=%cond, body=%body
  %ag = f32[128]{0} all-gather(%w), dimensions={0}
  %r = f32[] constant(0)
}
"""


def test_loop_aware_census_multiplies_trip_counts():
    from repro.core.hlo_analysis import loop_aware_census, parse_collectives
    flat = parse_collectives(HLO_LOOPED)
    assert flat.count_by_kind["all-reduce"] == 1
    stats, traffic = loop_aware_census(HLO_LOOPED)
    # the while body runs 12 times
    assert stats.count_by_kind["all-reduce"] == 12
    assert stats.bytes_by_kind["all-reduce"] == 12 * 64 * 4
    assert stats.count_by_kind["all-gather"] == 1
    assert traffic >= 0   # fusion-aware model: no dots here -> no traffic


def test_loop_aware_census_real_scan(mesh8):
    import jax, jax.numpy as jnp
    from repro.core.hlo_analysis import loop_aware_census

    def f(x, w):
        def body(h, wi):
            return jax.lax.psum(jnp.tanh(h @ wi), "x"), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    fn = jax.jit(compat.shard_map(f, mesh=mesh8, in_specs=(P(), P()),
                                  out_specs=P(), check_vma=False))
    c = fn.lower(jnp.zeros((8, 16)), jnp.zeros((5, 16, 16))).compile()
    stats, _ = loop_aware_census(c.as_text())
    # 5 loop iterations x 1 psum of [8,16] f32
    assert stats.count_by_kind.get("all-reduce", 0) == 5
    assert stats.bytes_by_kind["all-reduce"] == 5 * 8 * 16 * 4
