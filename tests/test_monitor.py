"""StragglerMonitor edge cases: warmup, degenerate streams, escalation.

The z-score detector must be well-defined on the streams a real train
loop produces at its boundaries: the very first step (no model yet),
constant-duration streams (variance exactly zero), and zero-duration
streams (mean exactly zero — e.g. mocked clocks in tests), none of
which may flag, divide by zero, or emit NaN.
"""

import math

import pytest

from repro.runtime.monitor import StragglerMonitor

pytestmark = pytest.mark.fast


def test_first_step_never_flags():
    m = StragglerMonitor()
    v = m.record(0, 123.456)
    assert v.z == 0.0 and not v.straggle and v.action == "ok"


def test_constant_duration_stream_stays_ok():
    """Zero variance: identical durations are on-model by definition."""
    m = StragglerMonitor(warmup=3)
    for i in range(50):
        v = m.record(i, 0.5)
        assert not v.straggle and v.action == "ok"
        assert v.z == 0.0 and math.isfinite(v.z)


def test_zero_duration_stream_no_blowup_then_spike_detects():
    """mean == 0 and var == 0: the relative std floor is also 0, so the
    old epsilon division scored ~1e9 for any float jitter.  On-model
    steps must score exactly 0; a genuine excursion is still caught."""
    m = StragglerMonitor(warmup=3)
    for i in range(10):
        v = m.record(i, 0.0)
        assert v.z == 0.0 and not v.straggle and v.action == "ok"
    spike = m.record(10, 1.0)
    assert spike.straggle and spike.z == math.inf


def test_warmup_suppresses_early_outliers():
    m = StragglerMonitor(warmup=5)
    m.record(0, 1.0)
    # steps 2..warmup: huge excursions, still within warmup
    for i in range(1, 5):
        v = m.record(i, 100.0 if i == 3 else 1.0)
        assert not v.straggle
    # past warmup the same excursion flags
    for i in range(5, 10):
        m.record(i, 1.0)
    v = m.record(10, 100.0)
    assert v.straggle


def test_genuine_spike_flags_then_skip_then_rescale():
    m = StragglerMonitor(warmup=3, z_flag=3.0, z_skip=6.0, max_skips=2)
    for i in range(20):
        m.record(i, 1.0 + 0.01 * ((-1) ** i))
    # moderate outlier: flag only (between z_flag and z_skip std floor)
    v = m.record(20, 1.4)
    assert v.straggle and v.action == "flag"
    # hard outliers escalate: skip_sync x max_skips, then rescale
    actions = [m.record(21 + k, 10.0).action for k in range(4)]
    assert actions == ["skip_sync", "skip_sync", "rescale", "rescale"]
    # recovery resets the escalation ladder
    ok = m.record(30, 1.0)
    assert ok.action == "ok" and m.consecutive_skips == 0


def test_ewma_not_poisoned_by_outliers():
    m = StragglerMonitor(warmup=3)
    for i in range(10):
        m.record(i, 1.0)
    mean_before = m.mean
    m.record(10, 50.0)           # straggle: must not enter the EWMA
    assert m.mean == mean_before


def test_history_is_bounded_ring():
    """A long-running server records one verdict per decode batch; the
    history must cap out (newest evidence kept) instead of growing
    into an OOM."""
    m = StragglerMonitor(warmup=0, history_cap=16)
    for i in range(100):
        m.record(i, 1.0)
    assert len(m.history) == 16
    assert [v.step for v in m.history] == list(range(84, 100))
    assert m.n == 100                  # detector state is unaffected
    # the default cap applies when none is given
    assert StragglerMonitor().history.maxlen == StragglerMonitor.HISTORY_CAP


def test_supervisor_anomalies_bounded_ring():
    from repro.runtime.monitor import StepVerdict
    from repro.runtime.train_loop import StepSupervisor
    sup = StepSupervisor(anomaly_cap=8)
    for i in range(50):
        sup.on_verdict(StepVerdict(step=i, duration=9.0, z=7.0,
                                   straggle=True, action="skip_sync"))
    assert len(sup.anomalies) == 8
    assert [a.step for a in sup.anomalies] == list(range(42, 50))
    assert StepSupervisor().anomalies.maxlen == StepSupervisor.ANOMALY_CAP
