"""Planner unit/property tests — the plan/execute split pays off here.

:func:`repro.core.plan_sync` is pure Python over static metadata, so the
superstep compiler's invariants (round validity, CRCW arbitration, cost
prediction, cache behaviour) are checked in milliseconds without touching
a mesh or XLA.  Property tests run under hypothesis when the ``[test]``
extra is installed and fall back to a fixed seed sweep otherwise; the one
XLA test at the bottom (cache + ledger compliance on a real mesh) is
marked ``slow``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (LPF_SYNC_DEFAULT, LPFFatalError, Msg, PlanCache,
                        Slot, SyncAttributes, plan_cost, plan_signature,
                        plan_sync)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.fast


def table_property(fn):
    """Run ``fn(seed)`` over many seeds: hypothesis-driven (with
    shrinking) when available, a fixed sweep otherwise.  The example
    budget comes from the active hypothesis profile (``dev`` locally,
    ``ci-slow`` in the nightly workflow — see ``conftest.py``)."""
    if HAVE_HYPOTHESIS:
        return settings(deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(40))(fn)


def make_slot(sid, size, dtype="float32", kind="global", name=None):
    return Slot(sid=sid, name=name or f"s{sid}", size=size,
                dtype=np.dtype(dtype), kind=kind, orig_shape=(size,))


def random_table(seed):
    """A random legal h-relation: (p, msgs)."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 9))
    dtype = rng.choice(["float32", "int32", "float64"])
    slots = [make_slot(100 + i, int(rng.integers(8, 33)), dtype)
             for i in range(int(rng.integers(1, 4)))]
    msgs = []
    for _ in range(int(rng.integers(1, 16))):
        a = slots[int(rng.integers(len(slots)))]
        b = slots[int(rng.integers(len(slots)))]
        size = int(rng.integers(1, min(a.size, b.size) + 1))
        msgs.append(Msg(
            src=int(rng.integers(p)), dst=int(rng.integers(p)),
            src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
            dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
            size=size))
    return p, msgs


def rounds_of(plan):
    assert plan.method == "direct"
    return plan.rounds


# ---------------------------------------------------------------------------
# direct-method round structure
# ---------------------------------------------------------------------------

@table_property
def test_rounds_form_partial_permutations(seed):
    """No round sends twice from one PID or receives twice at one PID."""
    p, msgs = random_table(seed)
    plan = plan_sync(msgs, p, SyncAttributes(method="direct"))
    for rd in rounds_of(plan):
        srcs = [msgs[i].src for i in rd.msg_idx]
        dsts = [msgs[i].dst for i in rd.msg_idx]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        # one source and one destination slot per round
        assert len({msgs[i].src_slot.sid for i in rd.msg_idx}) == 1
        assert len({msgs[i].dst_slot.sid for i in rd.msg_idx}) == 1
        # padding covers every member message
        assert rd.size == max(msgs[i].size for i in rd.msg_idx)


@table_property
def test_every_message_scheduled_exactly_once(seed):
    p, msgs = random_table(seed)
    plan = plan_sync(msgs, p, SyncAttributes(method="direct"))
    placed = [i for rd in rounds_of(plan) for i in rd.msg_idx]
    assert sorted(placed) == list(range(len(msgs)))


def _conflicting(a, b):
    return (a.dst == b.dst and a.dst_slot.sid == b.dst_slot.sid
            and a.dst_off < b.dst_off + b.size
            and b.dst_off < a.dst_off + a.size)


@table_property
def test_crcw_conflicts_ordered_by_source_pid(seed):
    """Overlapping writes land in strictly increasing rounds following the
    ascending (src, dst, dst_off) arbitration order, so the highest
    source PID writes last — the CRCW refinement the paper's S2.1 allows."""
    p, msgs = random_table(seed)
    plan = plan_sync(msgs, p, SyncAttributes(method="direct"))
    round_no = {}
    for r, rd in enumerate(rounds_of(plan)):
        for i in rd.msg_idx:
            round_no[i] = r
    for i, a in enumerate(msgs):
        for j, b in enumerate(msgs):
            if i == j or not _conflicting(a, b):
                continue
            if a.src_slot.sid != b.src_slot.sid:
                continue  # cross-group ordering is by group position
            if (a.src, a.dst, a.dst_off) < (b.src, b.dst, b.dst_off):
                assert round_no[i] < round_no[j], (a, b)


# ---------------------------------------------------------------------------
# cost prediction
# ---------------------------------------------------------------------------

@table_property
def test_planned_cost_matches_plan_cost(seed):
    """The plan's embedded cost must be exactly what ``plan_cost`` derives
    for the same method/round/wire decision — and the h-relation must be
    reproducible from the raw table by an independent oracle."""
    p, msgs = random_table(seed)
    plan = plan_sync(msgs, p, LPF_SYNC_DEFAULT)

    sent = np.zeros(p, np.int64)
    recv = np.zeros(p, np.int64)
    for m in msgs:
        if m.src != m.dst:
            nbytes = m.size * np.dtype(m.src_slot.dtype).itemsize
            sent[m.src] += nbytes
            recv[m.dst] += nbytes
    assert plan.cost.h_bytes == max(int(sent.max()), int(recv.max()))
    assert plan.cost.n_msgs == len(msgs)
    assert plan.cost.label == ""
    assert plan.cost.rounds >= 1
    # wire >= h for any non-fused method (padding and Bruck only inflate)
    assert plan.cost.wire_bytes >= plan.cost.h_bytes \
        or plan.cost.method in ("fused", "fused_ag")
    # rebuild through the public plan_cost with the plan's own decisions
    re = plan_cost(msgs, p, LPF_SYNC_DEFAULT, "x", plan.cost.method,
                   plan.cost.rounds, {}, {})
    assert re.h_bytes == plan.cost.h_bytes
    assert re.n_msgs == plan.cost.n_msgs


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

@table_property
def test_cache_hits_on_equivalent_table_with_fresh_slots(seed):
    """Re-registering the same pattern through new slots (what the BSP
    collectives do on every call) must reuse the cached plan."""
    p, msgs = random_table(seed)
    remap = {}

    def clone_slot(s):
        if s.sid not in remap:
            remap[s.sid] = make_slot(500 + len(remap), s.size, s.dtype)
        return remap[s.sid]

    msgs2 = [dataclasses.replace(m, src_slot=clone_slot(m.src_slot),
                                 dst_slot=clone_slot(m.dst_slot))
             for m in msgs]
    cache = PlanCache()
    plan1 = cache.get_or_plan(msgs, p, LPF_SYNC_DEFAULT)
    plan2 = cache.get_or_plan(msgs2, p, LPF_SYNC_DEFAULT)
    assert cache.stats.misses == 1 and cache.stats.hits == 1
    assert plan1 is plan2
    assert plan_signature(msgs, p, LPF_SYNC_DEFAULT) == \
        plan_signature(msgs2, p, LPF_SYNC_DEFAULT)


def test_cache_misses_on_permuted_table():
    """CRCW arbitration is order-sensitive, so a permuted table is a
    different superstep and must re-plan."""
    a = make_slot(1, 16)
    b = make_slot(2, 16)
    m1 = Msg(0, 1, a, 0, b, 0, 4)
    m2 = Msg(1, 2, a, 4, b, 4, 4)
    cache = PlanCache()
    cache.get_or_plan([m1, m2], 4, LPF_SYNC_DEFAULT)
    cache.get_or_plan([m2, m1], 4, LPF_SYNC_DEFAULT)
    assert cache.stats.misses == 2 and cache.stats.hits == 0


def test_cache_misses_on_different_attrs_and_p():
    a = make_slot(1, 16)
    b = make_slot(2, 16)
    msgs = [Msg(0, 1, a, 0, b, 0, 4)]
    cache = PlanCache()
    cache.get_or_plan(msgs, 4, LPF_SYNC_DEFAULT)
    cache.get_or_plan(msgs, 8, LPF_SYNC_DEFAULT)
    cache.get_or_plan(msgs, 4, SyncAttributes(no_conflict=True))
    cache.get_or_plan(msgs, 4, SyncAttributes(method="direct"))
    assert cache.stats.misses == 4 and cache.stats.hits == 0


def test_cache_lru_eviction():
    a = make_slot(1, 16)
    b = make_slot(2, 16)
    cache = PlanCache(maxsize=2)
    for dst in (1, 2, 3):
        cache.get_or_plan([Msg(0, dst, a, 0, b, 0, 4)], 4, LPF_SYNC_DEFAULT)
    assert len(cache) == 2
    # oldest (dst=1) was evicted -> re-planning it is a miss
    cache.get_or_plan([Msg(0, 1, a, 0, b, 0, 4)], 4, LPF_SYNC_DEFAULT)
    assert cache.stats.misses == 4


# ---------------------------------------------------------------------------
# CRCW arbitration, fast paths, methods — handcrafted cases
# ---------------------------------------------------------------------------

def test_crcw_highest_pid_wins_at_plan_level():
    a = make_slot(1, 8)
    b = make_slot(2, 8)
    low = Msg(0, 1, a, 0, b, 0, 4)
    high = Msg(2, 1, a, 0, b, 2, 4)   # overlaps [2, 4) of low's write
    plan = plan_sync([low, high], 4, SyncAttributes(method="direct"))
    rnd = {i: r for r, rd in enumerate(plan.rounds) for i in rd.msg_idx}
    assert rnd[1] > rnd[0]            # higher source PID applied later
    # the no-conflict assertion skips arbitration but still yields a
    # legal schedule (same-destination messages serialise regardless)
    relaxed = plan_sync([low, high], 4,
                        SyncAttributes(method="direct", no_conflict=True))
    assert sorted(i for rd in relaxed.rounds for i in rd.msg_idx) == [0, 1]


def test_total_exchange_classified_fused():
    p, w = 4, 3
    a = make_slot(1, p * w)
    b = make_slot(2, p * w)
    msgs = [Msg(s, d, a, d * w, b, s * w, w)
            for s in range(p) for d in range(p)]
    plan = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
    assert plan.method == "fused" and plan.fused_w == w
    assert plan.cost.rounds == 1
    assert plan.cost.wire_bytes == (p - 1) * w * 4


def test_allgather_classified_fused_ag():
    p, w = 4, 5
    a = make_slot(1, w)
    b = make_slot(2, p * w)
    msgs = [Msg(s, d, a, 0, b, s * w, w)
            for s in range(p) for d in range(p)]
    plan = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
    assert plan.method == "fused_ag" and plan.fused_w == w
    assert plan.ag_src_off == (0,) * p and not plan.ag_exclude_self
    assert plan.cost.rounds == 1


def test_reduce_scatter_classified_fused_rs():
    p, w = 4, 3
    a = make_slot(1, p * w, "int32")
    b = make_slot(2, w, "int32")
    msgs = [Msg(s, d, a, d * w, b, 0, w)
            for s in range(p) for d in range(p)]
    plan = plan_sync(msgs, p, SyncAttributes(reduce_op="sum"))
    assert plan.method == "fused_rs" and plan.fused_w == w
    assert plan.reduce_op == "sum"
    assert plan.rs_dst_off == (0,) * p
    assert plan.cost.rounds == 1
    # one reduce-scatter: (p-1) chunks of w int32 on the wire per process
    assert plan.cost.wire_bytes == (p - 1) * w * 4
    assert plan.cost.wire_bytes == plan.cost.h_bytes
    # without reduce_op the same table is a conflicting-write CRCW
    # superstep and must NOT take the fused path
    crcw = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
    assert crcw.method == "direct"
    # max/min reductions fuse too (all_to_all + local combine lowering)
    assert plan_sync(msgs, p, SyncAttributes(reduce_op="max")).method == \
        "fused_rs"


def test_scatter_classified_fused_scatter():
    p, w = 4, 3
    a = make_slot(1, p * w)
    b = make_slot(2, p * w)
    root = 1
    # canonical scatter incl. self-message, per-destination offsets d*w
    msgs = [Msg(root, d, a, d * w, b, d * w, w) for d in range(p)]
    plan = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
    assert plan.method == "fused_scatter" and plan.fused_root == root
    assert plan.sc_dst_off == tuple(d * w for d in range(p))
    assert plan.sc_mask == (1,) * p
    assert plan.cost.rounds == 1
    # equal h to the direct schedule (root sends (p-1)w), one l instead
    # of p-1 — the fused schedule strictly dominates
    assert plan.cost.wire_bytes == plan.cost.h_bytes == (p - 1) * w * 4
    direct = plan_sync(msgs, p, SyncAttributes(method="direct"))
    assert direct.cost.rounds == p - 1


def test_gather_classified_fused_gather():
    p, w = 4, 2
    a = make_slot(1, w)
    b = make_slot(2, p * w)
    root = 2
    msgs = [Msg(s, root, a, 0, b, s * w, w) for s in range(p)]
    plan = plan_sync(msgs, p, LPF_SYNC_DEFAULT)
    assert plan.method == "fused_gather" and plan.fused_root == root
    assert plan.g_has_self and plan.g_src_off == (0,) * p
    assert plan.cost.rounds == 1
    # p-1 variant: everyone but root
    sub = [m for m in msgs if m.src != root]
    plan2 = plan_sync(sub, p, LPF_SYNC_DEFAULT)
    assert plan2.method == "fused_gather" and not plan2.g_has_self


def test_reduce_op_relaxes_round_packing():
    """Combining writes commute, so conflicting messages need no strict
    round ordering — the schedule packs like a no_conflict assertion."""
    a = make_slot(1, 8)
    b = make_slot(2, 8)
    # three messages from distinct sources conflicting at dst 1
    msgs = [Msg(s, 1, a, 0, b, 0, 4) for s in (0, 2, 3)]
    crcw = plan_sync(msgs, 4, SyncAttributes(method="direct"))
    acc = plan_sync(msgs, 4, SyncAttributes(method="direct",
                                            reduce_op="sum"))
    # both serialise on the shared receiver, but the accumulate plan is
    # free to do so without arbitration-order constraints
    assert acc.cost.rounds <= crcw.cost.rounds
    assert acc.reduce_op == "sum"


def test_reduce_op_validation():
    a = make_slot(1, 8)
    b = make_slot(2, 8)
    msgs = [Msg(0, 1, a, 0, b, 0, 4)]
    with pytest.raises(LPFFatalError):
        plan_sync(msgs, 4, SyncAttributes(reduce_op="prod"))
    with pytest.raises(LPFFatalError):
        plan_sync(msgs, 4, SyncAttributes(method="bruck", reduce_op="sum"))
    with pytest.raises(LPFFatalError):
        plan_sync(msgs, 4, SyncAttributes(method="valiant",
                                          reduce_op="sum"))


def test_cache_misses_on_reduce_op():
    """reduce_op changes superstep semantics, so it must key the cache."""
    p, w = 4, 2
    a = make_slot(1, p * w)
    b = make_slot(2, w)
    msgs = [Msg(s, d, a, d * w, b, 0, w)
            for s in range(p) for d in range(p)]
    cache = PlanCache()
    cache.get_or_plan(msgs, p, LPF_SYNC_DEFAULT)
    cache.get_or_plan(msgs, p, SyncAttributes(reduce_op="sum"))
    cache.get_or_plan(msgs, p, SyncAttributes(reduce_op="max"))
    assert cache.stats.misses == 3 and cache.stats.hits == 0
    assert plan_signature(msgs, p, LPF_SYNC_DEFAULT) != \
        plan_signature(msgs, p, SyncAttributes(reduce_op="sum"))


def test_bruck_round_count_and_validation():
    p = 8
    a = make_slot(1, p)
    b = make_slot(2, p)
    msgs = [Msg(s, (s + k) % p, a, 0, b, s % (p - 1), 1)
            for s in range(p) for k in (1, 2)]
    plan = plan_sync(msgs, p, SyncAttributes(method="bruck"))
    assert plan.method == "bruck"
    assert 1 <= plan.cost.rounds <= int(np.ceil(np.log2(p)))
    for step, rows in plan.bruck_steps:
        assert all(1 <= r < p and (r & step) for r in rows)
    with pytest.raises(LPFFatalError):
        plan_sync(msgs + [msgs[0]], p, SyncAttributes(method="bruck"))


def test_p1_and_empty_plans():
    a = make_slot(1, 8)
    b = make_slot(2, 8)
    plan = plan_sync([Msg(0, 0, a, 0, b, 0, 8)], 1, LPF_SYNC_DEFAULT)
    assert plan.method == "seq" and plan.cost.method == "noop"
    assert plan.cost.rounds == 0 and plan.cost.wire_bytes == 0
    empty = plan_sync([], 8, LPF_SYNC_DEFAULT)
    assert empty.method == "noop" and empty.cost.n_msgs == 0


def test_plan_validates_the_table():
    a = make_slot(1, 8)
    b = make_slot(2, 8)
    with pytest.raises(LPFFatalError):       # destination range OOB
        plan_sync([Msg(0, 1, a, 0, b, 6, 4)], 4, LPF_SYNC_DEFAULT)
    with pytest.raises(LPFFatalError):       # pid out of range
        plan_sync([Msg(0, 9, a, 0, b, 0, 4)], 4, LPF_SYNC_DEFAULT)
    local = make_slot(3, 8, kind="local")
    with pytest.raises(LPFFatalError):       # remote side must be global
        plan_sync([Msg(0, 1, a, 0, local, 0, 4, origin="put")], 4,
                  LPF_SYNC_DEFAULT)


# ---------------------------------------------------------------------------
# end-to-end: one planning pass for repeated supersteps, ledger == plan
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cache_one_planning_pass_and_ledger_compliance(mesh8):
    """Two ``sync()`` calls with the identical message table plan once,
    and the executed ledger entries equal the plan's predicted cost."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.core import global_plan_cache

    cache = global_plan_cache()
    cache.clear()

    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2 * p)
        a = ctx.register_global("a", jnp.arange(4.0) + 10.0 * ctx.pid)
        b = ctx.register_global("b", jnp.zeros(4))
        for _ in range(2):                       # identical superstep x2
            ctx.put(a, b, to=lambda s: (s + 1) % p, size=4)
            ctx.sync(label="shift")
        return ctx.value(b)

    out, ledger = lpf.exec_(mesh8, spmd, None, out_specs=P("x"),
                            return_ledger=True)
    shifted = np.asarray(out).reshape(8, 4)
    for d in range(8):
        np.testing.assert_allclose(shifted[d],
                                   np.arange(4.0) + 10.0 * ((d - 1) % 8))
    assert cache.stats.misses == 1 and cache.stats.hits == 1
    first, second = ledger.records
    assert dataclasses.replace(first, label="") == \
        dataclasses.replace(second, label="")

    # the executed ledger entry equals a from-scratch plan of the table
    slot_a = make_slot(0, 4)
    slot_b = make_slot(1, 4)
    msgs = [Msg(s, (s + 1) % 8, slot_a, 0, slot_b, 0, 4, origin="put")
            for s in range(8)]
    fresh = plan_sync(msgs, 8, LPF_SYNC_DEFAULT)
    assert dataclasses.replace(fresh.cost, label="shift") == first
