"""The pure-LPF serve engine on the host mesh (slow tier).

What the fast-tier fake cannot prove: the real recorded decode
programs are bit-identical across solo / batched / per-token-fallback
execution, the admission price equals the executed ledger (model
compliance end to end), and the chaos harness's per-request serve
invariant holds under its worst fixed plans.
"""

import pytest

from repro.runtime.faults import FaultPlan, _run_one
from repro.runtime.server import (LPFServer, ProgramDecodeEngine,
                                  ServeRequest, synthetic_requests)

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def program_engine():
    return ProgramDecodeEngine(buckets=((2, 8), (4, 8)))


def req(rid, n=4, seed=0):
    return ServeRequest(rid=rid, n_tokens=n, deadline_s=10.0, seed=seed)


def test_engine_bit_identical_solo_batched_fallback(program_engine):
    eng = program_engine
    a, b = req(0, seed=1234), req(1, seed=777)
    solo = eng.decode((4, 8), [a], 4)[0]
    batched = eng.decode((4, 8), [a, b], 4)[0]
    assert solo == batched
    eng.quarantine((4, 8))
    try:
        assert eng.decode((4, 8), [a], 4)[0] == solo
    finally:
        eng._quarantined.discard((4, 8))


def test_engine_prices_match_ledger_and_serve(program_engine):
    """Model compliance end to end: the admission price equals the
    executed ledger, so the served vclock is exactly the sum of batch
    prices, no admitted request misses its deadline, and every
    admitted request terminates classified or completed."""
    eng = program_engine
    assert eng.token_seconds((2, 8)) > 0
    srv = LPFServer(eng, max_queue=8)
    reqs = synthetic_requests(10, 3, eng.buckets(),
                              token_cost_s=eng.token_seconds((4, 8)))
    for r in reqs:
        srv.submit(r)
    srv.run_until_idle()
    h = srv.drain()
    assert h["deadline_misses"] == 0
    assert h["completed"] > 0
    assert h["completed"] + h["shed"] == h["admitted"]
    assert h["program_pinned"] >= 2          # hot buckets stay pinned
    for out in srv.take_outcomes().values():
        if out.status == "completed":
            assert out.completion_v <= out.predicted_v + 1e-12
        else:
            assert out.classified


def test_serve_chaos_invariant_smoke():
    """One pass of the serve chaos workload under its worst fixed
    plans via the harness's own comparator — the CI-shaped reduction
    of the nightly 100-seed soak."""
    baselines = {}
    for spec in ("serve_admit@0x-1", "serve_decode@0x-1"):
        verdict, detail = _run_one("serve", FaultPlan.parse(spec),
                                   baselines)
        assert verdict in ("identical", "classified"), (spec, detail)
