"""BSP collectives + cross-pod gradient sync."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import bsp, core as lpf
from repro.core import CompressSpec, SyncAttributes, compat

pytestmark = pytest.mark.slow


def test_collectives_suite(mesh8):
    def spmd(ctx, s, p, _):
        ar = bsp.allreduce(ctx, jnp.arange(10.0) + 100.0 * ctx.pid)
        bc = bsp.broadcast(ctx, jnp.arange(7.0) + 100.0 * ctx.pid, root=3)
        ag = bsp.allgather(ctx, jnp.full(2, 1.0) * ctx.pid)
        sc = bsp.exscan(ctx, jnp.full(3, 1.0) * (ctx.pid + 1))
        a2a = bsp.alltoall(ctx, jnp.arange(8.0) + 10.0 * ctx.pid)
        return ar, bc, ag, sc, a2a

    ar, bc, ag, sc, a2a = lpf.exec_(mesh8, spmd,
                                    out_specs=tuple([P("x")] * 5))
    ar = np.asarray(ar).reshape(8, 10)
    np.testing.assert_allclose(ar[4], np.arange(10.0) * 8 + 100.0 * 28)
    bc = np.asarray(bc).reshape(8, 7)
    np.testing.assert_allclose(bc, np.tile(np.arange(7.0) + 300.0, (8, 1)))
    ag = np.asarray(ag).reshape(8, 16)
    np.testing.assert_allclose(ag[5], np.repeat(np.arange(8.0), 2))
    sc = np.asarray(sc).reshape(8, 3)
    np.testing.assert_allclose(sc[:, 0],
                               [sum(range(1, i + 1)) for i in range(8)])
    a2a = np.asarray(a2a).reshape(8, 8)
    np.testing.assert_allclose(a2a[2],
                               [2.0 + 10.0 * s for s in range(8)])


def test_allreduce_nondivisible_length(mesh8):
    def spmd(ctx, s, p, _):
        return bsp.allreduce(ctx, jnp.ones(13))

    out = np.asarray(lpf.exec_(mesh8, spmd, out_specs=P("x"))).reshape(8, 13)
    np.testing.assert_allclose(out, 8.0)


def test_allreduce_max_min_ops(mesh8):
    """max/min allreduces take the fused_rs path (all_to_all + combine)
    and must not leak the zero-initialised staging buffers into results
    that are all-negative / all-positive."""
    def spmd(ctx, s, p, _):
        neg = bsp.allreduce(ctx, -(jnp.arange(11.0) + 1.0 + ctx.pid),
                            op=jnp.maximum, label="mx")
        pos = bsp.allreduce(ctx, jnp.arange(11.0) + 1.0 + ctx.pid,
                            op=jnp.minimum, label="mn")
        return neg, pos

    neg, pos = lpf.exec_(mesh8, spmd, out_specs=(P("x"), P("x")))
    neg = np.asarray(neg).reshape(8, 11)
    pos = np.asarray(pos).reshape(8, 11)
    np.testing.assert_allclose(neg, np.tile(-(np.arange(11.0) + 1.0),
                                            (8, 1)))
    np.testing.assert_allclose(pos, np.tile(np.arange(11.0) + 1.0,
                                            (8, 1)))


def test_allreduce_explicit_bruck_method_still_works(mesh8):
    """An explicit bruck/valiant method request cannot combine
    conflicting writes, so allreduce must route it through the exchange
    algorithm instead of staging an accumulating-put superstep."""
    def spmd(ctx, s, p, _):
        return bsp.allreduce(ctx, jnp.ones(16),
                             attrs=SyncAttributes(method="bruck"))

    out = np.asarray(lpf.exec_(mesh8, spmd, out_specs=P("x"))).reshape(8, 16)
    np.testing.assert_allclose(out, 8.0)


def test_reduce_to_root_vs_allreduce_cost(mesh8):
    """reduce must no longer silently run (and bill) an allreduce: both
    cost 2 fused rounds, but reduce's result lands at root only."""
    ledgers = {}

    def spmd(ctx, s, p, _):
        ledgers["ledger"] = ctx.ledger
        x = jnp.arange(24.0) * (1.0 + ctx.pid)
        return bsp.reduce(ctx, x, root=2)

    out, ledger = lpf.exec_(mesh8, spmd, out_specs=P("x"),
                            return_ledger=True)
    out = np.asarray(out).reshape(8, 24)
    want = np.arange(24.0) * sum(1.0 + i for i in range(8))
    np.testing.assert_allclose(out[2], want)
    assert (out[np.arange(8) != 2] == 0).all()
    assert [r.method for r in ledger.records] == ["fused_rs",
                                                  "fused_gather"]


def test_compressed_allreduce_error_bounded(mesh8):
    def spmd(ctx, s, p, _):
        x = jnp.linspace(-1, 1, 64) * (1.0 + 0.01 * ctx.pid)
        return bsp.allreduce(
            ctx, x, attrs=SyncAttributes(compress=CompressSpec(bits=8)))

    out = np.asarray(lpf.exec_(mesh8, spmd, out_specs=P("x"))).reshape(8, 64)
    exact = np.linspace(-1, 1, 64) * (8 + 0.01 * 28)
    rel = np.abs(out[0] - exact).max() / np.abs(exact).max()
    assert rel < 0.05


def test_cross_pod_grad_sync(mesh_pdm):
    grads = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.arange(4.0)}
    specs = {"w": P("data", "model"), "b": P(None)}
    sync = bsp.build_cross_pod_sync(mesh_pdm, specs)
    gw = jax.device_put(grads["w"], NamedSharding(mesh_pdm, specs["w"]))
    gb = jax.device_put(grads["b"], NamedSharding(mesh_pdm, specs["b"]))
    with compat.set_mesh(mesh_pdm):
        out = jax.jit(sync)({"w": gw, "b": gb})
    # pods hold identical replicas here -> mean equals input
    np.testing.assert_allclose(np.asarray(out["w"]), grads["w"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), grads["b"], rtol=1e-6)


@pytest.mark.parametrize("method,want_method,want_rounds",
                         [("auto", "rs+ag", 2), ("ring", "ring", 1)])
def test_pod_allreduce_methods(mesh_pdm, method, want_method, want_rounds):
    """pod_allreduce inside a manual-over-pod region averages across
    pods; ``auto`` takes the fused reduce-scatter + all-gather pair."""
    from repro.bsp.pod_sync import pod_allreduce
    from repro.core import CostLedger

    ledger = CostLedger()

    def body(x):
        pid = jax.lax.axis_index("pod").astype(jnp.float32)
        local = {"g": x + pid * 10.0}
        out = pod_allreduce(local, 2, "pod", ledger=ledger, method=method)
        return out["g"]

    fn = compat.shard_map(body, mesh=mesh_pdm, in_specs=P(),
                          out_specs=P(), axis_names={"pod"}, check_vma=False)
    with compat.set_mesh(mesh_pdm):
        out = jax.jit(fn)(jnp.ones(4))
    np.testing.assert_allclose(np.asarray(out), 6.0)   # mean(1, 11)
    assert ledger.records and ledger.records[0].method == want_method
    assert ledger.records[0].rounds == want_rounds


def test_pod_allreduce_compressed(mesh_pdm):
    from repro.bsp.pod_sync import pod_allreduce
    from repro.core import SyncAttributes, CompressSpec

    def body(x):
        pid = jax.lax.axis_index("pod").astype(jnp.float32)
        out = pod_allreduce({"g": x * (1.0 + pid)}, 2, "pod",
                            attrs=SyncAttributes(
                                compress=CompressSpec(bits=8)))
        return out["g"]

    fn = compat.shard_map(body, mesh=mesh_pdm, in_specs=P(),
                          out_specs=P(), axis_names={"pod"}, check_vma=False)
    with compat.set_mesh(mesh_pdm):
        out = np.asarray(jax.jit(fn)(jnp.linspace(-1, 1, 32)))
    want = np.linspace(-1, 1, 32) * 1.5
    assert np.abs(out - want).max() < 0.05


def test_fft_compliance_hlo_vs_ledger(mesh8):
    """Model compliance, measured: the compiled HLO's collective bytes
    must not exceed the ledger's promise (fused paths may shrink it)."""
    from repro.algorithms.fft import bsp_fft_spmd
    from repro.core.hlo_analysis import parse_collectives

    n = 256

    def spmd(ctx, s, p, xt):
        xl = xt.reshape(p, n // p)[s]
        return bsp_fft_spmd(ctx, xl, n)

    ledger_box = {}

    def wrapped(xt):
        ctx = lpf.LPFContext(("x",))
        ledger_box["l"] = ctx.ledger
        return spmd(ctx, ctx.pid, ctx.p, xt)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8, in_specs=(P(),),
                                  out_specs=P("x"), check_vma=False))
    x = jnp.zeros(n, jnp.complex64)
    compiled = fn.lower(x).compile()
    stats = parse_collectives(compiled.as_text())
    ledger = ledger_box["l"]
    assert stats.total_count >= 1
    # ledger promise is per-process wire bytes; HLO result shapes are the
    # per-device received bytes of each collective — compare totals
    assert stats.total_bytes <= ledger.total_wire_bytes * 1.25
    assert stats.total_bytes > 0
