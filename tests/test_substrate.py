"""Substrate: optimizer, compression+error feedback, data determinism,
checkpointing (atomic/async/elastic), straggler monitor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, SyntheticStream
from repro.optim import (AdamWConfig, adafactor_init, adafactor_update,
                         adamw_init, adamw_update, ef_compress,
                         ef_decompress, ef_init, warmup_cosine)
from repro.runtime.monitor import StragglerMonitor

pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _optimize(update, init, steps=300):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([[1.0, 1.0],
                                                               [1.0, 1.0]])}
    target = {"w": jnp.asarray([0.5, 0.5]), "b": jnp.zeros((2, 2))}
    state = init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: sum(
            jnp.sum((p[k] - target[k]) ** 2) for k in p))(params)
        return update(grads, state, params)

    for _ in range(steps):
        params, state, _ = step(params, state)
    return params, target


def test_adamw_converges():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    params, target = _optimize(
        lambda g, s, p: adamw_update(g, s, p, cfg),
        lambda p: adamw_init(p, cfg))
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target["w"]), atol=1e-2)


def test_adafactor_converges():
    from repro.optim import AdafactorConfig
    cfg = AdafactorConfig(lr=0.05)
    params, target = _optimize(
        lambda g, s, p: adafactor_update(g, s, p, cfg),
        lambda p: adafactor_init(p, cfg))
    np.testing.assert_allclose(np.asarray(params["b"]),
                               np.asarray(target["b"]), atol=5e-2)


def test_adamw_clips_gradients():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    _, _, metrics = adamw_update({"w": jnp.full(3, 1e6)}, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e6   # reported pre-clip


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < 2e-4


# ---------------------------------------------------------------------------
# error-feedback compression
# ---------------------------------------------------------------------------

def test_error_feedback_accumulates_lost_mass():
    g = {"w": jnp.asarray([1e-4, 0.5, -0.25])}
    residual = ef_init(g)
    total_exact = np.zeros(3)
    total_sent = np.zeros(3)
    for _ in range(50):
        q, scales, residual = ef_compress(g, residual)
        sent = ef_decompress(q, scales)
        total_exact += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    # cumulative transmitted mass tracks the exact sum despite int8
    np.testing.assert_allclose(total_sent, total_exact, rtol=0.02,
                               atol=2e-3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab=97, seq_len=32, global_batch=4, seed=7)
    s1 = SyntheticStream(cfg)
    s2 = SyntheticStream(cfg)
    b1 = s1.batch(12)
    b2 = s2.batch(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(13)["tokens"], b1["tokens"])
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert (b1["labels"][:, -1] == -1).all()
    # resumable: state is just the step
    st = s1.state(12)
    assert SyntheticStream.resume(st) == 12


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=97, seq_len=256, global_batch=2, seed=0,
                     structure=0.9)
    b = SyntheticStream(cfg).batch(0)
    toks = b["tokens"]
    a, c = SyntheticStream(cfg).a, SyntheticStream(cfg).c
    follows = np.mean(toks[:, 1:] == (toks[:, :-1] * a + c) % 97)
    assert follows > 0.7


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(rng):
    return {"a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    save(str(tmp_path), 7, tree, meta={"k": 1})
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_atomic_publish(tmp_path, rng):
    tree = _tree(rng)
    save(str(tmp_path), 1, tree)
    # a stale tmp dir from a crashed save must not affect latest_step
    os.makedirs(tmp_path / ".tmp_step_2", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_structure_mismatch(tmp_path, rng):
    save(str(tmp_path), 1, _tree(rng))
    bad = {"a": jnp.zeros((4, 8))}
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, jax.eval_shape(lambda: bad))


def test_async_checkpointer_and_gc(tmp_path, rng):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(rng))
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_sweeps_abandoned_tmp(tmp_path, rng):
    """A ``.tmp_step_*`` staging dir orphaned by a crash is removed by
    the next save — it must not accumulate alongside published steps."""
    stale = tmp_path / ".tmp_step_99"
    os.makedirs(stale)
    (stale / "leaf_0.npy").write_bytes(b"partial")
    save(str(tmp_path), 1, _tree(rng))
    assert not stale.exists()
    assert latest_step(str(tmp_path)) == 1
    # the async path sweeps too (its _gc runs after every save)
    os.makedirs(stale)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(2, _tree(rng))
    ck.wait()
    assert not stale.exists()


def test_async_checkpointer_keep_zero_and_one(tmp_path, rng):
    """keep=0 must retain nothing (the ``steps[:-0]`` empty-slice bug
    deleted nothing); keep=1 retains exactly the newest step."""
    ck0 = AsyncCheckpointer(str(tmp_path / "k0"), keep=0)
    for s in (1, 2, 3):
        ck0.save(s, _tree(rng))
    ck0.wait()
    assert [d for d in os.listdir(tmp_path / "k0")
            if d.startswith("step_")] == []
    ck1 = AsyncCheckpointer(str(tmp_path / "k1"), keep=1)
    for s in (1, 2, 3):
        ck1.save(s, _tree(rng))
    ck1.wait()
    assert [d for d in os.listdir(tmp_path / "k1")
            if d.startswith("step_")] == ["step_3"]
    with pytest.raises(ValueError, match="keep must be >= 0"):
        AsyncCheckpointer(str(tmp_path), keep=-1)
    # fewer checkpoints than keep: gc must delete nothing (a negative
    # slice bound would silently drop the OLDEST checkpoints)
    ck3 = AsyncCheckpointer(str(tmp_path / "k3"), keep=3)
    for s in (1, 2):
        ck3.save(s, _tree(rng))
    ck3.wait()
    assert sorted(d for d in os.listdir(tmp_path / "k3")
                  if d.startswith("step_")) == ["step_1", "step_2"]


def test_checkpoint_elastic_resharding(tmp_path, rng, mesh8):
    """Save from an 8-device mesh, restore onto a different layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh8, P("x", None)))
    save(str(tmp_path), 1, {"x": xs})
    # restore replicated (the "new mesh" here: a single device)
    out = restore(str(tmp_path), 1, jax.eval_shape(lambda: {"x": x}))
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(x))
    # and back onto the mesh with a different spec
    out2 = restore(str(tmp_path), 1, jax.eval_shape(lambda: {"x": x}),
                   shardings={"x": NamedSharding(mesh8, P(None, "x"))})
    np.testing.assert_allclose(np.asarray(out2["x"]), np.asarray(x))


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_detection_and_escalation():
    mon = StragglerMonitor(z_flag=3.0, z_skip=6.0, max_skips=2, warmup=3)
    for i in range(20):
        v = mon.record(i, 1.0 + 0.01 * (i % 3))
        assert v.action == "ok"
    # moderate outlier -> flag
    v = mon.record(20, 1.5)
    assert v.action == "flag" and v.straggle
    # extreme outliers -> skip_sync then rescale after max_skips
    actions = [mon.record(21 + k, 10.0).action for k in range(4)]
    assert actions[0] == "skip_sync"
    assert "rescale" in actions


def test_straggler_monitor_model_not_poisoned():
    mon = StragglerMonitor(warmup=3)
    for i in range(10):
        mon.record(i, 1.0)
    mean_before = mon.mean
    mon.record(10, 50.0)       # huge outlier
    assert abs(mon.mean - mean_before) < 1e-6
