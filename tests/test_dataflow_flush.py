"""Dataflow-precise flush: a local read of a slot executes exactly the
pending supersteps in its dependency cone — the topological slice of the
trace's slot-dataflow graph — leaving independent supersteps recorded
across the compute barrier.

Pure-level tests drive :func:`repro.core.dependency_cone` and the numpy
reference interpreter (executing the cone first, then the remainder,
must be bit-identical to in-order execution); the XLA tests check the
real ``ctx.program()`` path: ledger superstep counts equal cone sizes,
the deferred remainder still flushes at ``end_record``, and post-flush
replay hits the program cache.  Property tests run under hypothesis
when available and fall back to a fixed seed sweep otherwise.
"""

import numpy as np
import pytest

from repro.core import (LPF_SYNC_DEFAULT, Msg, ProgramStep, Slot,
                        SyncAttributes, dependency_cone, simulate_program)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.fast


def table_property(fn):
    if HAVE_HYPOTHESIS:
        return settings(deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(60))(fn)


def make_slot(sid, size, dtype="int32", kind="global"):
    return Slot(sid=sid, name=f"s{sid}", size=size, dtype=np.dtype(dtype),
                kind=kind, orig_shape=(size,))


# ---------------------------------------------------------------------------
# cone computation
# ---------------------------------------------------------------------------

def test_cone_contains_writers_only():
    A, B, C, D = (make_slot(i, 16) for i in range(1, 5))
    steps = [
        ProgramStep((Msg(0, 1, A, 0, B, 0, 4),), LPF_SYNC_DEFAULT, "w_b"),
        ProgramStep((Msg(2, 3, C, 0, D, 0, 4),), LPF_SYNC_DEFAULT, "w_d"),
        ProgramStep((Msg(1, 2, B, 8, A, 8, 4),), LPF_SYNC_DEFAULT, "r_b"),
    ]
    # a read of B depends on its writer only; the independent C->D
    # superstep and the step merely *reading* B stay recorded
    assert dependency_cone(steps, sid=2) == [0]
    # a read of D: only its writer
    assert dependency_cone(steps, sid=4) == [1]
    # a *write* of B must also flush B's readers (WAR)
    assert dependency_cone(steps, sid=2, include_reads=True) == [0, 2]


def test_cone_transitive_raw_chain():
    A, B, C, D = (make_slot(i, 16) for i in range(1, 5))
    steps = [
        ProgramStep((Msg(0, 1, A, 0, B, 0, 4),), LPF_SYNC_DEFAULT, "a2b"),
        ProgramStep((Msg(1, 2, B, 0, C, 0, 4),), LPF_SYNC_DEFAULT, "b2c"),
        ProgramStep((Msg(2, 3, C, 0, D, 0, 4),), LPF_SYNC_DEFAULT, "c2d"),
    ]
    # reading D pulls the whole chain (c2d reads C written by b2c, ...)
    assert dependency_cone(steps, sid=4) == [0, 1, 2]
    # reading C needs only the first two
    assert dependency_cone(steps, sid=3) == [0, 1]


def test_cone_waw_and_war_ordering():
    A, B = make_slot(1, 16), make_slot(2, 16)
    # two writes overlapping in B: flushing the later writer must drag
    # the earlier one along (arbitration order), even across a gap
    steps = [
        ProgramStep((Msg(0, 1, A, 0, B, 0, 8),), LPF_SYNC_DEFAULT, "w1"),
        ProgramStep((Msg(3, 2, A, 8, A, 0, 4),),
                    SyncAttributes(reduce_op="sum"), "noise"),
        ProgramStep((Msg(2, 1, A, 8, B, 4, 8),), LPF_SYNC_DEFAULT, "w2"),
    ]
    cone = dependency_cone(steps, sid=2)
    assert 0 in cone and 2 in cone
    # the unrelated accumulate into A stays pending... unless A is read
    assert 1 not in cone or steps[1].msgs[0].dst_slot.sid == 1


def test_cone_empty_when_slot_untouched():
    A, B = make_slot(1, 16), make_slot(2, 16)
    steps = [ProgramStep((Msg(0, 1, A, 0, B, 0, 4),), LPF_SYNC_DEFAULT,
                         "w")]
    assert dependency_cone(steps, sid=99) == []
    assert dependency_cone(steps, sid=1) == []      # A is only read
    assert dependency_cone(steps, sid=1, include_reads=True) == [0]


# ---------------------------------------------------------------------------
# the differential property: cone-first execution == in-order execution
# ---------------------------------------------------------------------------

def random_program(seed):
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 8))
    slots = [make_slot(100 + i, int(rng.integers(8, 25)), "int32")
             for i in range(int(rng.integers(2, 5)))]
    steps = []
    for k in range(int(rng.integers(2, 7))):
        reduce_op = [None, None, None, "sum", "max", "min"][
            int(rng.integers(6))]
        attrs = SyncAttributes(reduce_op=reduce_op)
        msgs = []
        for _ in range(int(rng.integers(0, 9))):
            a = slots[int(rng.integers(len(slots)))]
            b = slots[int(rng.integers(len(slots)))]
            size = int(rng.integers(1, min(a.size, b.size) + 1))
            msgs.append(Msg(
                src=int(rng.integers(p)), dst=int(rng.integers(p)),
                src_slot=a, src_off=int(rng.integers(a.size - size + 1)),
                dst_slot=b, dst_off=int(rng.integers(b.size - size + 1)),
                size=size))
        steps.append(ProgramStep(tuple(msgs), attrs, f"s{k}"))
    return p, slots, steps


@table_property
def test_cone_first_execution_bit_identical(seed):
    """Flushing a read slot's cone early, then the deferred remainder,
    must equal in-order execution on every slot of every process — the
    exact reordering the dataflow-precise flush performs."""
    rng = np.random.default_rng(seed + 7)
    p, slots, steps = random_program(seed)
    read_slot = slots[int(rng.integers(len(slots)))]
    cone = dependency_cone(steps, read_slot.sid,
                           include_reads=bool(rng.integers(2)))
    cone_set = set(cone)
    reordered = [steps[i] for i in cone] + \
        [s for i, s in enumerate(steps) if i not in cone_set]
    values = {s.sid: rng.integers(-10_000, 10_000,
                                  size=(p, s.size)).astype(np.int32)
              for s in slots}
    eager = simulate_program([(s.msgs, s.attrs) for s in steps], values)
    split = simulate_program([(s.msgs, s.attrs) for s in reordered],
                             values)
    for sid in eager:
        assert (eager[sid] == split[sid]).all(), sid
    # and the cone is genuinely a cone: every writer of the slot is in it
    for i, s in enumerate(steps):
        if any(m.dst_slot.sid == read_slot.sid for m in s.msgs):
            assert i in cone_set


# ---------------------------------------------------------------------------
# XLA: the real ctx.program() cone-flush path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_read_flushes_exactly_its_cone(mesh8):
    """Inside a recording, reading one slot executes exactly its
    dependency cone (ledger superstep count == cone size); independent
    supersteps stay pending until end_record — and the final values
    match eager execution bit for bit."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.core import compat

    boxes = {}

    def run(recorded):
        box = {}

        def wrapped(_):
            ctx = lpf.LPFContext(("x",))
            box["ledger"] = ctx.ledger
            ctx.resize_memory_register(4)
            ctx.resize_message_queue(4 * ctx.p)
            p = ctx.p
            a = ctx.register_global(
                "a", (jnp.arange(8) + 100 * ctx.pid).astype(jnp.int32))
            b = ctx.register_global("b", jnp.zeros(8, jnp.int32))
            c = ctx.register_global("c", jnp.zeros(8, jnp.int32))
            d = ctx.register_global("d", jnp.zeros(8, jnp.int32))

            def steps():
                ctx.put(a, b, to=lambda s: (s + 1) % p, size=4)
                ctx.sync(lpf.SyncAttributes(reduce_op="sum"), label="w_b")
                ctx.put(a, c, to=lambda s: (s + 2) % p, size=4)
                ctx.sync(label="w_c")
                ctx.put(b, d, to=lambda s: (s + 3) % p, size=4)
                ctx.sync(label="b2d")
                if recorded:
                    # the read of c: its cone is just w_c — one ledger
                    # entry; w_b and b2d (a RAW chain) stay pending
                    assert len(ctx._rec_pending) == 3
                cval = ctx.value(c)
                if recorded:
                    assert box["ledger"].supersteps == 1
                    assert box["ledger"].records[0].label == "w_c"
                    assert len(ctx._rec_pending) == 2
                # reading d pulls the chain [w_b, b2d]
                dval = ctx.value(d)
                if recorded:
                    assert box["ledger"].supersteps == 3
                    assert not ctx._rec_pending
                return cval, dval

            if recorded:
                with ctx.program():
                    out = steps()
            else:
                out = steps()
            return out

        fn = jax.jit(compat.shard_map(
            wrapped, mesh=mesh8, in_specs=(P(),),
            out_specs=(P("x"), P("x")), check_vma=False))
        boxes[recorded] = box
        return [np.asarray(v) for v in fn(jnp.zeros(1))]

    eager = run(False)
    coned = run(True)
    for e, o in zip(eager, coned):
        np.testing.assert_array_equal(e, o)


@pytest.mark.slow
def test_cone_flush_replay_hits_program_cache(mesh8):
    """Satellite: after a cone flush splits a trace in two, replaying
    the loop still hits the program cache for BOTH sub-programs, and
    ``ctx.cache_stats.reset()`` zeroes the counters while keeping the
    caches warm."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as lpf
    from repro.core import compat

    plan_cache = lpf.PlanCache()
    program_cache = lpf.ProgramCache()
    stats_box = {}

    def spmd(ctx):
        ctx.resize_memory_register(3)
        ctx.resize_message_queue(2 * ctx.p)
        p = ctx.p
        a = ctx.register_global("a", jnp.arange(4.0) + ctx.pid)
        b = ctx.register_global("b", jnp.zeros(8))
        c = ctx.register_global("c", jnp.zeros(8))
        acc = jnp.zeros(8)
        for i in range(10):
            with ctx.program():
                ctx.put(a, b, to=lambda s: (s + 1) % p, size=4)
                ctx.sync(label="w_b")
                ctx.put(a, c, to=lambda s: (s + 2) % p, size=4)
                ctx.sync(label="w_c")
                # mid-program read of b: cone flush -> [w_b] executes,
                # [w_c] stays pending until end_record
                acc = acc + ctx.value(b)
            acc = acc + ctx.value(c)
            if i == 0:
                # replay loop measured from a clean slate: the
                # satellite reset() keeps the caches warm but zeroes
                # the counters
                ctx.cache_stats.reset()
                assert ctx.cache_stats["program"].misses == 0
                assert ctx.cache_stats["plan"].misses == 0
        stats_box["stats"] = ctx.cache_stats
        return acc

    def wrapped(_):
        ctx = lpf.LPFContext(("x",), plan_cache=plan_cache,
                             program_cache=program_cache)
        return spmd(ctx)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh8, in_specs=(P(),),
                                  out_specs=P("x"), check_vma=False))
    np.asarray(fn(jnp.zeros(1)))
    stats = stats_box["stats"]
    # 9 replay iterations x 2 sub-programs (the cone + the remainder),
    # all hits, no optimizer or planner activity after the reset
    assert stats["program"].hits == 18
    assert stats["program"].misses == 0
    assert stats["plan"].misses == 0
