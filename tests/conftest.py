"""Test fixtures.

Eight host devices are enabled HERE ONLY (not globally/pyproject): the
LPF semantics/property tests need p > 1 SPMD processes, while the model
smoke tests are sharding-free (device-count agnostic, everything lands on
device 0).  The 512-device production override belongs exclusively to
``repro.launch.dryrun`` (its first two lines), never to the test session.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

# `python -m pytest` from the repo root works without an installed package
# or a PYTHONPATH export (the tier-1 command still sets one; harmless).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

try:  # hypothesis is an optional [test] extra; profiles only matter then
    from hypothesis import settings as _hyp_settings

    # "dev" keeps the fast tier fast; the nightly workflow selects
    # "ci-slow" via `pytest --hypothesis-profile=ci-slow` so the
    # differential harnesses get real fuzzing time.  Property tests that
    # want the profile budget must NOT pin max_examples themselves.
    _hyp_settings.register_profile("dev", max_examples=60, deadline=None)
    _hyp_settings.register_profile("ci-slow", max_examples=600,
                                   deadline=None)
    _hyp_settings.load_profile("dev")
except ImportError:  # pragma: no cover - seeded fallbacks take over
    pass

from repro.core import compat  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return compat.make_mesh((8,), ("x",))


@pytest.fixture(scope="session")
def mesh_pdm():
    """Tiny (pod, data, model) mesh for multi-axis tests."""
    return compat.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
