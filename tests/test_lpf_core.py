"""LPF core semantics: the twelve primitives against explicit oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import core as lpf
from repro.core import (CompressSpec, LPFCapacityError, LPFFatalError,
                        SyncAttributes)

pytestmark = [pytest.mark.filterwarnings("ignore::DeprecationWarning"),
              pytest.mark.slow]


def run8(mesh8, spmd, args=None, out_specs=P("x"), **kw):
    return lpf.exec_(mesh8, spmd, args, out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# put / get / sync
# ---------------------------------------------------------------------------

def test_put_shift(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.arange(4.0) + 10.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(4))
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=4)
        ctx.sync()
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 4)
    want = np.stack([np.arange(4.0) + 10.0 * ((i - 1) % 8)
                     for i in range(8)])
    np.testing.assert_allclose(out, want)


def test_get_neighbour(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.full(3, 1.0) * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(3))
        ctx.get(src, dst, frm=lambda s: (s + 2) % p, size=3)
        ctx.sync()
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 3)
    np.testing.assert_allclose(
        out, np.stack([np.full(3, (i + 2) % 8.0) for i in range(8)]))


def test_offsets_and_partial_sizes(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.arange(8.0) + 100.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.full(8, -1.0))
        # send elements [2:5) to the right neighbour's offset 1
        ctx.put(src, dst, to=lambda s: (s + 1) % p, src_off=2, dst_off=1,
                size=3)
        ctx.sync()
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 8)
    for i in range(8):
        left = (i - 1) % 8
        want = np.full(8, -1.0)
        want[1:4] = np.arange(2.0, 5.0) + 100.0 * left
        np.testing.assert_allclose(out[i], want)


def test_crcw_highest_pid_wins(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        mine = ctx.register_global("m", jnp.full(2, 1.0) * ctx.pid)
        tgt = ctx.register_global("t", jnp.full(2, -1.0))
        ctx.put(mine, tgt, to=0, size=2)
        ctx.sync()
        return ctx.tensor(tgt)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 2)
    assert out[0, 0] == 7.0               # arbitrary-CRCW: last writer wins
    assert (out[1:] == -1.0).all()        # non-targets untouched


def test_reads_observe_pre_sync_values(mesh8):
    """All payloads must be read from the pre-superstep state, even when
    the same slot is both source and destination."""
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(1)
        ctx.resize_message_queue(p)
        buf = ctx.register_global("b", jnp.full(2, 1.0) * ctx.pid)
        ctx.put(buf, buf, to=lambda s: (s + 1) % p, size=2)
        ctx.sync()
        return ctx.tensor(buf)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 2)
    np.testing.assert_allclose(out[:, 0], [(i - 1) % 8 for i in range(8)])


# ---------------------------------------------------------------------------
# methods: bruck / valiant / fused equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["direct", "bruck"])
def test_methods_agree_on_permutation(mesh8, method):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.arange(4.0) + 10.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(4))
        ctx.put(src, dst, to=lambda s: (s * 3 + 1) % p, size=4)
        ctx.sync(SyncAttributes(method=method))
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 4)
    # invert the permutation d = (3s + 1) mod 8
    inv = {(3 * s + 1) % 8: s for s in range(8)}
    want = np.stack([np.arange(4.0) + 10.0 * inv[i] for i in range(8)])
    np.testing.assert_allclose(out, want)


def test_valiant_routing(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(3)
        ctx.resize_message_queue(4 * p, valiant_payload=64)
        src = ctx.register_global("src", jnp.arange(4.0) + 10.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(4))
        ctx.put(src, dst, to=lambda s: (s + 5) % p, size=4)
        ctx.sync(SyncAttributes(method="valiant"))
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd)).reshape(8, 4)
    want = np.stack([np.arange(4.0) + 10.0 * ((i - 5) % 8)
                     for i in range(8)])
    np.testing.assert_allclose(out, want)


def test_fused_total_exchange_detection(mesh8):
    def spmd(ctx, s, p, _):
        w = 2
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global(
            "src", jnp.arange(p * w, dtype=jnp.float32) + 100.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(p * w))
        ctx.put_msgs([(s_, d, src, d * w, dst, s_ * w, w)
                      for s_ in range(p) for d in range(p)])
        ctx.sync(label="a2a")
        return ctx.tensor(dst)

    out, ledger = run8(mesh8, spmd, return_ledger=True)
    assert ledger.records[0].method == "fused"
    assert ledger.records[0].rounds == 1
    out = np.asarray(out).reshape(8, 16)
    want = np.stack([np.concatenate(
        [np.arange(d * 2, d * 2 + 2) + 100.0 * s for s in range(8)])
        for d in range(8)])
    np.testing.assert_allclose(out, want)


# ---------------------------------------------------------------------------
# capacity / errors (mitigable before side effects)
# ---------------------------------------------------------------------------

def test_queue_capacity_mitigable(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(2)          # deliberately too small
        src = ctx.register_global("src", jnp.zeros(4))
        dst = ctx.register_global("dst", jnp.zeros(4))
        try:
            ctx.put(src, dst, to=lambda s: (s + 1) % p, size=4)  # p msgs
            code = 0
        except LPFCapacityError:
            # mitigate: grow the queue and retry — no side effects happened
            ctx.resize_message_queue(p)
            ctx.put(src, dst, to=lambda s: (s + 1) % p, size=4)
            code = 1
        ctx.sync()
        return jnp.full((1,), code, jnp.int32)

    out = np.asarray(run8(mesh8, spmd)).reshape(-1)
    assert (out == 1).all()


def test_register_capacity(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(1)
        ctx.register_global("a", jnp.zeros(2))
        try:
            ctx.register_global("b", jnp.zeros(2))
            return jnp.zeros((1,), jnp.int32)
        except LPFCapacityError:
            return jnp.ones((1,), jnp.int32)

    assert (np.asarray(run8(mesh8, spmd)) == 1).all()


def test_oob_message_fatal(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.zeros(4))
        dst = ctx.register_global("dst", jnp.zeros(2))
        ctx.put(src, dst, to=0, size=4)   # dst too small
        ctx.sync()
        return jnp.zeros((1,))

    with pytest.raises(LPFFatalError):
        run8(mesh8, spmd)


def test_local_slot_semantics(mesh8):
    """put FROM a local slot is legal (Algorithm 2's error broadcast);
    put INTO a local slot (remotely referred) is fatal."""
    def spmd_ok(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_local("src", jnp.full(4, 1.0) * ctx.pid)
        dst = ctx.register_global("dst", jnp.zeros(4))
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=4)
        ctx.sync()
        return ctx.tensor(dst)

    out = np.asarray(run8(mesh8, spmd_ok)).reshape(8, 4)
    np.testing.assert_allclose(out[:, 0], [(i - 1) % 8 for i in range(8)])

    def spmd_bad(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.zeros(4))
        dst = ctx.register_local("dst", jnp.zeros(4))
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=4)
        ctx.sync()
        return jnp.zeros((1,))

    with pytest.raises(LPFFatalError):
        run8(mesh8, spmd_bad)


# ---------------------------------------------------------------------------
# probe / ledger / compliance accounting
# ---------------------------------------------------------------------------

def test_probe_table():
    m = lpf.probe({"data": 16, "model": 16}, lpf.TPU_V5E)
    assert m.p == 256
    assert m.g > 0 and m.l > 0
    assert m.t_comm(1e6) > m.t_comm(0)
    m2 = lpf.probe({"pod": 2, "data": 16, "model": 16}, lpf.TPU_V5E)
    assert m2.g > m.g * 0.9   # DCN-dominated g is never better than ICI


def test_ledger_h_relation(mesh8):
    """The ledger must record exactly the BSP h-relation of the pattern."""
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.zeros(10))
        dst = ctx.register_global("dst", jnp.zeros(10))
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=10)
        ctx.sync(label="shift10")
        return ctx.tensor(dst)

    _, ledger = run8(mesh8, spmd, return_ledger=True)
    rec = ledger.records[0]
    assert rec.h_bytes == 10 * 4          # 10 f32 sent == received per pid
    assert rec.n_msgs == 8
    assert rec.rounds == 1


def test_compressed_sync_wire_bytes(mesh8):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p)
        src = ctx.register_global("src", jnp.linspace(-1, 1, 16))
        dst = ctx.register_global("dst", jnp.zeros(16))
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=16)
        ctx.sync(SyncAttributes(compress=CompressSpec(bits=8)))
        return ctx.tensor(dst)

    out, ledger = run8(mesh8, spmd, return_ledger=True)
    out = np.asarray(out).reshape(8, 16)
    np.testing.assert_allclose(out[0], np.linspace(-1, 1, 16), atol=0.02)
    # int8 wire: ~4x fewer bytes than the h-relation's f32 accounting
    assert ledger.records[0].wire_bytes < ledger.records[0].h_bytes / 2


def test_rehook_pristine_context(mesh8):
    def sub(ctx, s, p, args):
        ctx.resize_memory_register(1)
        ctx.resize_message_queue(p)
        src = ctx.register_global("v", jnp.full(1, 1.0) * ctx.pid)
        dst = src
        ctx.put(src, dst, to=lambda s: (s + 1) % p, size=1)
        ctx.sync()
        return ctx.tensor(dst)

    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(1)
        ctx.register_global("outer", jnp.zeros(1))
        inner = lpf.rehook(ctx, sub)       # fresh registry, same procs
        assert ctx.registry.n_active == 1  # outer context untouched
        return inner

    out = np.asarray(run8(mesh8, spmd)).reshape(-1)
    np.testing.assert_allclose(out, [(i - 1) % 8 for i in range(8)])


def test_on_hold_context_rejects_staging_and_sync():
    """Active contexts are disjoint (paper S2.2): while a rehook
    sub-program runs, the parent context must refuse staging and sync."""
    from repro.core import LPFContext

    ctx = LPFContext(())
    ctx.resize_memory_register(2)
    ctx.resize_message_queue(4)
    a = ctx.register_global("a", jnp.arange(4.0))
    b = ctx.register_global("b", jnp.zeros(4))
    seen = []

    def sub(sub_ctx, s, p, _):
        for stage in (lambda: ctx.put(a, b, to=0, size=4),
                      lambda: ctx.get(a, b, frm=0, size=4),
                      lambda: ctx.put_msgs([(0, 0, a, 0, b, 0, 4)]),
                      lambda: ctx.sync()):
            with pytest.raises(LPFFatalError):
                stage()
            seen.append(1)
        return jnp.zeros(1)

    lpf.rehook(ctx, sub)
    assert len(seen) == 4
    # released after the sub-program: the parent context works again
    ctx.put(a, b, to=0, size=4)
    ctx.sync()
    np.testing.assert_allclose(np.asarray(ctx.tensor(b)), np.arange(4.0))


def test_valiant_scratch_resize_does_not_leak_slots():
    """Re-provisioning the Valiant scratch must replace the old slot, not
    leak a registration per resize_message_queue call."""
    from repro.core import LPFContext

    ctx = LPFContext(())
    ctx.resize_message_queue(4, valiant_payload=32)
    baseline = ctx.registry.n_active
    for _ in range(5):
        ctx.resize_message_queue(4, valiant_payload=64)
    assert ctx.registry.n_active == baseline
    assert ctx._scratch is not None and ctx._scratch.size == 64
    # user slots registered alongside survive the re-provisioning
    ctx.resize_memory_register(1)
    slot = ctx.register_global("user", jnp.zeros(4))
    ctx.resize_message_queue(4, valiant_payload=16)
    assert ctx.registry.value(slot).shape == (4,)


def test_pad_to_validation():
    from repro.bsp import pad_to

    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(pad_to(x, 6)),
                               [0, 1, 2, 3, 0, 0])
    assert pad_to(x, 4) is x
    with pytest.raises(LPFFatalError):       # cannot shrink
        pad_to(x, 3)
    with pytest.raises(LPFFatalError):       # 1-D only
        pad_to(jnp.zeros((2, 2)), 8)


def test_sequential_root_context():
    """LPF_ROOT: p=1 context outside any mesh — puts are memcpys."""
    from repro.core import LPFContext
    ctx = LPFContext(())
    ctx.resize_memory_register(2)
    ctx.resize_message_queue(4)
    a = ctx.register_global("a", jnp.arange(4.0))
    b = ctx.register_global("b", jnp.zeros(4))
    ctx.put(a, b, to=0, size=4)
    ctx.sync()
    np.testing.assert_allclose(np.asarray(ctx.tensor(b)), np.arange(4.0))


def test_sequential_reads_observe_pre_sync_values():
    """Chained p=1 puts (a->b, b->c) in one superstep must deliver b's
    PRE-superstep contents to c, matching the p>1 direct semantics."""
    from repro.core import LPFContext
    ctx = LPFContext(())
    ctx.resize_memory_register(3)
    ctx.resize_message_queue(4)
    a = ctx.register_global("a", jnp.arange(1.0, 5.0))
    b = ctx.register_global("b", jnp.full(4, 7.0))
    c = ctx.register_global("c", jnp.zeros(4))
    ctx.put(a, b, to=0, size=4)
    ctx.put(b, c, to=0, size=4)
    ctx.sync()
    np.testing.assert_allclose(np.asarray(ctx.tensor(b)),
                               np.arange(1.0, 5.0))
    np.testing.assert_allclose(np.asarray(ctx.tensor(c)), 7.0)
