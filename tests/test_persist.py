"""Persistent program cache: round trip, re-verification, corruption
paths, eviction write-back, env wiring, and the cross-process warm
start (zero re-plans / zero searches / ledger bit-for-bit)."""

import dataclasses
import json
import os
import shutil
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis.verifier import VerifierReport
from repro.core import (CacheStats, LPF_SYNC_DEFAULT, LPFContext,
                        LPFMachine, Msg, PersistError, PersistentStore,
                        ProgramCache, ProgramStep, Slot,
                        steps_from_signature)
from repro.core.persist import FORMAT_VERSION, entry_filename
from repro.runtime.monitor import cache_metrics

P = 4
MACHINE = LPFMachine(p=P, g=1e-9, l=1e-6, r=1e-10)


def make_slot(sid, size=16):
    return Slot(sid=sid, name=f"s{sid}", size=size,
                dtype=np.dtype("float32"), kind="global",
                orig_shape=(size,))


def shift_trace(n_steps=3, base_sid=0):
    """n_steps independent shifts through distinct slot pairs — each a
    distinct content key, so the program has a unique canonical form."""
    steps = []
    for k in range(n_steps):
        a = make_slot(base_sid + 2 * k)
        b = make_slot(base_sid + 2 * k + 1)
        msgs = tuple(Msg(s, (s + k + 1) % P, a, 0, b, 0, 4 * (k + 1),
                         origin="put") for s in range(P))
        steps.append(ProgramStep(msgs, LPF_SYNC_DEFAULT, f"s{k}"))
    return steps


def build_and_certify(cache, steps=None):
    steps = steps if steps is not None else shift_trace()
    prog, key = cache.get_or_build_keyed(steps, P, MACHINE)
    cert = cache.certify(key, steps, prog)
    assert cert.ok
    return prog, key, steps


# ---------------------------------------------------------------------------
# round trip + warm start (in-process)
# ---------------------------------------------------------------------------

def test_roundtrip_and_warm_hit(tmp_path):
    cold = ProgramCache(persist_dir=str(tmp_path))
    prog, key, steps = build_and_certify(cold)
    assert cold.stats.misses == 1 and cold.stats.disk_misses == 1
    assert os.path.exists(tmp_path / entry_filename(key))

    warm = ProgramCache(persist_dir=str(tmp_path))
    prog2, key2 = warm.get_or_build_keyed(steps, P, MACHINE)
    assert key2 == key
    # a warm start is NOT a schedule search: the disk hit replaces the
    # optimize_program run entirely
    assert warm.stats.misses == 0
    assert warm.stats.disk_hits == 1 and warm.stats.invalidated == 0
    # the loaded entry arrives pre-certified (re-verified at load)
    cert2 = warm.certify(key2, steps, prog2)
    assert cert2.ok
    # identical IR, field for field
    assert dataclasses.asdict(prog2) == dataclasses.asdict(prog)


def test_store_survives_clear(tmp_path):
    cache = ProgramCache(persist_dir=str(tmp_path))
    _, key, steps = build_and_certify(cache)
    cache.clear()
    assert len(cache) == 0
    prog, _ = cache.get_or_build_keyed(steps, P, MACHINE)
    assert cache.stats.misses == 0 and cache.stats.disk_hits == 1


def test_reconstructed_trace_matches_signature(tmp_path):
    """steps_from_signature is signature-exact — the offline audit
    verifies the same canonical program the recorder persisted."""
    from repro.core import program_signature

    steps = shift_trace()
    sig = program_signature(steps, P)
    p2, steps2, scratch2 = steps_from_signature(sig)
    assert p2 == P and scratch2 is None
    assert program_signature(steps2, p2) == sig


# ---------------------------------------------------------------------------
# corruption / skew: every path degrades to a cold miss, never an error
# ---------------------------------------------------------------------------

def _tamper_truncate(path):
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:len(blob) - 7])


def _tamper_bitflip(path):
    blob = bytearray(open(path, "rb").read())
    blob[-3] ^= 0x40
    open(path, "wb").write(bytes(blob))


def _tamper_header(field, value):
    def tamper(path):
        blob = open(path, "rb").read()
        nl = blob.find(b"\n")
        header = json.loads(blob[:nl])
        header[field] = value
        open(path, "wb").write(
            json.dumps(header).encode() + blob[nl:])
    return tamper


def _tamper_garbage(path):
    open(path, "wb").write(b"not a cache entry at all")


@pytest.mark.parametrize("tamper", [
    _tamper_truncate,
    _tamper_bitflip,
    _tamper_header("format", FORMAT_VERSION + 1),
    _tamper_header("jax", "0.0.0"),
    _tamper_header("magic", "pickle"),
    _tamper_garbage,
], ids=["truncated", "bitflip", "format-skew", "jax-skew", "bad-magic",
        "garbage"])
def test_corrupt_entry_degrades_to_cold_miss(tmp_path, tamper):
    rec = ProgramCache(persist_dir=str(tmp_path))
    prog, key, steps = build_and_certify(rec)
    path = str(tmp_path / entry_filename(key))
    tamper(path)

    cache = ProgramCache(persist_dir=str(tmp_path))
    prog2, key2 = cache.get_or_build_keyed(steps, P, MACHINE)   # no raise
    assert key2 == key
    assert cache.stats.invalidated == 1 and cache.stats.disk_hits == 0
    assert cache.stats.misses == 1          # re-optimized from scratch
    assert dataclasses.asdict(prog2) == dataclasses.asdict(prog)
    # the bad entry was dropped, and certification re-persists a good
    # one: the next fresh process warm-starts again
    cert = cache.certify(key2, steps, prog2)
    assert cert.ok
    fresh = ProgramCache(persist_dir=str(tmp_path))
    fresh.get_or_build_keyed(steps, P, MACHINE)
    assert fresh.stats.disk_hits == 1 and fresh.stats.invalidated == 0


def test_renamed_entry_rejected_as_key_mismatch(tmp_path):
    """An entry copied onto another key's filename (hash collision /
    adversarial rename) must not be served for that key."""
    rec = ProgramCache(persist_dir=str(tmp_path))
    _, key_a, _ = build_and_certify(rec, shift_trace(n_steps=2))
    steps_b = shift_trace(n_steps=3)
    prog_b, key_b = rec.get_or_build_keyed(steps_b, P, MACHINE)
    rec.certify(key_b, steps_b, prog_b)
    shutil.copyfile(tmp_path / entry_filename(key_a),
                    tmp_path / entry_filename(key_b))

    cache = ProgramCache(persist_dir=str(tmp_path))
    cache.get_or_build_keyed(steps_b, P, MACHINE)
    assert cache.stats.invalidated == 1 and cache.stats.disk_hits == 0


def test_save_refuses_unverified(tmp_path):
    store = PersistentStore(str(tmp_path))
    cache = ProgramCache()
    steps = shift_trace()
    prog, key = cache.get_or_build_keyed(steps, P, MACHINE)
    with pytest.raises(PersistError):
        store.save(key, prog, None)
    failed = VerifierReport(ok=False, n_steps=1, n_groups=1, n_rewrites=0)
    with pytest.raises(PersistError):
        store.save(key, prog, failed)
    assert store.filenames() == []


# ---------------------------------------------------------------------------
# write-back on evict
# ---------------------------------------------------------------------------

def test_eviction_writes_back(tmp_path):
    cache = ProgramCache(maxsize=2)                  # no store yet
    prog_a, key_a, steps_a = build_and_certify(cache, shift_trace(2))
    build_and_certify(cache, shift_trace(3))
    cache.attach_store(str(tmp_path))                # attached late
    assert PersistentStore(str(tmp_path)).filenames() == []
    # inserting a third entry evicts the oldest certified one -> disk
    cache.get_or_build_keyed(shift_trace(4), P, MACHINE)
    assert cache.stats.evictions == 1
    assert os.path.exists(tmp_path / entry_filename(key_a))

    warm = ProgramCache(persist_dir=str(tmp_path))
    warm.get_or_build_keyed(steps_a, P, MACHINE)
    assert warm.stats.disk_hits == 1


# ---------------------------------------------------------------------------
# context wiring + metrics export
# ---------------------------------------------------------------------------

def test_context_env_var_attaches_store(tmp_path, monkeypatch):
    monkeypatch.setenv("LPF_PROGRAM_CACHE_DIR", str(tmp_path))
    ctx = LPFContext((), program_cache=ProgramCache())
    assert ctx.program_cache.store is not None
    assert ctx.program_cache.store.directory == str(tmp_path)
    # explicit argument wins over the environment
    other = tmp_path / "other"
    ctx2 = LPFContext((), program_cache=ProgramCache(),
                      persist_dir=str(other))
    assert ctx2.program_cache.store.directory == str(other)
    # no env, no arg -> no store
    monkeypatch.delenv("LPF_PROGRAM_CACHE_DIR")
    ctx3 = LPFContext((), program_cache=ProgramCache())
    assert ctx3.program_cache.store is None


def test_cache_metrics_exporter(tmp_path):
    cache = ProgramCache(persist_dir=str(tmp_path))
    _, _, steps = build_and_certify(cache)
    warm = ProgramCache(persist_dir=str(tmp_path))
    warm.get_or_build_keyed(steps, P, MACHINE)
    ctx = LPFContext((), program_cache=warm)
    m = cache_metrics(ctx)
    assert m["program_disk_hits"] == 1
    assert m["program_misses"] == 0
    assert {"plan_hits", "plan_misses", "program_hits",
            "program_invalidated"} <= set(m)
    assert all(isinstance(v, int) for v in m.values())


# ---------------------------------------------------------------------------
# the analysis CLI over a persisted cache
# ---------------------------------------------------------------------------

def test_cli_record_audit_and_cost_diff(tmp_path, capsys):
    from repro.analysis.__main__ import main as cli

    cache_dir = str(tmp_path / "cache")
    costs = str(tmp_path / "costs.json")
    assert cli(["--record-cache", cache_dir, "--dump-costs", costs,
                "pagerank", "fft_redistribute"]) == 0
    assert cli(["--cache-dir", cache_dir, "--diff-costs", costs]) == 0
    out = capsys.readouterr().out
    assert "2 entries, 2 verified, 0 bad" in out
    with open(costs) as fh:
        dumped = json.load(fh)
    assert len(dumped) == 2
    assert all(c["predicted_us"] > 0 for c in dumped.values())

    # corrupt one entry: the audit must flag it and fail the run
    victim = sorted(os.listdir(cache_dir))[0]
    _tamper_bitflip(os.path.join(cache_dir, victim))
    assert cli(["--cache-dir", cache_dir]) == 1
    # a missing entry fails the cost diff
    os.remove(os.path.join(cache_dir, victim))
    assert cli(["--cache-dir", cache_dir, "--diff-costs", costs]) == 1


# ---------------------------------------------------------------------------
# the whole claim, cross-process
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cross_process_warm_start(tmp_path):
    """Record in one process, replay in a fresh one: 0 re-plans, 0
    schedule searches, every program a verified disk hit, and the
    replayed ledger bit-for-bit identical (asserted by the benchmark's
    parent process, which this test drives end to end)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks", "warm_start.py"),
         "--cache-dir", str(tmp_path)],
        env=env, cwd=repo, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 re-plans, 0 searches" in proc.stdout
    assert "ledger bit-for-bit" in proc.stdout
