"""Property-based tests: random h-relations vs a numpy oracle.

The system invariant under test is the LPF contract itself: *any* legal
static message table, executed by any method, produces the CRCW result
(ascending-source-PID sequential application) and its ledger records the
exact BSP h-relation of the pattern.
"""

import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import HealthCheck, given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro import core as lpf
from repro.core import SyncAttributes

pytestmark = pytest.mark.slow

P_PROCS = 8
SLOT = 16


@st.composite
def h_relations(draw):
    n_msgs = draw(st.integers(1, 12))
    msgs = []
    for _ in range(n_msgs):
        src = draw(st.integers(0, P_PROCS - 1))
        dst = draw(st.integers(0, P_PROCS - 1))
        size = draw(st.integers(1, 6))
        src_off = draw(st.integers(0, SLOT - size))
        dst_off = draw(st.integers(0, SLOT - size))
        msgs.append((src, dst, src_off, dst_off, size))
    return msgs


def oracle(msgs):
    """Sequential CRCW application in (src, dst, dst_off) order."""
    src_vals = np.stack([np.arange(SLOT) + 100.0 * s
                         for s in range(P_PROCS)])
    dst_vals = np.full((P_PROCS, SLOT), -1.0)
    for (s, d, so, do, sz) in sorted(msgs,
                                     key=lambda m: (m[0], m[1], m[3])):
        dst_vals[d, do:do + sz] = src_vals[s, so:so + sz]
    return dst_vals


def h_relation_bytes(msgs):
    sent = np.zeros(P_PROCS)
    recv = np.zeros(P_PROCS)
    for (s, d, so, do, sz) in msgs:
        if s != d:
            sent[s] += sz * 4
            recv[d] += sz * 4
    return int(max(sent.max(), recv.max()))


def run_relation(mesh8, msgs, attrs):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(3)
        ctx.resize_message_queue(4 * len(msgs), valiant_payload=256)
        src = ctx.register_global("src",
                                  jnp.arange(SLOT, dtype=jnp.float32)
                                  + 100.0 * ctx.pid)
        dst = ctx.register_global("dst", jnp.full(SLOT, -1.0))
        ctx.put_msgs([(s_, d, src, so, dst, do, sz)
                      for (s_, d, so, do, sz) in msgs])
        ctx.sync(attrs)
        return ctx.tensor(dst)

    out, ledger = lpf.exec_(mesh8, spmd, out_specs=P("x"),
                            return_ledger=True)
    return np.asarray(out).reshape(P_PROCS, SLOT), ledger


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(h_relations())
def test_random_h_relation_direct(mesh8, msgs):
    out, ledger = run_relation(mesh8, msgs, SyncAttributes(method="direct"))
    np.testing.assert_allclose(out, oracle(msgs))
    assert ledger.records[0].h_bytes == h_relation_bytes(msgs)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(h_relations())
def test_random_h_relation_valiant(mesh8, msgs):
    # valiant routes conflicting writes through intermediates and cannot
    # preserve CRCW source order: restrict to conflict-free tables (the
    # documented contract for two-phase routing)
    filtered = []
    for m in msgs:
        s_, d, so, do, sz = m
        clash = any(d == f[1] and do < f[3] + f[4] and f[3] < do + sz
                    for f in filtered)
        if not clash:
            filtered.append(m)
    out, _ = run_relation(mesh8, filtered, SyncAttributes(method="valiant"))
    np.testing.assert_allclose(out, oracle(filtered))


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.permutations(list(range(P_PROCS))), st.integers(1, SLOT))
def test_permutation_methods_equivalent(mesh8, perm, size):
    """direct and bruck must agree on any full permutation."""
    results = []
    for method in ("direct", "bruck"):
        def spmd(ctx, s, p, _, method=method):
            ctx.resize_memory_register(2)
            ctx.resize_message_queue(p)
            src = ctx.register_global(
                "src", jnp.arange(SLOT, dtype=jnp.float32)
                + 100.0 * ctx.pid)
            dst = ctx.register_global("dst", jnp.full(SLOT, -1.0))
            ctx.put(src, dst, to=lambda s: perm[s], size=size)
            ctx.sync(SyncAttributes(method=method))
            return ctx.tensor(dst)
        out = lpf.exec_(mesh8, spmd, out_specs=P("x"))
        results.append(np.asarray(out).reshape(P_PROCS, SLOT))
    np.testing.assert_allclose(results[0], results[1])


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=8, max_size=64))
def test_allreduce_matches_numpy(mesh8, vals):
    from repro import bsp
    base = np.asarray(vals, np.float32)

    def spmd(ctx, s, p, _):
        x = jnp.asarray(base) * (1.0 + ctx.pid.astype(jnp.float32))
        return bsp.allreduce(ctx, x)

    out = np.asarray(lpf.exec_(mesh8, spmd, out_specs=P("x")))
    out = out.reshape(P_PROCS, -1)
    want = base * sum(1.0 + i for i in range(P_PROCS))
    for row in out:
        np.testing.assert_allclose(row, want, rtol=2e-4, atol=1e-3)
