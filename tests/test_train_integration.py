"""End-to-end: distributed train steps on a tiny mesh, loss decrease,
checkpoint/resume determinism, LPF cross-pod sync + local SGD."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compat
from repro.data import DataConfig, SyntheticStream
from repro.optim import AdamWConfig
from repro.runtime.train_loop import TrainLoopConfig, train_loop
from repro.runtime.train_step import build_serve_step, build_train_step

pytestmark = pytest.mark.slow


def tiny_cfg():
    cfg = get_config("llama3.2-1b", smoke=True)
    return dataclasses.replace(cfg, vocab=256)


def mesh_dm():
    return compat.make_mesh((2, 2), ("data", "model"))


def stream_for(cfg, B=8, S=32):
    return SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=S,
                                      global_batch=B, seed=0), cfg)


def test_train_loss_decreases(tmp_path):
    cfg = tiny_cfg()
    mesh = mesh_dm()
    ts = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=3e-3))
    out = train_loop(ts, stream_for(cfg),
                     TrainLoopConfig(steps=30, ckpt_dir=None))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert np.isfinite(last)
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_bitexact(tmp_path):
    cfg = tiny_cfg()
    mesh = mesh_dm()
    ts = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=1e-3),
                          donate=False)
    stream = stream_for(cfg)
    # run 1: 10 steps with a checkpoint at 5
    out_a = train_loop(ts, stream, TrainLoopConfig(
        steps=10, ckpt_dir=str(tmp_path / "a"), ckpt_every=5))
    # run 2: restart from the step-5 checkpoint and continue
    out_b = train_loop(ts, stream, TrainLoopConfig(
        steps=10, ckpt_dir=str(tmp_path / "a"), ckpt_every=100,
        resume=True))
    # resumed from step 10 checkpoint -> no steps ran; force from 5:
    import shutil
    shutil.rmtree(tmp_path / "a" / "step_10")
    out_c = train_loop(ts, stream, TrainLoopConfig(
        steps=10, ckpt_dir=str(tmp_path / "a"), ckpt_every=100,
        resume=True))
    for la, lc in zip(out_a["losses"][5:], out_c["losses"]):
        assert abs(la - lc) < 1e-4, (la, lc)


def test_grad_accumulation_equivalence():
    """k-microbatch accumulation == single big batch (same grads step)."""
    cfg = tiny_cfg()
    mesh = mesh_dm()
    ts1 = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=1e-3),
                           grad_accum=1, donate=False)
    ts4 = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=1e-3),
                           grad_accum=4, donate=False)
    stream = stream_for(cfg)
    batch = jax.tree.map(jnp.asarray, stream.batch(0))
    p0, o0 = ts1.init_fn(jax.random.PRNGKey(0))
    p1, _, m1 = ts1.step_fn(p0, o0, batch)
    p0b, o0b = ts4.init_fn(jax.random.PRNGKey(0))
    p4, _, m4 = ts4.step_fn(p0b, o0b, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        diff = float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
        assert diff < 5e-3, diff


def test_lpf_pod_sync_mode(mesh_pdm):
    """LPF cross-pod gradient sync: runs, loss finite, params identical
    across pods (replicated out-spec enforces it structurally)."""
    cfg = tiny_cfg()
    ts = build_train_step(cfg, mesh_pdm, opt_cfg=AdamWConfig(lr=1e-3),
                          grad_sync="lpf")
    stream = stream_for(cfg)
    params, opt = ts.init_fn(jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, stream.batch(0))
    params, opt, metrics = ts.step_fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert ts.ledger.records, "LPF mode must record superstep costs"
    # uncompressed gradients default to the fused reduce-scatter +
    # all-gather pair; ring (lax.psum) remains reachable explicitly
    assert ts.ledger.records[0].method == "rs+ag"
    assert ts.ledger.records[0].rounds == 2


def test_lpf_bucketed_overlap_pod_sync(mesh_pdm):
    """The overlapped DDP-style bucket pipeline: gradients split at
    scan-layer boundaries, synced as overlapped rs+ag bucket pairs —
    numerically equivalent to the single-pair rs+ag sync."""
    cfg = tiny_cfg()
    ts_flat = build_train_step(cfg, mesh_pdm, opt_cfg=AdamWConfig(lr=1e-3),
                               grad_sync="lpf", donate=False)
    ts_bkt = build_train_step(cfg, mesh_pdm, opt_cfg=AdamWConfig(lr=1e-3),
                              grad_sync="lpf", donate=False,
                              grad_bucket_bytes=1 << 20)
    stream = stream_for(cfg)
    batch = jax.tree.map(jnp.asarray, stream.batch(0))
    p0, o0 = ts_flat.init_fn(jax.random.PRNGKey(0))
    pf, _, mf = ts_flat.step_fn(p0, o0, batch)
    p0b, o0b = ts_bkt.init_fn(jax.random.PRNGKey(0))
    pb, _, mb = ts_bkt.step_fn(p0b, o0b, batch)
    assert np.isfinite(float(mb["loss"]))
    assert abs(float(mf["loss"]) - float(mb["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pb)):
        diff = float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
        assert diff < 1e-4, diff
    # the ledger carries the overlapped bucket schedule: rs/ag halves
    # and overlap[..] groups
    assert ts_bkt.ledger.records
    assert all(r.method == "bucketed_overlap"
               or r.method.startswith("overlap[")
               for r in ts_bkt.ledger.records)
    assert sum(r.wire_bytes for r in ts_bkt.ledger.records) > 0


def test_local_sgd_stale_sync(mesh_pdm):
    """sync_every=k: inner steps skip the pod sync (stale), outer steps
    run it — loss still decreases."""
    cfg = tiny_cfg()
    ts_sync = build_train_step(cfg, mesh_pdm, opt_cfg=AdamWConfig(lr=3e-3),
                               grad_sync="lpf")
    ts_local = build_train_step(cfg, mesh_pdm, opt_cfg=AdamWConfig(lr=3e-3),
                                grad_sync="gspmd")
    stream = stream_for(cfg)
    out = train_loop(ts_sync, stream,
                     TrainLoopConfig(steps=16, sync_every=4),
                     step_fn_nosync=ts_local.step_fn)
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < np.mean(out["losses"][:3])


def test_serve_step_distributed(mesh_pdm):
    cfg = tiny_cfg()
    ss = build_serve_step(cfg, mesh_pdm, global_batch=4, cache_len=16)
    from repro.models import init_caches, init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, ss.param_sharding)
    caches = jax.device_put(init_caches(cfg, 4, 16), ss.cache_sharding)
    tok = jnp.zeros((4,), jnp.int32)
    for pos in range(3):
        tok, caches = ss.step_fn(params, caches, tok, jnp.int32(pos))
    assert tok.shape == (4,)
    assert int(tok.max()) < cfg.vocab


def test_steps_per_call_matches_iterated_single_steps():
    """K steps rolled into one scan == K single-step calls: same params
    (to optimizer tolerance), metrics stacked [K]."""
    cfg = tiny_cfg()
    mesh = mesh_dm()
    ts1 = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=1e-3),
                           donate=False)
    ts3 = build_train_step(cfg, mesh, opt_cfg=AdamWConfig(lr=1e-3),
                           donate=False, steps_per_call=3)
    stream = stream_for(cfg)
    batches = [jax.tree.map(jnp.asarray, stream.batch(i))
               for i in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    p_ref, o_ref = ts1.init_fn(jax.random.PRNGKey(0))
    losses = []
    for b in batches:
        p_ref, o_ref, m = ts1.step_fn(p_ref, o_ref, b)
        losses.append(float(m["loss"]))
    p0, o0 = ts3.init_fn(jax.random.PRNGKey(0))
    p_scan, _, metrics = ts3.step_fn(p0, o0, stacked)

    assert metrics["loss"].shape == (3,)
    np.testing.assert_allclose(np.asarray(metrics["loss"]), losses,
                               atol=5e-3)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_scan)):
        diff = float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max())
        assert diff < 5e-3, diff


def test_serve_decode_fn_matches_per_token(mesh_pdm):
    """The fused decode loop (one scan) == per-token jitted dispatch."""
    from repro.models import init_caches, init_params
    cfg = tiny_cfg()
    B, L, T = 4, 32, 6
    ss = build_serve_step(cfg, mesh_pdm, global_batch=B, cache_len=L,
                          donate_cache=False)
    params = jax.device_put(init_params(jax.random.PRNGKey(1), cfg),
                            ss.param_sharding)
    caches0 = jax.device_put(init_caches(cfg, B, L), ss.cache_sharding)
    tok0 = jnp.zeros((B,), jnp.int32)

    tok, caches, seq = tok0, caches0, []
    for pos in range(T):
        tok, caches = ss.step_fn(params, caches, tok, jnp.int32(pos))
        seq.append(np.asarray(tok))
    caches1 = jax.device_put(init_caches(cfg, B, L), ss.cache_sharding)
    toks, _ = ss.decode_fn(T)(params, caches1, tok0, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(toks), np.stack(seq))
    # memoized per length
    assert ss.decode_fn(T) is ss.decode_fn(T)
