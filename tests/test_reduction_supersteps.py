"""Reduction supersteps: fused_rs / fused_scatter / fused_gather.

Two families of checks, mirroring ``tests/test_sync_plan.py``'s
cache/compliance XLA test:

* **ledger vs HLO** — the fused methods' ledger entries must describe
  what the compiler actually scheduled: one native collective
  (``reduce-scatter`` / ``all-to-all`` / ``all-gather``), no
  ``collective-permute`` chains, and wire bytes within the collective's
  operand bytes.
* **bit-for-bit vs direct** — for integer dtypes a reduction superstep
  must produce *exactly* the same result through the fused one-shot as
  through the generic coloured-round ``direct`` method (integer sums,
  maxes and mins are associative, so any schedule agrees exactly).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import bsp, core as lpf
from repro.core import FUSED_METHODS, SyncAttributes, compat
from repro.core.hlo_analysis import parse_collectives

pytestmark = pytest.mark.slow


def _compile_with_ledger(mesh, spmd, x, out_specs):
    """jit-compile an LPF spmd fn; returns (compiled fn, trace ledger)."""
    box = {}

    def wrapped(a):
        ctx = lpf.LPFContext(("x",))
        box["ledger"] = ctx.ledger
        return spmd(ctx, ctx.pid, ctx.p, a)

    fn = jax.jit(compat.shard_map(wrapped, mesh=mesh, in_specs=(P(),),
                                  out_specs=out_specs, check_vma=False))
    compiled = fn.lower(x).compile()
    return fn, compiled, box["ledger"]


# ---------------------------------------------------------------------------
# ledger vs HLO compliance
# ---------------------------------------------------------------------------

def test_allreduce_ledger_and_hlo_compliance(mesh8):
    """allreduce = fused_rs + fused_ag: rounds <= 2, per-process wire
    <= 2(n/p)(p-1)*itemsize, and the compiled HLO carries a real
    reduce-scatter instead of collective-permute rounds."""
    n, p = 1024, 8

    def spmd(ctx, s, p_, xt):
        return bsp.allreduce(ctx, xt)

    fn, compiled, ledger = _compile_with_ledger(
        mesh8, spmd, jnp.zeros(n, jnp.float32), P("x"))
    stats = parse_collectives(compiled.as_text())
    assert stats.count_by_kind.get("reduce-scatter", 0) >= 1
    assert stats.count_by_kind.get("all-gather", 0) >= 1
    assert stats.count_by_kind.get("collective-permute", 0) == 0

    rs, ag = ledger.records
    assert rs.method == "fused_rs" and ag.method == "fused_ag"
    assert rs.is_fused and ag.is_fused
    assert rs.rounds + ag.rounds == 2
    c = n // p
    assert rs.wire_bytes + ag.wire_bytes <= 2 * c * (p - 1) * 4
    # HLO result-shape bytes of the collectives stay within the promise
    assert 0 < stats.total_bytes <= ledger.total_wire_bytes * 1.25

    out = np.asarray(fn(jnp.ones(n, jnp.float32))).reshape(p, n)
    np.testing.assert_allclose(out, 8.0)


def test_reduce_is_a_genuine_reduction_to_root(mesh8):
    """The headline bugfix: ``reduce`` must run reduce-scatter + gather
    (2 fused rounds), not a full allreduce, and its ledger must say so."""
    n, p, root = 512, 8, 3

    def spmd(ctx, s, p_, xt):
        return bsp.reduce(ctx, xt + ctx.pid, root=root)

    fn, compiled, ledger = _compile_with_ledger(
        mesh8, spmd, jnp.zeros(n, jnp.float32), P("x"))
    rs, gather = ledger.records
    assert rs.method == "fused_rs"
    assert gather.method == "fused_gather"
    assert rs.rounds == 1 and gather.rounds == 1
    c = n // p
    assert rs.wire_bytes == (p - 1) * c * 4
    assert gather.wire_bytes == (p - 1) * c * 4
    stats = parse_collectives(compiled.as_text())
    assert stats.count_by_kind.get("reduce-scatter", 0) >= 1
    assert stats.count_by_kind.get("collective-permute", 0) == 0

    out = np.asarray(fn(jnp.arange(n, dtype=jnp.float32))).reshape(p, n)
    want = np.sum(np.stack([np.arange(n, dtype=np.float64) + i
                            for i in range(p)]), axis=0)
    np.testing.assert_allclose(out[root], want, rtol=1e-6)
    # the result is defined at root only; everyone else holds zeros
    assert (out[np.arange(p) != root] == 0.0).all()


def test_scatter_gather_ledger_and_hlo(mesh8):
    """fused_scatter / fused_gather: one collective each, no permute
    chains, cost equal to the direct schedule's h with a single l."""
    w, root_s, root_g = 4, 2, 5

    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(3)
        ctx.resize_message_queue(2 * p)
        full = ctx.register_global(
            "full", jnp.arange(p * w, dtype=jnp.float32) * (1.0 + ctx.pid))
        mine = ctx.register_global("mine", jnp.zeros(w))
        back = ctx.register_global("back", jnp.full(p * w, -1.0))
        ctx.put_msgs([(root_s, d, full, d * w, mine, 0, w)
                      for d in range(p)])
        ctx.sync(label="scatter")
        ctx.put_msgs([(s_, root_g, mine, 0, back, s_ * w, w)
                      for s_ in range(p)])
        ctx.sync(label="gather")
        return ctx.tensor(mine), ctx.tensor(back)

    fn, compiled, ledger = _compile_with_ledger(
        mesh8, spmd, jnp.zeros(1), (P("x"), P("x")))
    sc, ga = ledger.records
    assert sc.method == "fused_scatter" and sc.rounds == 1
    assert ga.method == "fused_gather" and ga.rounds == 1
    assert {sc.method, ga.method} <= FUSED_METHODS
    p = 8
    assert sc.wire_bytes == sc.h_bytes == (p - 1) * w * 4
    assert ga.wire_bytes == ga.h_bytes == (p - 1) * w * 4
    stats = parse_collectives(compiled.as_text())
    assert stats.count_by_kind.get("all-to-all", 0) >= 1
    assert stats.count_by_kind.get("all-gather", 0) >= 1
    assert stats.count_by_kind.get("collective-permute", 0) == 0

    mine, back = fn(jnp.zeros(1))
    mine = np.asarray(mine).reshape(p, w)
    back = np.asarray(back).reshape(p, p * w)
    want = np.stack([np.arange(p * w)[d * w:(d + 1) * w] * (1.0 + root_s)
                     for d in range(p)])
    np.testing.assert_allclose(mine, want)
    np.testing.assert_allclose(back[root_g], want.reshape(-1))
    assert (back[np.arange(p) != root_g] == -1.0).all()


def test_broadcast_takes_two_fused_rounds(mesh8):
    """broadcast = fused_scatter + fused_ag: 2 rounds instead of p+1."""
    def spmd(ctx, s, p, _):
        return bsp.broadcast(ctx, jnp.arange(64.0) + 100.0 * ctx.pid,
                             root=6)

    fn, compiled, ledger = _compile_with_ledger(
        mesh8, spmd, jnp.zeros(1), P("x"))
    scatter, ag = ledger.records
    assert scatter.method == "fused_scatter" and scatter.rounds == 1
    assert ag.method == "fused_ag" and ag.rounds == 1
    out = np.asarray(fn(jnp.zeros(1))).reshape(8, 64)
    np.testing.assert_allclose(out, np.tile(np.arange(64.0) + 600.0,
                                            (8, 1)))


def test_plan_cache_reuses_reduction_plans(mesh8):
    """Repeated allreduces through fresh slots must not re-plan: the
    first invocation plans its two supersteps once (slot-renamed
    signatures); the second replays the whole recorded program from the
    program cache without consulting the planner at all."""
    cache = lpf.global_plan_cache()
    cache.clear()
    pcache = lpf.global_program_cache()
    pcache.clear()

    def spmd(ctx, s, p, xt):
        y = bsp.allreduce(ctx, xt, label="ar1")
        return bsp.allreduce(ctx, y, label="ar2")

    fn, compiled, ledger = _compile_with_ledger(
        mesh8, spmd, jnp.zeros(64, jnp.float32), P("x"))
    # 2 allreduces x 2 supersteps = 4 syncs over 2 distinct relations:
    # exactly 2 planning passes ever run (the schedule search may
    # *consult* the memoized planner a few more times while pricing
    # merge/overlap candidates — hits, never re-plans); the second
    # allreduce replays from the program cache
    assert cache.stats.misses == 2
    assert pcache.stats.misses == 1 and pcache.stats.hits == 1
    a, b, c, d = ledger.records
    assert dataclasses.replace(a, label="") == dataclasses.replace(
        c, label="")
    assert dataclasses.replace(b, label="") == dataclasses.replace(
        d, label="")


# ---------------------------------------------------------------------------
# property: fused reductions agree with `direct` bit-for-bit on ints
# ---------------------------------------------------------------------------

def _run_reduction(mesh8, vals, w, method, reduce_op, dst_init):
    def spmd(ctx, s, p, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(p * p)
        src = ctx.register_global(
            "src", jnp.asarray(vals, jnp.int32) + 1000 * ctx.pid)
        dst = ctx.register_global(
            "dst", jnp.full(w, dst_init, jnp.int32))
        ctx.put_msgs([(s_, d, src, d * w, dst, 0, w)
                      for s_ in range(p) for d in range(p)])
        ctx.sync(SyncAttributes(method=method, reduce_op=reduce_op))
        return ctx.tensor(dst)

    return np.asarray(lpf.exec_(mesh8, spmd, None,
                                out_specs=P("x"))).reshape(8, w)


@pytest.mark.parametrize("reduce_op", ["sum", "max", "min"])
@pytest.mark.parametrize("seed", range(4))
def test_fused_rs_matches_direct_bitwise_int(mesh8, reduce_op, seed):
    """The fused one-shot and the coloured-round schedule must agree
    exactly for integer payloads — including ignoring the destination's
    pre-superstep contents (messages combine with each other only)."""
    rng = np.random.default_rng(seed)
    p, w = 8, int(rng.integers(1, 5))
    vals = rng.integers(-1000, 1000, size=p * w)
    # dst_init != identity detects any pre-existing-value leak
    fused = _run_reduction(mesh8, vals, w, "auto", reduce_op, dst_init=77)
    direct = _run_reduction(mesh8, vals, w, "direct", reduce_op,
                            dst_init=77)
    assert (fused == direct).all()
    contrib = np.stack([vals.reshape(p, w) + 1000 * s for s in range(p)])
    oracle = {"sum": contrib.sum(0), "max": contrib.max(0),
              "min": contrib.min(0)}[reduce_op]
    # every process d holds the combined chunk d
    want = np.stack([oracle[d] for d in range(p)])
    assert (fused == want).all()


@pytest.mark.parametrize("seed", range(3))
def test_generic_accumulating_superstep_matches_oracle(mesh8, seed):
    """Non-canonical conflicting tables (no fused path) still combine
    correctly through the direct accumulate schedule."""
    rng = np.random.default_rng(100 + seed)
    p, size = 8, 6
    # random many-to-few table with overlapping destination windows
    table = [(int(rng.integers(p)), int(rng.integers(3)),
              int(rng.integers(3)), int(rng.integers(1, 4)))
             for _ in range(int(rng.integers(2, 10)))]

    def spmd(ctx, s, p_, _):
        ctx.resize_memory_register(2)
        ctx.resize_message_queue(len(table))
        src = ctx.register_global(
            "src", jnp.arange(size, dtype=jnp.int32) + 10 * ctx.pid)
        dst = ctx.register_global("dst", jnp.full(size, 5, jnp.int32))
        ctx.put_msgs([(s_, d, src, so, dst, so, sz)
                      for (s_, d, so, sz) in table])
        ctx.sync(SyncAttributes(reduce_op="sum"))
        return ctx.tensor(dst)

    out = np.asarray(lpf.exec_(mesh8, spmd, None,
                               out_specs=P("x"))).reshape(8, size)
    # oracle: first write replaces, later overlapping writes add
    want = np.tile(np.full(size, 5, np.int64), (8, 1))
    written = np.zeros((8, size), bool)
    for (s_, d, so, sz) in table:
        chunk = np.arange(size, dtype=np.int64)[so:so + sz] + 10 * s_
        seg = slice(so, so + sz)
        was = written[d, seg]
        want[d, seg] = np.where(was, want[d, seg] + chunk, chunk)
        written[d, seg] = True
    assert (out == want).all()
