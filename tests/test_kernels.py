"""Pallas kernels vs pure-jnp oracles (interpret=True shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fft_stage import ops as fft_ops
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

pytestmark = pytest.mark.slow


def t(rng, shape, dt=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dt)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SWEEP = [
    # B, H, Hkv, S,   D,  causal, window, softcap, dtype
    (1, 2, 2, 128, 64, True, None, None, jnp.float32),
    (2, 4, 2, 256, 64, True, None, None, jnp.float32),
    (1, 4, 1, 128, 128, False, None, None, jnp.float32),
    (1, 2, 2, 256, 64, True, 64, None, jnp.float32),
    (1, 2, 2, 128, 64, True, None, 30.0, jnp.float32),
    (1, 2, 1, 192, 64, True, None, None, jnp.float32),   # ragged S vs block
    (1, 2, 2, 128, 64, True, None, None, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,Hkv,S,D,causal,window,softcap,dt", SWEEP)
def test_flash_forward(rng, B, H, Hkv, S, D, causal, window, softcap, dt):
    q, k, v = t(rng, (B, H, S, D), dt), t(rng, (B, Hkv, S, D), dt), \
        t(rng, (B, Hkv, S, D), dt)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        softcap=softcap, interpret=True)
    o_ref = attention_ref(q, k, v, causal=causal, window=window,
                          softcap=softcap)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert float(jnp.abs(o.astype(jnp.float32)
                         - o_ref.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("B,H,Hkv,S,D,causal,window,softcap,dt", SWEEP[:5])
def test_flash_backward(rng, B, H, Hkv, S, D, causal, window, softcap, dt):
    q, k, v = t(rng, (B, H, S, D), dt), t(rng, (B, Hkv, S, D), dt), \
        t(rng, (B, Hkv, S, D), dt)

    def loss_k(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=causal,
                                       window=window, softcap=softcap,
                                       interpret=True) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=softcap) ** 2)

    g1 = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))
        assert rel < 5e-4


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SWEEP = [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 32, 32),
    (1, 256, 2, 16, 1, 64, 64),
    (1, 128, 4, 16, 1, 16, 128),    # chunk == S
]


@pytest.mark.parametrize("B,S,H,Pd,G,N,chunk", SSD_SWEEP)
def test_ssd_kernel(rng, B, S, H, Pd, G, N, chunk):
    x = t(rng, (B, S, H, Pd))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    b = t(rng, (B, S, G, N))
    c = t(rng, (B, S, G, N))
    y, stf = ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    y_ref, st_ref = ssd_ref(x, dt, a, b, c)
    assert float(jnp.abs(y - y_ref).max()
                 / (jnp.abs(y_ref).max() + 1e-9)) < 1e-4
    assert float(jnp.abs(stf - st_ref).max()
                 / (jnp.abs(st_ref).max() + 1e-9)) < 1e-4


def test_ssd_chunk_invariance(rng):
    """Chunk length is an implementation detail: results must agree."""
    B, S, H, Pd, G, N = 1, 128, 2, 16, 1, 32
    x = t(rng, (B, S, H, Pd))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    b = t(rng, (B, S, G, N))
    c = t(rng, (B, S, G, N))
    y16, _ = ssd_scan(x, dt, a, b, c, chunk=16, interpret=True)
    y64, _ = ssd_scan(x, dt, a, b, c, chunk=64, interpret=True)
    assert float(jnp.abs(y16 - y64).max()) < 1e-4


def test_mamba_chunked_jnp_matches_ref(rng):
    """The model's chunked-jnp SSD path equals the sequential oracle."""
    from repro.models.mamba import MambaConfig, _ssd_chunked
    B, S, H, Pd, G, N = 2, 96, 4, 16, 1, 24
    cfg = MambaConfig(d_model=H * Pd // 2, d_state=N, head_dim=Pd,
                      chunk=32)
    x = t(rng, (B, S, H, Pd))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    b = t(rng, (B, S, G, N))
    c = t(rng, (B, S, G, N))
    y, st = _ssd_chunked(x, dt, a, jnp.repeat(b, H, 2), jnp.repeat(c, H, 2),
                         cfg)
    y_ref, st_ref = ssd_ref(x, dt, a, b, c)
    assert float(jnp.abs(y - y_ref.astype(jnp.float32)).max()
                 / (jnp.abs(y_ref).max() + 1e-9)) < 1e-4


# ---------------------------------------------------------------------------
# local FFT kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,n", [(1, 64), (4, 256), (8, 1024),
                                     (3, 4096)])
def test_fft_stage_kernel(rng, batch, n):
    x = (rng.standard_normal((batch, n))
         + 1j * rng.standard_normal((batch, n))).astype(np.complex64)
    y = fft_ops.fft(jnp.asarray(x), interpret=True)
    ref = np.fft.fft(x)
    assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 1e-5
    xi = fft_ops.ifft(jnp.asarray(ref), interpret=True)
    assert np.abs(np.asarray(xi) - x).max() < 1e-4


@settings(max_examples=8, deadline=None)
@given(st.integers(4, 11))
def test_fft_stage_property(logn):
    n = 1 << logn
    rng = np.random.default_rng(logn)
    x = (rng.standard_normal(n) + 1j * rng.standard_normal(n)
         ).astype(np.complex64)
    y = fft_ops.fft(jnp.asarray(x), interpret=True)
    ref = np.fft.fft(x)
    assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 1e-5
